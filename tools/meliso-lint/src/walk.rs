//! Std-only recursive `.rs` collector (walkdir stand-in).

use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};

/// All `.rs` files under `root`, sorted, as paths relative to `root` with
/// `/` separators.  Sorted order keeps diagnostics deterministic across
/// platforms and filesystem enumeration orders.
pub fn rust_sources(root: &Path) -> io::Result<Vec<String>> {
    let mut found = BTreeSet::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            let ty = entry.file_type()?;
            if ty.is_dir() {
                stack.push(path);
            } else if ty.is_file() && path.extension().map(|e| e == "rs").unwrap_or(false) {
                found.insert(relative_slash(root, &path));
            }
        }
    }
    Ok(found.into_iter().collect())
}

fn relative_slash(root: &Path, path: &Path) -> String {
    let rel: PathBuf = path.strip_prefix(root).unwrap_or(path).to_path_buf();
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_this_crate_sorted() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let files = rust_sources(&root).unwrap();
        assert!(files.contains(&"lexer.rs".to_string()), "{files:?}");
        assert!(files.contains(&"rules.rs".to_string()));
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }
}
