//! # meliso-lint — determinism & concurrency static analysis for MELISO+
//!
//! The MELISO+ determinism contract (docs/ARCHITECTURE.md) promises that a
//! solve is bit-identical across shard counts, placements, concurrency
//! levels and steal orders.  That only holds if a handful of source-level
//! invariants hold everywhere; this crate machine-checks them:
//!
//! | rule | name | invariant |
//! |------|------|-----------|
//! | D1 | `nondeterministic_map` | no `HashMap`/`HashSet` in result-path modules (`plane`, `server`, `iterative`, `ec`, `linalg`, `matrices`) — ordered maps only |
//! | D2 | `clock` | no `Instant::now`/`SystemTime` outside `obs/` and `plane/timing.rs` |
//! | D3 | `ad_hoc_random` | no `rand::`/`thread_rng` — randomness flows through `util::rng` counter streams |
//! | C1 | `unbounded_recv` | no bare `.recv()` — gathers and worker loops use `.recv_timeout(..)` |
//! | C2 | `panic_path` | no `.unwrap()`/`.expect()`/`panic!`-family in non-test `plane`/`server` code |
//! | C3 | `lock_order` | structural mutex strictly before per-`(operand, MCA)` slot mutexes, per function |
//!
//! Waive a diagnostic in place with
//! `// meliso-lint: allow(<rule>) -- <reason>` on the offending line or the
//! line above; the reason is mandatory (a bare waiver is a
//! `malformed_waiver` diagnostic).
//!
//! Run it from the workspace root:
//!
//! ```text
//! cargo run -p meliso-lint            # lints rust/src, exit 1 on findings
//! cargo run -p meliso-lint -- <dir>   # lint another source root
//! ```
//!
//! The analysis is token-level (a hand-rolled lexer, no crates.io
//! dependencies — this repo builds hermetically), which is exactly enough
//! for these rules and keeps the tool buildable everywhere the crate is.

pub mod lexer;
pub mod rules;
pub mod walk;

pub use rules::{lint_file, Diagnostic, FileCtx};

use std::io;
use std::path::Path;

/// Lint every `.rs` file under `root` (a source root like `rust/src`).
/// Diagnostics come back sorted by `(file, line, col)`.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Diagnostic>> {
    let mut diags = Vec::new();
    for rel in walk::rust_sources(root)? {
        let src = std::fs::read_to_string(root.join(&rel))?;
        let ctx = FileCtx {
            rel_path: rel.clone(),
        };
        diags.extend(lint_file(&ctx, &src));
    }
    diags.sort();
    Ok(diags)
}
