//! A minimal Rust lexer: just enough token shape for the lint rules.
//!
//! Produces a flat stream of identifier / punctuation / literal tokens with
//! 1-based line:column positions, plus the text of every `//` line comment
//! (the waiver grammar lives in comments).  Strings, raw strings, byte
//! strings, char literals, lifetimes, numbers and nested block comments are
//! consumed correctly so their contents can never masquerade as code — a
//! `"HashMap"` inside a string or doc comment is not a diagnostic.

/// Token class.  The lint rules only distinguish words from punctuation;
/// every literal collapses into [`TokKind::Lit`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Ident,
    Punct,
    Lit,
}

/// One lexed token with its source position (1-based).
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A lexed file: the token stream plus per-line `//` comments.
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// `(line, comment text)` for every line comment, including the `//`.
    pub line_comments: Vec<(u32, String)>,
}

struct Cursor {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens and line comments.  The lexer never fails: any
/// character it does not understand becomes a one-char punctuation token,
/// and unterminated literals simply run to end of file.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor {
        chars: src.chars().collect(),
        pos: 0,
        line: 1,
        col: 1,
    };
    let mut toks = Vec::new();
    let mut line_comments = Vec::new();

    while let Some(c) = cur.peek(0) {
        let (line, col) = (cur.line, cur.col);
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        // Comments.
        if c == '/' && cur.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            line_comments.push((line, text));
            continue;
        }
        if c == '/' && cur.peek(1) == Some('*') {
            cur.bump();
            cur.bump();
            let mut depth = 1usize;
            while depth > 0 {
                match (cur.peek(0), cur.peek(1)) {
                    (Some('/'), Some('*')) => {
                        cur.bump();
                        cur.bump();
                        depth += 1;
                    }
                    (Some('*'), Some('/')) => {
                        cur.bump();
                        cur.bump();
                        depth -= 1;
                    }
                    (Some(_), _) => {
                        cur.bump();
                    }
                    (None, _) => break,
                }
            }
            continue;
        }
        // Raw strings / raw identifiers / byte strings, before plain idents.
        if c == 'r' || c == 'b' {
            if let Some(tok) = lex_prefixed(&mut cur, line, col) {
                toks.push(tok);
                continue;
            }
        }
        if is_ident_start(c) {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if !is_ident_continue(ch) {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text,
                line,
                col,
            });
            continue;
        }
        if c == '"' {
            lex_string(&mut cur);
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::from("\"str\""),
                line,
                col,
            });
            continue;
        }
        if c == '\'' {
            if lex_quote(&mut cur) {
                toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::from("'char'"),
                    line,
                    col,
                });
            }
            // Lifetimes are consumed silently: no rule looks at them.
            continue;
        }
        if c.is_ascii_digit() {
            let text = lex_number(&mut cur);
            toks.push(Tok {
                kind: TokKind::Lit,
                text,
                line,
                col,
            });
            continue;
        }
        cur.bump();
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
            col,
        });
    }

    Lexed {
        toks,
        line_comments,
    }
}

/// Handle `r"…"`, `r#"…"#`, `r#ident`, `b"…"`, `b'…'`, `br#"…"#`.
/// Returns `None` when the `r`/`b` is just the start of a plain identifier
/// (the caller then lexes it normally).
fn lex_prefixed(cur: &mut Cursor, line: u32, col: u32) -> Option<Tok> {
    let c0 = cur.peek(0)?;
    let mut ahead = 1;
    if c0 == 'b' && matches!(cur.peek(1), Some('r')) {
        ahead = 2;
    }
    // Count `#` marks after the prefix.
    let mut hashes = 0usize;
    while cur.peek(ahead + hashes) == Some('#') {
        hashes += 1;
    }
    match cur.peek(ahead + hashes) {
        Some('"') => {
            // (Byte-)raw or plain-prefixed string.  `b"` has hashes == 0.
            for _ in 0..ahead + hashes + 1 {
                cur.bump();
            }
            let raw = c0 == 'r' || ahead == 2;
            if !raw {
                // `b"…"` — ordinary escapes apply.
                lex_string_body(cur);
            } else if hashes == 0 {
                // `r"…"` — no escapes, ends at first quote.
                while let Some(ch) = cur.bump() {
                    if ch == '"' {
                        break;
                    }
                }
            } else {
                // `r#…#"…"#…#` — ends at quote followed by `hashes` marks.
                loop {
                    match cur.bump() {
                        Some('"') => {
                            let mut seen = 0;
                            while seen < hashes && cur.peek(0) == Some('#') {
                                cur.bump();
                                seen += 1;
                            }
                            if seen == hashes {
                                break;
                            }
                        }
                        Some(_) => {}
                        None => break,
                    }
                }
            }
            Some(Tok {
                kind: TokKind::Lit,
                text: String::from("\"str\""),
                line,
                col,
            })
        }
        Some('\'') if c0 == 'b' && ahead == 1 && hashes == 0 => {
            cur.bump(); // b
            lex_quote(cur);
            Some(Tok {
                kind: TokKind::Lit,
                text: String::from("'char'"),
                line,
                col,
            })
        }
        Some(ch) if c0 == 'r' && hashes == 1 && is_ident_start(ch) => {
            // Raw identifier `r#ident` — token text is the bare name.
            cur.bump(); // r
            cur.bump(); // #
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if !is_ident_continue(ch) {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            Some(Tok {
                kind: TokKind::Ident,
                text,
                line,
                col,
            })
        }
        _ => None,
    }
}

/// Consume a `"`-opened string literal, cursor on the opening quote.
fn lex_string(cur: &mut Cursor) {
    cur.bump(); // opening quote
    lex_string_body(cur);
}

/// Consume a string body with escapes, cursor just past the opening quote.
fn lex_string_body(cur: &mut Cursor) {
    while let Some(ch) = cur.bump() {
        match ch {
            '\\' => {
                cur.bump();
            }
            '"' => break,
            _ => {}
        }
    }
}

/// Cursor on a `'`.  Returns `true` if it was a char literal (consumed),
/// `false` for a lifetime (also consumed).
fn lex_quote(cur: &mut Cursor) -> bool {
    cur.bump(); // the quote
    match cur.peek(0) {
        Some('\\') => {
            // Escaped char literal: `\n`, `\\`, `\'`, `\x41`, `\u{1F600}`.
            cur.bump(); // the backslash
            match cur.bump() {
                Some('u') => {
                    if cur.peek(0) == Some('{') {
                        while let Some(ch) = cur.bump() {
                            if ch == '}' {
                                break;
                            }
                        }
                    }
                }
                Some('x') => {
                    cur.bump();
                    cur.bump();
                }
                _ => {}
            }
            if cur.peek(0) == Some('\'') {
                cur.bump();
            }
            true
        }
        Some(ch) if is_ident_continue(ch) => {
            // `'a'` is a char literal; `'a` (no closing quote after the
            // ident run) is a lifetime.
            let mut run = 1;
            while cur.peek(run).map(is_ident_continue).unwrap_or(false) {
                run += 1;
            }
            if cur.peek(run) == Some('\'') && run == 1 {
                cur.bump();
                cur.bump();
                true
            } else {
                for _ in 0..run {
                    cur.bump();
                }
                false
            }
        }
        Some('\'') => {
            cur.bump();
            true
        }
        Some(_) => {
            // Punctuation char literal like `'('`.
            cur.bump();
            if cur.peek(0) == Some('\'') {
                cur.bump();
            }
            true
        }
        None => false,
    }
}

/// Consume a numeric literal (ints, floats, suffixes, exponents).
fn lex_number(cur: &mut Cursor) -> String {
    let mut text = String::new();
    let mut seen_dot = false;
    while let Some(ch) = cur.peek(0) {
        if is_ident_continue(ch) {
            text.push(ch);
            cur.bump();
            continue;
        }
        if ch == '.' && !seen_dot && cur.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false) {
            seen_dot = true;
            text.push(ch);
            cur.bump();
            continue;
        }
        if (ch == '+' || ch == '-')
            && matches!(text.chars().last(), Some('e') | Some('E'))
            && cur.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false)
        {
            text.push(ch);
            cur.bump();
            continue;
        }
        break;
    }
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_idents() {
        let src = r##"
            // HashMap in a comment
            /* HashMap /* nested */ still comment */
            let s = "HashMap";
            let r = r#"HashMap"#;
            let b = b"HashMap";
            let real = HashSet::new();
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(ids.contains(&"HashSet".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; x }";
        let lexed = lex(src);
        let chars: Vec<_> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lit && t.text == "'char'")
            .collect();
        assert_eq!(chars.len(), 1);
        assert!(idents(src).contains(&"str".to_string()));
    }

    #[test]
    fn positions_are_one_based() {
        let lexed = lex("ab\n  cd");
        assert_eq!((lexed.toks[0].line, lexed.toks[0].col), (1, 1));
        assert_eq!((lexed.toks[1].line, lexed.toks[1].col), (2, 3));
    }

    #[test]
    fn line_comments_are_captured_with_line() {
        let lexed = lex("let x = 1; // meliso-lint: allow(clock) -- why\nlet y = 2;");
        assert_eq!(lexed.line_comments.len(), 1);
        let (line, text) = &lexed.line_comments[0];
        assert_eq!(*line, 1);
        assert!(text.contains("allow(clock)"));
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let lexed = lex("for i in 0..10 { }");
        let texts: Vec<_> = lexed.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"0"));
        assert!(texts.contains(&"10"));
        assert_eq!(texts.iter().filter(|t| **t == ".").count(), 2);
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let ids = idents("let r#type = 1;");
        assert!(ids.contains(&"type".to_string()));
    }
}
