//! The lint rules: determinism (D1–D3) and concurrency (C1–C3).
//!
//! Every rule works on the token stream from [`crate::lexer`], with two
//! structural overlays computed first:
//!
//! * **test regions** — the brace span of any item carrying an attribute
//!   that mentions `test` (`#[test]`, `#[cfg(test)]`, …).  Test code is
//!   exempt from every rule except D3 (tests must be deterministic too).
//! * **function spans** — `fn name { … }` brace spans, used by C3 to
//!   approximate lock-acquisition order per function.
//!
//! Diagnostics can be waived in place with
//! `// meliso-lint: allow(<rule>) -- <reason>` on the offending line or the
//! line above.  A waiver without a `-- <reason>` is itself a diagnostic
//! (`malformed_waiver`): the reason is the reviewable artifact.

use crate::lexer::{lex, Tok, TokKind};

/// Rule identifiers, as used in waiver comments and diagnostics.
pub mod rule {
    /// D1 — `HashMap`/`HashSet` in a result-path module.
    pub const NONDETERMINISTIC_MAP: &str = "nondeterministic_map";
    /// D2 — `Instant::now`/`SystemTime` outside `obs/` + `plane/timing.rs`.
    pub const CLOCK: &str = "clock";
    /// D3 — `rand::`/`thread_rng` anywhere (randomness must flow through
    /// `util::rng` counter streams).
    pub const AD_HOC_RANDOM: &str = "ad_hoc_random";
    /// C1 — bare `.recv()` (unbounded wait) instead of `.recv_timeout(..)`.
    pub const UNBOUNDED_RECV: &str = "unbounded_recv";
    /// C2 — `.unwrap()`/`.expect()`/`panic!`-family in non-test
    /// `plane`/`server` code.
    pub const PANIC_PATH: &str = "panic_path";
    /// C3 — slot mutex acquired before the structural mutex in one function.
    pub const LOCK_ORDER: &str = "lock_order";
    /// A waiver comment missing its `-- <reason>` tail.
    pub const MALFORMED_WAIVER: &str = "malformed_waiver";
}

/// Modules whose iteration order can reach solve results (D1 scope).
const RESULT_PATH_MODULES: &[&str] =
    &["plane", "serve", "server", "iterative", "ec", "linalg", "matrices"];

/// Modules where the panic-free (typed-`ServeError`/`PlaneError`)
/// contract holds (C2): no unwrap/expect/panic on the request path.
const PANIC_FREE_MODULES: &[&str] = &["plane", "serve", "server"];

/// One finding, pointing at a file position.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}:{}: [{}] {}",
            self.file, self.line, self.col, self.rule, self.msg
        )
    }
}

/// A parsed `// meliso-lint: allow(<rule>) -- <reason>` comment.
struct Waiver {
    line: u32,
    rule: String,
    has_reason: bool,
}

fn parse_waivers(comments: &[(u32, String)]) -> Vec<Waiver> {
    let mut waivers = Vec::new();
    for (line, text) in comments {
        let Some(at) = text.find("meliso-lint:") else {
            continue;
        };
        let rest = &text[at + "meliso-lint:".len()..];
        let Some(open) = rest.find("allow(") else {
            continue;
        };
        let after = &rest[open + "allow(".len()..];
        let Some(close) = after.find(')') else {
            continue;
        };
        let rule = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let has_reason = tail
            .find("--")
            .map(|d| !tail[d + 2..].trim().is_empty())
            .unwrap_or(false);
        waivers.push(Waiver {
            line: *line,
            rule,
            has_reason,
        });
    }
    waivers
}

/// Inclusive token-index span.
#[derive(Clone, Copy)]
struct Span {
    start: usize,
    end: usize,
}

/// Find the token index of the brace matching the `{` at `open`.
/// Returns the last token index when unbalanced (lexer-level safety net).
fn matching_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Brace spans of items behind a `test`-mentioning attribute.
///
/// Heuristic: an attribute `#[…]` whose bracket content contains the bare
/// identifier `test` (and not `not`, so `#[cfg(not(test))]` keeps its body
/// linted) marks the next `{ … }` block as test code.  Attributes followed
/// by `;` before any `{` (e.g. on a `use`) mark nothing.
fn test_regions(toks: &[Tok]) -> Vec<Span> {
    let mut regions: Vec<Span> = Vec::new();
    let mut i = 0;
    while i + 1 < toks.len() {
        let is_attr = toks[i].kind == TokKind::Punct
            && toks[i].text == "#"
            && toks[i + 1].kind == TokKind::Punct
            && toks[i + 1].text == "[";
        if !is_attr {
            i += 1;
            continue;
        }
        // Find the matching `]`.
        let mut depth = 0usize;
        let mut close = None;
        let mut mentions_test = false;
        let mut mentions_not = false;
        for (j, t) in toks.iter().enumerate().skip(i + 1) {
            match (t.kind, t.text.as_str()) {
                (TokKind::Punct, "[") => depth += 1,
                (TokKind::Punct, "]") => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(j);
                        break;
                    }
                }
                (TokKind::Ident, "test") => mentions_test = true,
                (TokKind::Ident, "not") => mentions_not = true,
                _ => {}
            }
        }
        let Some(close) = close else { break };
        if mentions_test && !mentions_not {
            // Scan for the item body, skipping over further attributes.
            let mut k = close + 1;
            while k < toks.len() {
                let t = &toks[k];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "{" => {
                            let end = matching_brace(toks, k);
                            regions.push(Span { start: k, end });
                            break;
                        }
                        ";" => break,
                        _ => {}
                    }
                }
                k += 1;
            }
        }
        i = close + 1;
    }
    regions
}

/// `fn` body spans with the function name (C3 scope units).
fn fn_spans(toks: &[Tok]) -> Vec<(String, Span)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == TokKind::Ident && toks[i].text == "fn" {
            let name = toks
                .get(i + 1)
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text.clone())
                .unwrap_or_default();
            let mut k = i + 2;
            while k < toks.len() {
                let t = &toks[k];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "{" => {
                            let end = matching_brace(toks, k);
                            spans.push((name.clone(), Span { start: k, end }));
                            i = k; // nested fns/closures re-scan from inside
                            break;
                        }
                        ";" => break, // trait method declaration, no body
                        _ => {}
                    }
                }
                k += 1;
            }
        }
        i += 1;
    }
    spans
}

/// Per-file lint context: path relative to the scanned source root,
/// with `/` separators (e.g. `plane/handle.rs`).
pub struct FileCtx {
    pub rel_path: String,
}

impl FileCtx {
    fn top_module(&self) -> &str {
        match self.rel_path.find('/') {
            Some(cut) => &self.rel_path[..cut],
            None => "",
        }
    }

    fn result_path(&self) -> bool {
        RESULT_PATH_MODULES.contains(&self.top_module())
    }

    fn panic_free(&self) -> bool {
        PANIC_FREE_MODULES.contains(&self.top_module())
    }

    fn clock_exempt(&self) -> bool {
        self.top_module() == "obs" || self.rel_path == "plane/timing.rs"
    }
}

struct Linter<'a> {
    ctx: &'a FileCtx,
    toks: Vec<Tok>,
    tests: Vec<Span>,
    waivers: Vec<Waiver>,
    diags: Vec<Diagnostic>,
}

impl<'a> Linter<'a> {
    fn in_test(&self, idx: usize) -> bool {
        self.tests.iter().any(|s| s.start <= idx && idx <= s.end)
    }

    /// Emit a diagnostic unless a well-formed waiver covers it; a matching
    /// waiver without a reason becomes a `malformed_waiver` diagnostic.
    fn emit(&mut self, rule: &'static str, tok: &Tok, msg: String) {
        let covering = self
            .waivers
            .iter()
            .find(|w| w.rule == rule && (w.line == tok.line || w.line + 1 == tok.line));
        match covering {
            Some(w) if w.has_reason => {}
            Some(w) => {
                self.diags.push(Diagnostic {
                    file: self.ctx.rel_path.clone(),
                    line: w.line,
                    col: 1,
                    rule: rule::MALFORMED_WAIVER,
                    msg: format!(
                        "waiver for `{rule}` is missing its `-- <reason>` tail; \
                         the reason is what makes the waiver reviewable"
                    ),
                });
            }
            None => {
                self.diags.push(Diagnostic {
                    file: self.ctx.rel_path.clone(),
                    line: tok.line,
                    col: tok.col,
                    rule,
                    msg,
                });
            }
        }
    }

    fn ident_at(&self, i: usize, text: &str) -> bool {
        self.toks
            .get(i)
            .map(|t| t.kind == TokKind::Ident && t.text == text)
            .unwrap_or(false)
    }

    fn punct_at(&self, i: usize, text: &str) -> bool {
        self.toks
            .get(i)
            .map(|t| t.kind == TokKind::Punct && t.text == text)
            .unwrap_or(false)
    }

    /// `.name(` method-call shape at ident index `i`.
    fn is_method_call(&self, i: usize) -> bool {
        i >= 1 && self.punct_at(i - 1, ".") && self.punct_at(i + 1, "(")
    }

    fn rule_d1_nondeterministic_map(&mut self) {
        if !self.ctx.result_path() {
            return;
        }
        for i in 0..self.toks.len() {
            let t = &self.toks[i];
            if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
                continue;
            }
            if self.in_test(i) {
                continue;
            }
            let tok = t.clone();
            let name = tok.text.clone();
            let ordered = if name == "HashMap" { "BTreeMap" } else { "BTreeSet" };
            self.emit(
                rule::NONDETERMINISTIC_MAP,
                &tok,
                format!(
                    "`{name}` in result-path module `{}`: iteration order is \
                     nondeterministic; use `{ordered}` or waive with a reason",
                    self.ctx.top_module()
                ),
            );
        }
    }

    fn rule_d2_clock(&mut self) {
        if self.ctx.clock_exempt() {
            return;
        }
        for i in 0..self.toks.len() {
            let t = &self.toks[i];
            if t.kind != TokKind::Ident || self.in_test(i) {
                continue;
            }
            if t.text == "SystemTime" {
                let tok = t.clone();
                self.emit(
                    rule::CLOCK,
                    &tok,
                    "`SystemTime` outside `obs/`: wall-clock reads are confined to \
                     observability (route timing through `plane::timing`)"
                        .to_string(),
                );
            } else if t.text == "Instant"
                && self.punct_at(i + 1, ":")
                && self.punct_at(i + 2, ":")
                && self.ident_at(i + 3, "now")
            {
                let tok = t.clone();
                self.emit(
                    rule::CLOCK,
                    &tok,
                    "`Instant::now()` outside `obs/`/`plane/timing.rs`: clock reads on \
                     execution paths go through `plane::timing::monotonic_now()`"
                        .to_string(),
                );
            }
        }
    }

    fn rule_d3_ad_hoc_random(&mut self) {
        // Applies to test code too: tests replay from counter seeds.
        for i in 0..self.toks.len() {
            let t = &self.toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let hit = t.text == "thread_rng"
                || (t.text == "rand" && self.punct_at(i + 1, ":") && self.punct_at(i + 2, ":"));
            if hit {
                let tok = t.clone();
                self.emit(
                    rule::AD_HOC_RANDOM,
                    &tok,
                    "ad-hoc randomness: all random streams derive from `util::rng` \
                     counter seeds (`exec_stream_seed`/`mca_seed`) so solves replay \
                     bit-identically"
                        .to_string(),
                );
            }
        }
    }

    fn rule_c1_unbounded_recv(&mut self) {
        for i in 0..self.toks.len() {
            let t = &self.toks[i];
            if t.kind != TokKind::Ident || t.text != "recv" {
                continue;
            }
            if !self.is_method_call(i) || self.in_test(i) {
                continue;
            }
            let tok = t.clone();
            self.emit(
                rule::UNBOUNDED_RECV,
                &tok,
                "bare `.recv()` blocks forever if the sender side dies; use \
                 `.recv_timeout(..)` with a liveness check (see `drain_walk`)"
                    .to_string(),
            );
        }
    }

    fn rule_c2_panic_path(&mut self) {
        if !self.ctx.panic_free() {
            return;
        }
        for i in 0..self.toks.len() {
            let t = &self.toks[i];
            if t.kind != TokKind::Ident || self.in_test(i) {
                continue;
            }
            let method_hit = (t.text == "unwrap" || t.text == "expect") && self.is_method_call(i);
            let macro_hit = matches!(
                t.text.as_str(),
                "panic" | "unreachable" | "todo" | "unimplemented"
            ) && self.punct_at(i + 1, "!");
            if method_hit || macro_hit {
                let tok = t.clone();
                let what = if method_hit {
                    format!(".{}()", tok.text)
                } else {
                    format!("{}!", tok.text)
                };
                self.emit(
                    rule::PANIC_PATH,
                    &tok,
                    format!(
                        "`{what}` in non-test `{}` code: the plane/server contract is \
                         typed errors only (`PlaneError`); a panic here kills a shard \
                         or poisons a lock",
                        self.ctx.top_module()
                    ),
                );
            }
        }
    }

    /// Lock tier for C3, classified from the receiver/argument tokens of a
    /// lock acquisition.
    fn classify_lock(&self, site: usize) -> LockTier {
        // `site` indexes the `lock`/`lock_unpoisoned` ident.  Look at the
        // receiver chain before a `.lock()` and the argument tokens after a
        // `lock_unpoisoned(`.
        let mut names: Vec<&str> = Vec::new();
        if self.is_method_call(site) {
            // Walk the `a.b.c` / `a::b` chain backwards.
            let mut k = site - 1; // the `.`
            while k > 0 {
                k -= 1;
                let t = &self.toks[k];
                match (t.kind, t.text.as_str()) {
                    (TokKind::Ident, name) => names.push(name),
                    (TokKind::Punct, "." | ":" | ")" | "]" | "[") => {}
                    (TokKind::Lit, _) => {}
                    _ => break,
                }
            }
        } else if self.punct_at(site + 1, "(") {
            // Argument tokens up to the matching `)`.
            let mut depth = 0usize;
            for t in self.toks.iter().skip(site + 1) {
                match (t.kind, t.text.as_str()) {
                    (TokKind::Punct, "(") => depth += 1,
                    (TokKind::Punct, ")") => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    (TokKind::Ident, name) => names.push(name),
                    _ => {}
                }
            }
        }
        if names.iter().any(|n| *n == "structural") {
            LockTier::Structural
        } else if names.iter().any(|n| *n == "mcas" || *n == "executors") {
            LockTier::Slot
        } else {
            LockTier::Unknown
        }
    }

    fn rule_c3_lock_order(&mut self) {
        // Per function: once a per-(operand, MCA) slot mutex is taken, the
        // structural mutex must not be acquired afterwards in source order.
        // This is an approximation (guards may be dropped between the two
        // calls), deliberately conservative: the repo convention is to
        // never even *write* the inverted order in one function.
        let spans = fn_spans(&self.toks);
        let mut flagged: Vec<(Tok, String)> = Vec::new();
        for (name, span) in &spans {
            let mut slot_seen: Option<u32> = None;
            for i in span.start..=span.end.min(self.toks.len() - 1) {
                let t = &self.toks[i];
                if t.kind != TokKind::Ident || self.in_test(i) {
                    continue;
                }
                let is_lock = (t.text == "lock" && self.is_method_call(i))
                    || (t.text == "lock_unpoisoned" && self.punct_at(i + 1, "("));
                if !is_lock {
                    continue;
                }
                match self.classify_lock(i) {
                    LockTier::Slot => slot_seen = slot_seen.or(Some(t.line)),
                    LockTier::Structural => {
                        if let Some(slot_line) = slot_seen {
                            flagged.push((
                                t.clone(),
                                format!(
                                    "structural mutex acquired after a slot mutex \
                                     (slot lock at line {slot_line}) in fn `{name}`: \
                                     the lock order is structural -> slot, always"
                                ),
                            ));
                        }
                    }
                    LockTier::Unknown => {}
                }
            }
        }
        for (tok, msg) in flagged {
            self.emit(rule::LOCK_ORDER, &tok, msg);
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum LockTier {
    Structural,
    Slot,
    Unknown,
}

/// Lint one file's source text.  `ctx.rel_path` decides which module-scoped
/// rules apply.  Diagnostics come back sorted by position.
pub fn lint_file(ctx: &FileCtx, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let tests = test_regions(&lexed.toks);
    let waivers = parse_waivers(&lexed.line_comments);
    let mut linter = Linter {
        ctx,
        toks: lexed.toks,
        tests,
        waivers,
        diags: Vec::new(),
    };
    if !linter.toks.is_empty() {
        linter.rule_d1_nondeterministic_map();
        linter.rule_d2_clock();
        linter.rule_d3_ad_hoc_random();
        linter.rule_c1_unbounded_recv();
        linter.rule_c2_panic_path();
        linter.rule_c3_lock_order();
    }
    let mut diags = linter.diags;
    diags.sort();
    diags.dedup();
    diags
}
