//! CLI: `meliso-lint [source-root]` — lints `rust/src` by default, prints
//! `file:line:col: [rule] message` diagnostics, exits 1 if any remain.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn default_root() -> PathBuf {
    // Works both from the workspace root (`cargo run -p meliso-lint`) and
    // from the tool's own directory.
    let local = Path::new("rust/src");
    if local.is_dir() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let root = match args.next() {
        Some(flag) if flag == "--help" || flag == "-h" => {
            println!(
                "meliso-lint: determinism & concurrency checks (D1-D3, C1-C3)\n\
                 usage: meliso-lint [source-root]   (default: rust/src)\n\
                 waive: // meliso-lint: allow(<rule>) -- <reason>"
            );
            return ExitCode::SUCCESS;
        }
        Some(dir) => PathBuf::from(dir),
        None => default_root(),
    };
    let diags = match meliso_lint::lint_tree(&root) {
        Ok(diags) => diags,
        Err(err) => {
            eprintln!("meliso-lint: cannot read {}: {err}", root.display());
            return ExitCode::FAILURE;
        }
    };
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("meliso-lint: clean ({} ok)", root.display());
        ExitCode::SUCCESS
    } else {
        println!("meliso-lint: {} diagnostic(s)", diags.len());
        ExitCode::FAILURE
    }
}
