//! C2 fixture: panic paths in non-test server code.

pub fn risky(v: Option<u32>, r: Result<u32, String>) -> u32 {
    let a = v.unwrap();
    let b = r.expect("must");
    if a + b > 100 {
        panic!("overflow");
    }
    a + b
}

pub fn graceful(v: Option<u32>) -> u32 {
    v.unwrap_or_else(|| 0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::graceful(Some(3)), 3);
        let x: Option<u32> = Some(1);
        x.unwrap();
    }
}
