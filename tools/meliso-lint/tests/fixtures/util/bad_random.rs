//! D3 fixture: ad-hoc randomness — applies to test code too.

pub fn sample() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}

#[cfg(test)]
mod tests {
    #[test]
    fn nondeterministic_test_seed() {
        let _ = thread_rng();
    }
}
