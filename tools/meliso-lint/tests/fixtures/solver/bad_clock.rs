//! D2 fixture: clock reads outside `obs/` and `plane/timing.rs`.

use std::time::Instant;
use std::time::SystemTime;

pub fn now_pair() -> u128 {
    let t0 = Instant::now();
    let wall = SystemTime::now();
    let _ = wall;
    t0.elapsed().as_nanos()
}
