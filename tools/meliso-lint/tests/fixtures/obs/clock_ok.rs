//! D2 exemption fixture: `obs/` may read wall clocks.

use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    (Instant::now(), SystemTime::now())
}
