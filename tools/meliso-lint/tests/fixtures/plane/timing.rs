//! D2 exemption fixture: `plane/timing.rs` owns the monotonic clock.

use std::time::Instant;

pub fn monotonic_now() -> Instant {
    Instant::now()
}
