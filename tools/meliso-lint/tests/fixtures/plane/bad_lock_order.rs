//! C3 fixture: slot mutex taken before the structural mutex.

use std::sync::Mutex;

pub struct Shared {
    pub structural: Mutex<u32>,
    pub mcas: Vec<Mutex<u32>>,
}

pub fn inverted(sh: &Shared) -> u32 {
    let slot = sh.mcas[0].lock().unwrap_or_else(|e| e.into_inner());
    let st = sh.structural.lock().unwrap_or_else(|e| e.into_inner());
    *slot + *st
}

pub fn correct(sh: &Shared) -> u32 {
    let st = sh.structural.lock().unwrap_or_else(|e| e.into_inner());
    let slot = sh.mcas[0].lock().unwrap_or_else(|e| e.into_inner());
    *st + *slot
}
