//! D1 fixture: nondeterministic maps in a result-path module.

use std::collections::HashMap;
use std::collections::HashSet;

// meliso-lint: allow(nondeterministic_map) -- bounded scratch, drained before results
use std::collections::HashMap as WaivedMap;

// meliso-lint: allow(nondeterministic_map)
use std::collections::HashSet as BadWaiver;

pub fn sizes() -> (usize, usize) {
    let m = HashMap::<u32, u32>::new();
    let s = HashSet::<u32>::new();
    (m.len(), s.len())
}
