//! C1 fixture: unbounded receives in worker loops.

use std::sync::mpsc::Receiver;
use std::time::Duration;

pub fn gather(rx: &Receiver<u32>) -> u32 {
    rx.recv().unwrap_or(0)
}

pub fn gather_bounded(rx: &Receiver<u32>) -> u32 {
    rx.recv_timeout(Duration::from_millis(200)).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;

    #[test]
    fn recv_in_tests_is_fine() {
        let (tx, rx) = channel();
        tx.send(1u32).ok();
        let _ = rx.recv();
    }
}
