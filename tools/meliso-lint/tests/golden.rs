//! Golden tests pinning the lint rules against drift: the fixture tree
//! under `tests/fixtures/` must produce exactly the diagnostics recorded
//! in `tests/fixtures/expected.txt`, every rule must fire at least once,
//! and the real `rust/src` tree must stay clean.

use meliso_lint::rules::rule;
use meliso_lint::lint_tree;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn fixtures_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn fixture_diags() -> Vec<String> {
    lint_tree(&fixtures_root())
        .expect("fixture tree readable")
        .iter()
        .map(|d| d.to_string())
        .collect()
}

#[test]
fn fixtures_match_golden_diagnostics() {
    let got = fixture_diags();
    let expected: Vec<String> = std::fs::read_to_string(fixtures_root().join("expected.txt"))
        .expect("expected.txt readable")
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect();
    assert_eq!(
        got,
        expected,
        "fixture diagnostics drifted from the golden file;\n\
         got:\n  {}\nexpected:\n  {}",
        got.join("\n  "),
        expected.join("\n  ")
    );
}

#[test]
fn every_rule_fires_at_least_once() {
    let diags = lint_tree(&fixtures_root()).expect("fixture tree readable");
    let fired: BTreeSet<&str> = diags.iter().map(|d| d.rule).collect();
    for required in [
        rule::NONDETERMINISTIC_MAP,
        rule::CLOCK,
        rule::AD_HOC_RANDOM,
        rule::UNBOUNDED_RECV,
        rule::PANIC_PATH,
        rule::LOCK_ORDER,
        rule::MALFORMED_WAIVER,
    ] {
        assert!(
            fired.contains(required),
            "rule `{required}` never fired in the fixture tree (fired: {fired:?})"
        );
    }
}

#[test]
fn clean_fixtures_stay_clean() {
    let diags = fixture_diags();
    for clean in [
        "linalg/clean.rs",
        "obs/clock_ok.rs",
        "plane/timing.rs",
        "plane/bad_lock_order.rs:17", // the `correct` fn must not fire
    ] {
        let hits: Vec<&String> = diags.iter().filter(|d| d.contains(clean)).collect();
        assert!(hits.is_empty(), "unexpected diagnostics for {clean}: {hits:?}");
    }
}

/// The real tree is the ultimate fixture: `rust/src` stays lint-clean, so
/// the CI `static-analysis` job is blocking, not advisory.
#[test]
fn repo_source_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    if !root.is_dir() {
        // Tool checked out standalone — nothing to lint.
        return;
    }
    let diags = lint_tree(&root).expect("rust/src readable");
    let rendered: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        rendered.is_empty(),
        "rust/src has lint diagnostics:\n  {}",
        rendered.join("\n  ")
    );
}
