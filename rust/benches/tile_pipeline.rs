//! Tile-pipeline benchmark: leader-extracted dense tiles vs shard-side
//! materialization from chunk descriptors.
//!
//! The historical `program` path extracts every occupied chunk as a dense
//! zero-padded tile *on the leader thread* (double-buffered, but still a
//! serial per-chunk stage) before dispatching it to the owning shard.
//! `program_shared` instead ships a compact chunk descriptor — an `Arc`'d
//! [`MatrixSource`] plus chunk coordinates — and each shard materializes
//! its own tiles fused directly into conductance encoding.  On irregular
//! CSR operands with many shards the leader stage stops bounding
//! throughput.  This bench records, per operand (`sprand1k` / `powlaw1k`
//! patterns, smaller in `--quick`):
//!
//! * **chunks/s** programming throughput of both paths at 8 shards,
//! * the **leader extract-stage seconds**
//!   (`meliso_plane_extract_seconds_total` delta: the borrowed path pays
//!   it, the descriptor path retires it),
//! * the **shard fused encode seconds**
//!   (`meliso_shard_encode_seconds_total` delta, spread over the pool),
//! * **bit-identity** — a batch solved on a leader-programmed residency
//!   must equal the same batch on a descriptor-programmed residency
//!   (always asserted, never gated).
//!
//! The perf thresholds — descriptor path ≥ 1.5× chunks/s on the irregular
//! operands and leader extract-stage seconds reduced ≥ 4× — only assert
//! under `MELISO_BENCH_ASSERT=1`, the repo convention for wall-clock
//! claims on shared runners.
//!
//! Emits `BENCH_tile_pipeline.json` under `bench_results/`.
//!
//! Usage: `cargo bench --bench tile_pipeline [-- --quick --reps N]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{generators, MatrixSource};
use meliso::obs;
use meliso::plane::PlaneHandle;
use meliso::prelude::*;
use meliso::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Sum a counter family across all its label series (shards, stages).
fn counter_total(name: &str) -> f64 {
    obs::global()
        .snapshot()
        .families
        .iter()
        .filter(|f| f.name == name)
        .flat_map(|f| f.series.iter())
        .map(|s| match s.value {
            obs::registry::SeriesValue::Counter(v) => v,
            _ => 0.0,
        })
        .sum()
}

fn main() {
    let args = BenchArgs::parse();
    // The extract/encode stage seconds this bench reports are metrics
    // counters; record them regardless of the environment's MELISO_OBS.
    obs::set_level(obs::ObsLevel::Metrics);

    let n: usize = if args.quick { 256 } else { 1000 };
    let cell = 32usize;
    let workers = 8usize;
    let reps = args.reps_or(2, 3, 5);
    let seed = 0x711E_u64;
    let system = SystemConfig::new(4, 2, cell); // 8 MCAs -> 8 shards
    let opts = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
        .with_workers(workers)
        .with_ground_truth(false);

    // The registry's irregular testbed patterns (sprand1k / powlaw1k),
    // generated at bench dimension.
    let operands: Vec<(&str, Arc<dyn MatrixSource>)> = vec![
        (
            "sprand",
            Arc::new(generators::sprand_spd_csr(n, 4, 4.0, 1.0e2, 0.2, seed ^ 1)),
        ),
        (
            "powlaw",
            Arc::new(generators::power_law_csr(n, 3, 4.0, 1.0e2, 0.2, seed ^ 2)),
        ),
    ];

    println!(
        "# tile pipeline: {n}x{n} CSR operands on 4x2 MCAs of {cell}², {workers} shards, \
         {reps} reps\n"
    );

    let hard_assert = std::env::var("MELISO_BENCH_ASSERT").as_deref() == Ok("1");
    let mut op_series = Vec::new();
    for (name, src) in &operands {
        // Programming throughput, best-of-reps per path.  A fresh plane
        // per rep so every rep programs into empty tile slots.
        let mut leader_wall = f64::INFINITY;
        let mut shard_wall = f64::INFINITY;
        let mut chunks = 0usize;
        let mut leader_extract_s = 0.0;
        let mut shard_extract_s = 0.0;
        let mut leader_encode_s = 0.0;
        let mut shard_encode_s = 0.0;
        for _ in 0..reps {
            let plane = PlaneHandle::build(src.as_ref(), &system, &opts, backend()).unwrap();
            let (ex0, en0) = (
                counter_total(obs::names::PLANE_EXTRACT_SECONDS),
                counter_total(obs::names::SHARD_ENCODE_SECONDS),
            );
            let t = Instant::now();
            let (_, report) = plane.program(src.as_ref()).unwrap();
            leader_wall = leader_wall.min(t.elapsed().as_secs_f64());
            leader_extract_s += counter_total(obs::names::PLANE_EXTRACT_SECONDS) - ex0;
            leader_encode_s += counter_total(obs::names::SHARD_ENCODE_SECONDS) - en0;
            chunks = report.chunks_resident;

            let plane = PlaneHandle::build(src.as_ref(), &system, &opts, backend()).unwrap();
            let (ex0, en0) = (
                counter_total(obs::names::PLANE_EXTRACT_SECONDS),
                counter_total(obs::names::SHARD_ENCODE_SECONDS),
            );
            let t = Instant::now();
            let (_, report) = plane.program_shared(src.clone()).unwrap();
            shard_wall = shard_wall.min(t.elapsed().as_secs_f64());
            shard_extract_s += counter_total(obs::names::PLANE_EXTRACT_SECONDS) - ex0;
            shard_encode_s += counter_total(obs::names::SHARD_ENCODE_SECONDS) - en0;
            assert_eq!(
                chunks, report.chunks_resident,
                "{name}: paths programmed different chunk sets"
            );
        }
        let leader_cps = chunks as f64 / leader_wall.max(1e-12);
        let shard_cps = chunks as f64 / shard_wall.max(1e-12);
        let speedup = shard_cps / leader_cps.max(1e-12);
        // The borrowed path pays the leader extract stage every rep; the
        // descriptor path must retire it (shards extract instead).
        let extract_reduction =
            (leader_extract_s / reps as f64) / (shard_extract_s / reps as f64).max(1e-9);

        // Bit-identity across materialization paths — always asserted.
        let xs: Vec<Vector> = (0..2u64)
            .map(|k| Vector::standard_normal(n, 0xB0 + k))
            .collect();
        let solve = |shared: bool| -> Vec<Vector> {
            let plane = PlaneHandle::build(src.as_ref(), &system, &opts, backend()).unwrap();
            let id = if shared {
                plane.program_shared(src.clone()).unwrap().0
            } else {
                plane.program(src.as_ref()).unwrap().0
            };
            plane
                .execute_batch(id, &xs)
                .unwrap()
                .solves
                .into_iter()
                .map(|s| s.y)
                .collect()
        };
        assert_eq!(
            solve(false),
            solve(true),
            "{name}: descriptor materialization changed the result"
        );

        println!(
            "{name}: {chunks} chunks  leader {leader_wall:>7.3} s ({leader_cps:>9.1} chunks/s, \
             extract {:.3} s/rep)  descriptor {shard_wall:>7.3} s ({shard_cps:>9.1} chunks/s)  \
             -> {speedup:.2}x, extract stage /{extract_reduction:.0}",
            leader_extract_s / reps as f64,
        );
        if hard_assert {
            assert!(
                speedup >= 1.5,
                "{name}: descriptor path {speedup:.2}x < 1.5x leader chunks/s"
            );
            assert!(
                extract_reduction >= 4.0,
                "{name}: leader extract stage only reduced {extract_reduction:.1}x (< 4x)"
            );
        }

        let mut j = Json::obj();
        j.set("operand", Json::Str(name.to_string()))
            .set("chunks", Json::Num(chunks as f64))
            .set("leader_wall_s", Json::Num(leader_wall))
            .set("leader_chunks_per_s", Json::Num(leader_cps))
            .set(
                "leader_extract_s_per_rep",
                Json::Num(leader_extract_s / reps as f64),
            )
            .set(
                "leader_encode_s_per_rep",
                Json::Num(leader_encode_s / reps as f64),
            )
            .set("shard_wall_s", Json::Num(shard_wall))
            .set("shard_chunks_per_s", Json::Num(shard_cps))
            .set(
                "shard_extract_s_per_rep",
                Json::Num(shard_extract_s / reps as f64),
            )
            .set(
                "shard_encode_s_per_rep",
                Json::Num(shard_encode_s / reps as f64),
            )
            .set("speedup", Json::Num(speedup))
            .set("extract_stage_reduction", Json::Num(extract_reduction))
            .set("bit_identical", Json::Bool(true));
        op_series.push(j);
    }

    let mut counters = Json::obj();
    counters
        .set(
            obs::names::SHARD_ENCODE_SECONDS,
            Json::Num(counter_total(obs::names::SHARD_ENCODE_SECONDS)),
        )
        .set(
            obs::names::SUBMCA_STEALS,
            Json::Num(counter_total(obs::names::SUBMCA_STEALS)),
        );
    let mut j = Json::obj();
    j.set("bench", Json::Str("tile_pipeline".to_string()))
        .set("n", Json::Num(n as f64))
        .set("cell", Json::Num(cell as f64))
        .set("workers", Json::Num(workers as f64))
        .set("reps", Json::Num(reps as f64))
        .set("operands", Json::Arr(op_series))
        .set("counters", counters);
    args.write_result("BENCH_tile_pipeline.json", &j.pretty());

    if hard_assert {
        println!("\nPASS: bit-identical paths, descriptor >=1.5x chunks/s, extract stage >=4x down");
    } else {
        println!(
            "\nDONE (perf thresholds reported, not asserted — set MELISO_BENCH_ASSERT=1 to \
             enforce >=1.5x chunks/s and >=4x extract-stage reduction)"
        );
    }
}
