//! §Perf hot-path microbenchmarks + ablations (DESIGN.md §6):
//!
//! * tile MVM / EC-MVM throughput per backend (PJRT artifact vs native),
//! * encode (write–verify) cost per tile,
//! * end-to-end distributed solve throughput vs worker count,
//! * ablations: fused `ec_mvm` artifact vs 4 separate `mvm` calls,
//!   in-memory vs digital denoise, sparsity-aware chunk skipping on/off.
//!
//! Usage: `cargo bench --bench hotpath [-- --quick]`

use meliso::bench::{backend, BenchArgs, BenchRunner};
use meliso::device::materials::Material;
use meliso::ec::DenoiseMode;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::runtime::{Backend, EcMvmRequest};
use meliso::util::rng::Rng;
use std::sync::Arc;

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bench_backend_tiles(runner: &BenchRunner, name: &str, b: &Backend, sizes: &[usize]) {
    for &n in sizes {
        let a = rand_vec(n * n, 1);
        let x = rand_vec(n, 2);
        let stats = runner.run(&format!("{name}/mvm_{n}"), || {
            let _ = b.mvm(n, a.clone(), x.clone()).unwrap();
        });
        // 2 n^2 flops per MVM.
        println!("{}", stats.throughput_line(2.0 * (n * n) as f64, "flop"));

        let minv = {
            let mut m = vec![0.0f32; n * n];
            for i in 0..n {
                m[i * n + i] = 1.0;
            }
            m
        };
        let ones = vec![1.0f32; n];
        let req = EcMvmRequest {
            n,
            a: a.clone(),
            at: a.clone(),
            x: x.clone(),
            xt: x.clone(),
            minv,
            nv: ones.clone(),
            nu: ones.clone(),
            ny: ones,
        };
        let clone_req = || EcMvmRequest {
            n: req.n,
            a: req.a.clone(),
            at: req.at.clone(),
            x: req.x.clone(),
            xt: req.xt.clone(),
            minv: req.minv.clone(),
            nv: req.nv.clone(),
            nu: req.nu.clone(),
            ny: req.ny.clone(),
        };
        let stats = runner.run(&format!("{name}/ec_mvm_{n}"), || {
            let _ = b.ec_mvm(clone_req()).unwrap();
        });
        // 4 MVMs + combine.
        println!("{}", stats.throughput_line(8.0 * (n * n) as f64, "flop"));
    }
}

fn bench_fused_vs_separate(runner: &BenchRunner, b: &Backend, n: usize) {
    println!("\n-- ablation: fused ec_mvm artifact vs 4 separate mvm calls (n={n}) --");
    let a = rand_vec(n * n, 3);
    let x = rand_vec(n, 4);
    let mut minv = vec![0.0f32; n * n];
    for i in 0..n {
        minv[i * n + i] = 1.0;
    }
    let ones = vec![1.0f32; n];
    let req = EcMvmRequest {
        n,
        a: a.clone(),
        at: a.clone(),
        x: x.clone(),
        xt: x.clone(),
        minv: minv.clone(),
        nv: ones.clone(),
        nu: ones.clone(),
        ny: ones.clone(),
    };
    let clone_req = || EcMvmRequest {
        n: req.n,
        a: req.a.clone(),
        at: req.at.clone(),
        x: req.x.clone(),
        xt: req.xt.clone(),
        minv: req.minv.clone(),
        nv: req.nv.clone(),
        nu: req.nu.clone(),
        ny: req.ny.clone(),
    };
    let fused = runner.run("fused/ec_mvm", || {
        let _ = b.ec_mvm(clone_req()).unwrap();
    });
    println!("{}", fused.throughput_line(1.0, "ec-op"));
    let separate = runner.run("separate/4x mvm + combine", || {
        let v = b.mvm(n, a.clone(), x.clone()).unwrap();
        let u = b.mvm(n, a.clone(), x.clone()).unwrap();
        let y = b.mvm(n, a.clone(), x.clone()).unwrap();
        let p: Vec<f32> = (0..n).map(|i| v[i] + u[i] - y[i]).collect();
        let _ = b.mvm(n, minv.clone(), p).unwrap();
    });
    println!("{}", separate.throughput_line(1.0, "ec-op"));
    println!(
        "   fused speedup: {:.2}x",
        separate.mean_s / fused.mean_s.max(1e-12)
    );
}

fn bench_encode(runner: &BenchRunner) {
    println!("\n-- encode (write-verify) cost per 128² tile --");
    for k in [0usize, 2, 5] {
        let stats = runner.run(&format!("encode/wv_k{k}"), || {
            let mut mca = meliso::mca::Mca::new(Material::TaOxHfOx, 128, 128, 7);
            let a = Matrix::standard_normal(128, 128, 5);
            let opts = meliso::mca::WriteVerifyOpts {
                max_iters: k,
                rel_tol: 1e-9,
                norm_inf: false,
            };
            let _ = mca.write_verify_matrix(&a, &opts);
        });
        println!("{}", stats.throughput_line(128.0 * 128.0, "cell"));
    }
}

fn bench_solve_scaling(runner: &BenchRunner, b: &Backend) {
    println!("\n-- end-to-end distributed solve (add32, 8x8x256, EC) vs workers --");
    let source = registry::build("add32").unwrap();
    let x = Vector::standard_normal(source.ncols(), 1);
    let mut base = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_workers(workers)
            .with_wv_iters(1);
        let solver = Meliso::with_backend(SystemConfig::tiles_8x8(256), opts, b.clone());
        let stats = runner.run(&format!("solve/workers_{workers}"), || {
            let _ = solver.solve_source(source.as_ref(), &x).unwrap();
        });
        println!("{}", stats.throughput_line(1.0, "solve"));
        if workers == 1 {
            base = stats.mean_s;
        } else {
            println!("   speedup vs 1 worker: {:.2}x", base / stats.mean_s.max(1e-12));
        }
    }
}

fn bench_denoise_modes(runner: &BenchRunner, b: &Backend) {
    println!("\n-- ablation: denoise mode (iperturb66, TaOx, EC) --");
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 2);
    for (label, mode) in [
        ("in-memory", DenoiseMode::InMemory),
        ("digital", DenoiseMode::Digital),
        ("off", DenoiseMode::Off),
    ] {
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_denoise(mode)
            .with_wv_iters(2);
        let solver = Meliso::with_backend(SystemConfig::single_mca(128), opts, b.clone());
        let report = solver.solve_source(source.as_ref(), &x).unwrap();
        let stats = runner.run(&format!("denoise/{label}"), || {
            let _ = solver.solve_source(source.as_ref(), &x).unwrap();
        });
        println!(
            "{}   [eps_l2 {:.4e}]",
            stats.throughput_line(1.0, "solve"),
            report.rel_err_l2
        );
    }
}

fn bench_sparsity_skipping(runner: &BenchRunner, b: &Backend) {
    println!("\n-- ablation: sparsity-aware chunk skipping (add32 banded vs dense view) --");
    let banded = registry::build("add32").unwrap();
    let x = Vector::standard_normal(banded.ncols(), 3);
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_workers(4)
        .with_wv_iters(0);
    let solver = Meliso::with_backend(SystemConfig::tiles_8x8(512), opts, b.clone());
    let skipping = runner.run("skip/banded-source", || {
        let _ = solver.solve_source(banded.as_ref(), &x).unwrap();
    });
    println!("{}", skipping.throughput_line(1.0, "solve"));
    // Dense view of the same operand: block_is_zero always false.
    let dense = meliso::matrices::DenseSource::new(banded.block(0, 0, 4960, 4960));
    let no_skipping = runner.run("skip/dense-view", || {
        let _ = solver.solve_source(&dense, &x).unwrap();
    });
    println!("{}", no_skipping.throughput_line(1.0, "solve"));
    println!(
        "   skipping speedup: {:.2}x",
        no_skipping.mean_s / skipping.mean_s.max(1e-12)
    );
}

fn main() {
    let args = BenchArgs::parse();
    let runner = if args.quick {
        BenchRunner { warmup_iters: 1, sample_iters: 3 }
    } else {
        BenchRunner::default()
    };
    println!("# hotpath microbenchmarks + ablations\n");

    let native: Backend = Arc::new(NativeBackend::new());
    let primary = backend();
    let sizes: &[usize] = if args.quick { &[128, 1024] } else { &[32, 128, 512, 1024] };

    println!("-- tile kernels: native backend --");
    bench_backend_tiles(&runner, "native", &native, sizes);
    if primary.name() == "pjrt" {
        println!("\n-- tile kernels: pjrt artifact backend --");
        bench_backend_tiles(&runner, "pjrt", &primary, sizes);
    }

    bench_fused_vs_separate(&runner, &primary, 512);
    bench_encode(&runner);
    bench_solve_scaling(&runner, &primary);
    bench_denoise_modes(&runner, &primary);
    bench_sparsity_skipping(&runner, &primary);
}
