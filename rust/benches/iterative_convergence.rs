//! Iterative-solver convergence benchmark: CG / GMRES / Jacobi on
//! resident crossbar sessions.
//!
//! The headline claim of the iterative subsystem, asserted here:
//!
//! * **CG on a registry SPD operand converges to relative residual
//!   ≤ 1e-6** through a resident session — analog MVMs plus exact f64
//!   host-side iterative refinement — with **exactly one** write–verify
//!   programming pass for the whole solve.  Every iteration after the
//!   open is read-only, so the conductance write amortizes across the
//!   full Krylov trajectory (`write_amortization` in the output).
//!
//! All noise streams are seeded, so the trajectory is deterministic and
//! the assertions are stable across machines (no wall-clock thresholds).
//!
//! Usage: `cargo bench --bench iterative_convergence [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::util::json::Json;

fn solve_one(
    solver: &Meliso,
    matrix: &str,
    seed: u64,
    iter: &IterOptions,
) -> Result<ConvergenceReport, String> {
    let source = registry::build(matrix)?;
    let x_star = Vector::standard_normal(source.ncols(), seed);
    let b = source.matvec(&x_star);
    solver.solve_system(source, &b, iter)
}

fn main() {
    let args = BenchArgs::parse();
    let refinements = args.reps_or(30, 50, 80);
    let opts = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_wv_iters(4)
        .with_workers(2)
        .with_seed(42);
    let solver = Meliso::with_backend(SystemConfig::single_mca(64), opts, backend());

    println!("# iterative convergence on resident sessions (EpiRAM, 64² MCA)\n");

    // --- the asserted case: CG on a registry SPD operand ---------------
    let cg = IterOptions::default()
        .with_method(Method::Cg)
        .with_tol(1e-6)
        .with_max_iters(40)
        .with_inner_tol(1e-2)
        .with_refinements(refinements);
    let report = solve_one(&solver, "spd64", 7, &cg).unwrap();
    println!("{}\n", report.render());

    // --- companion methods (reported, not asserted) ---------------------
    let gmres = IterOptions::default()
        .with_method(Method::Gmres)
        .with_restart(24)
        .with_tol(1e-6)
        .with_max_iters(48)
        .with_inner_tol(1e-2)
        .with_refinements(refinements);
    let gmres_report = solve_one(&solver, "nonsym64", 9, &gmres).unwrap();
    println!("{}\n", gmres_report.render());

    let jacobi = IterOptions::default()
        .with_method(Method::Jacobi)
        .with_tol(1e-6)
        .with_max_iters(60)
        .with_inner_tol(1e-2)
        .with_refinements(refinements);
    let jacobi_report = solve_one(&solver, "iperturb66", 11, &jacobi).unwrap();
    println!("{}\n", jacobi_report.render());

    let mut j = Json::obj();
    j.set("bench", Json::Str("iterative_convergence".to_string()))
        .set("refinements_budget", Json::Num(refinements as f64))
        .set("cg_spd64", report.to_json())
        .set("gmres_nonsym64", gmres_report.to_json())
        .set("jacobi_iperturb66", jacobi_report.to_json());
    args.write_result("BENCH_iterative_convergence.json", &j.pretty());

    assert!(
        report.converged && report.rel_residual <= 1e-6,
        "CG on spd64 must reach 1e-6, got {:.3e} (converged: {})",
        report.rel_residual,
        report.converged
    );
    assert_eq!(
        report.programming_passes, 1,
        "the whole solve must pay exactly one write-verify programming pass"
    );
    assert!(
        report.mvms as usize >= report.iterations,
        "every inner iteration is one served MVM"
    );
    println!(
        "PASS: CG reached {:.3e} with one programming pass over {} MVMs \
         (write amortization {:.0}x)",
        report.rel_residual,
        report.mvms,
        report.write_amortization()
    );
}
