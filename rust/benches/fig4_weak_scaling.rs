//! Regenerates **Figure 4 (weak scaling)**: fixed problem (add32, 4960²),
//! fixed 8×8 MCA tile array, array cell size swept 32² → 1024².  Reports
//! relative error norms and the mean-across-MCAs write energy/latency —
//! small cells force virtualization reassignment (energy/latency blow up),
//! large cells execute in a single pass.
//!
//! Usage: `cargo bench --bench fig4_weak_scaling [-- --reps N --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{registry, DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;

fn main() {
    let args = BenchArgs::parse();
    let reps = args.reps_or(1, 1, 10);
    let backend = backend();
    // Small cell sizes mean thousands of chunk encodes on one host; the
    // default skips 32² unless --full is set (the trend is identical).
    let cells: Vec<usize> = if args.full {
        vec![32, 64, 128, 256, 512, 1024]
    } else if args.quick {
        vec![256, 512, 1024]
    } else {
        vec![64, 128, 256, 512, 1024]
    };

    // --dense replicates the paper's dense mapping (no sparsity-aware chunk
    // skipping): every chunk is assigned, so small cells pay the full
    // virtualization reassignment overhead — the paper's Fig 4 trend.  The
    // default banded path shows our sparsity optimization on top of it.
    let dense = args.rest.iter().any(|a| a == "--dense");
    println!(
        "# Fig 4 — weak scaling: add32 (4960²) on 8x8 tiles, cell size sweep ({reps} reps{})\n",
        if dense { ", dense mapping" } else { ", sparsity-aware" }
    );
    let banded = registry::build("add32").unwrap();
    let source: std::sync::Arc<dyn MatrixSource> = if dense {
        std::sync::Arc::new(DenseSource::new(banded.block(0, 0, 4960, 4960)))
    } else {
        banded
    };
    let x = Vector::standard_normal(source.ncols(), 0x5eed);
    let mut csv = String::from("cell,device,eps_l2,eps_inf,ew_j,lw_s,chunks,skipped,reassign\n");
    println!(
        "{:>5}  {:<10} {:>11} {:>11} {:>11} {:>11} {:>7} {:>8} {:>9}",
        "cell", "device", "eps_l2", "eps_inf", "E_w(J)", "L_w(s)", "chunks", "skipped", "reassign"
    );
    for &cell in &cells {
        for material in Material::ALL {
            let opts = SolveOptions::default()
                .with_device(material)
                .with_ec(true)
                .with_wv_iters(2)
                .with_workers(4);
            let solver =
                Meliso::with_backend(SystemConfig::tiles_8x8(cell), opts, backend.clone());
            let reports = solver.replicate(source.as_ref(), &x, reps).unwrap();
            let s = ReplicationSummary::from_reports(&reports);
            let last = reports.last().unwrap();
            println!(
                "{cell:>5}  {:<10} {:>11.4e} {:>11.4e} {:>11.4e} {:>11.4e} {:>7} {:>8} {:>9}",
                material.name(),
                s.rel_err_l2,
                s.rel_err_inf,
                s.ew_mean,
                s.lw_mean,
                last.chunks_total,
                last.chunks_skipped,
                last.row_reassignments,
            );
            csv.push_str(&format!(
                "{cell},{},{:.6e},{:.6e},{:.6e},{:.6e},{},{},{}\n",
                material.name(),
                s.rel_err_l2,
                s.rel_err_inf,
                s.ew_mean,
                s.lw_mean,
                last.chunks_total,
                last.chunks_skipped,
                last.row_reassignments,
            ));
        }
    }
    args.write_result("fig4_weak_scaling.csv", &csv);
}
