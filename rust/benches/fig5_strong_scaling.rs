//! Regenerates **Figure 5 (strong scaling)**: fixed hardware (8×8 tiles of
//! 1024² cells), problem size swept across the SuiteSparse stand-ins from
//! 66² (bcsstk02) to 65,025² (Dubcova2).  Reports error norms and
//! mean-across-MCAs write energy/latency, both raw and divided by the
//! virtualization normalization factor (the paper's dashed lines, applied
//! from 16,129² up).
//!
//! Usage: `cargo bench --bench fig5_strong_scaling [-- --quick | --full]`
//! `--quick` stops at add32 (4960²); the default stops at Dubcova1
//! (16,129²); `--full` runs all seven sizes including Dubcova2 (65,025²).

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;

fn main() {
    let args = BenchArgs::parse();
    let reps = args.reps_or(1, 1, 3);
    let backend = backend();
    let cutoff = if args.full {
        usize::MAX
    } else if args.quick {
        5_000
    } else {
        17_000
    };

    println!("# Fig 5 — strong scaling: 8x8 tiles x 1024² cells, problem-size sweep ({reps} reps)\n");
    let mut csv = String::from(
        "matrix,dim,device,eps_l2,eps_inf,ew_j,lw_s,ew_norm_j,lw_norm_s,reassign,chunks,skipped,wall_s\n",
    );
    println!(
        "{:<10} {:>6}  {:<10} {:>11} {:>11} {:>11} {:>11} {:>9} {:>8}",
        "matrix", "dim", "device", "eps_l2", "E_w(J)", "L_w(s)", "L_w/norm", "reassign", "wall(s)"
    );
    for name in registry::STRONG_SCALING_ORDER {
        let info = registry::info(name).unwrap();
        if info.dim > cutoff {
            println!("[skipping {name} ({}²) — use --full]", info.dim);
            continue;
        }
        let source = registry::build(name).unwrap();
        let x = Vector::standard_normal(source.ncols(), 0x5eed);
        for material in Material::ALL {
            let opts = SolveOptions::default()
                .with_device(material)
                .with_ec(true)
                .with_wv_iters(2)
                .with_workers(4);
            let solver =
                Meliso::with_backend(SystemConfig::tiles_8x8(1024), opts, backend.clone());
            let mut acc_l2 = 0.0;
            let mut acc_inf = 0.0;
            let mut acc_ew = 0.0;
            let mut acc_lw = 0.0;
            let mut last = None;
            for r in 0..reps {
                let opts_run = solver.options().clone().with_seed(42 + r as u64);
                let solver_run = Meliso::with_backend(
                    *solver.config(),
                    opts_run,
                    backend.clone(),
                );
                let report = solver_run.solve_source(source.as_ref(), &x).unwrap();
                acc_l2 += report.rel_err_l2;
                acc_inf += report.rel_err_inf;
                acc_ew += report.ew_mean;
                acc_lw += report.lw_mean;
                last = Some(report);
            }
            let n = reps as f64;
            let (l2, inf, ew, lw) = (acc_l2 / n, acc_inf / n, acc_ew / n, acc_lw / n);
            let last = last.unwrap();
            // The paper's normalization: divide by the per-MCA reassignment
            // count, applied from 16,129² up.
            let norm = if info.dim >= 16_129 {
                last.row_reassignments as f64
            } else {
                1.0
            };
            println!(
                "{:<10} {:>6}  {:<10} {:>11.4e} {:>11.4e} {:>11.4e} {:>11.4e} {:>9} {:>8.1}",
                name,
                info.dim,
                material.name(),
                l2,
                ew,
                lw,
                lw / norm,
                last.row_reassignments,
                last.wall_seconds,
            );
            csv.push_str(&format!(
                "{name},{},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{},{},{},{:.2}\n",
                info.dim,
                material.name(),
                l2,
                inf,
                ew,
                lw,
                ew / norm,
                lw / norm,
                last.row_reassignments,
                last.chunks_total,
                last.chunks_skipped,
                last.wall_seconds,
            ));
        }
    }
    args.write_result("fig5_strong_scaling.csv", &csv);
}
