//! Execution-plane scaling benchmark: streaming sparsity-aware dispatch
//! over the sharded worker pool (`meliso::plane`).
//!
//! Quantifies what the unified plane exists for:
//!
//! * **chunks/s** — occupied-chunk throughput of the one-shot path as the
//!   shard count sweeps (the leader streams tiles through
//!   `ChunkPlan::nonzero_chunks`, so a banded operand never pays the
//!   O(grid²) walk or a dense materialization),
//! * **normalization-factor sweep** — the paper's Fig 5 axis: smaller
//!   cells force more MCA reassignments per solve; the bench records
//!   throughput across cell sizes at fixed tile grid,
//! * **determinism** — for a fixed seed, results are bit-identical across
//!   shard counts (always asserted), and the in-memory result stays within
//!   the device error envelope of the exact banded matvec.
//!
//! The wall-clock scaling threshold (shards=4 at least 1.2x the
//! single-shard chunks/s) only asserts when `MELISO_BENCH_ASSERT=1`, like
//! `serving_throughput` — shared CI runners are load-noisy, so CI reports
//! the numbers (and uploads `BENCH_plane_scaling.json`) without flaking.
//!
//! Usage: `cargo bench --bench plane_scaling [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{registry, BandedSource, MatrixSource};
use meliso::prelude::*;
use meliso::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = BenchArgs::parse();
    // Quick mode shrinks the operand (same band profile) so CI smoke
    // stays fast; default/full run the registry's banded8k CI operand.
    let (name, source): (&str, Arc<dyn MatrixSource>) = if args.quick {
        (
            "banded2k",
            Arc::new(BandedSource::new(2048, 48, 4.0, 1.0e2, 0.2, 0x4D454C49 ^ 13)),
        )
    } else {
        ("banded8k", registry::build("banded8k").unwrap())
    };
    let n = source.nrows();
    let base = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
        .with_placement(Placement::SparsityAware)
        .with_ground_truth(false);
    let x = Vector::standard_normal(n, 7);

    println!("# plane scaling: {name} ({n}x{n}), streaming sparsity-aware dispatch\n");

    // --- shard sweep: occupied-chunk throughput at fixed geometry -------
    let system = SystemConfig::new(4, 4, 256);
    let mut shard_series = Vec::new();
    let mut results: Vec<(usize, Vector, f64)> = Vec::new();
    for shards in [1usize, 2, 4] {
        let solver = Meliso::with_backend(system, base.clone().with_workers(shards), backend());
        let t = Instant::now();
        let report = solver.solve_source(source.as_ref(), &x).unwrap();
        let wall = t.elapsed().as_secs_f64();
        let chunks = report.chunks_total - report.chunks_skipped;
        let cps = chunks as f64 / wall.max(1e-12);
        println!(
            "shards {shards}: {chunks} occupied chunks (of {}) in {wall:>7.3} s -> {cps:>8.1} chunks/s",
            report.chunks_total
        );
        let mut j = Json::obj();
        j.set("shards", Json::Num(shards as f64))
            .set("chunks_occupied", Json::Num(chunks as f64))
            .set("chunks_total", Json::Num(report.chunks_total as f64))
            .set("wall_s", Json::Num(wall))
            .set("chunks_per_s", Json::Num(cps));
        shard_series.push(j);
        results.push((shards, report.y, cps));
    }

    // Determinism across shard counts: always asserted (seed-stable).
    let deterministic = results.iter().all(|(_, y, _)| *y == results[0].1);
    println!("\ndeterminism: bit-identical y across shard counts: {deterministic}");

    // Accuracy anchor: the banded matvec reference is O(n·band) on the
    // host, so it stays cheap even where the dense O(n²) truth would not.
    let b = source.matvec(&x);
    let rel = results[0].1.sub(&b).norm_l2() / b.norm_l2();
    println!("rel l2 error vs banded reference: {rel:.4e}");

    // --- normalization-factor sweep (Fig 5 axis) ------------------------
    let mut norm_series = Vec::new();
    for cell in [128usize, 256, 512] {
        let solver = Meliso::with_backend(
            SystemConfig::new(4, 4, cell),
            base.clone().with_workers(4),
            backend(),
        );
        let t = Instant::now();
        let report = solver.solve_source(source.as_ref(), &x).unwrap();
        let wall = t.elapsed().as_secs_f64();
        let chunks = report.chunks_total - report.chunks_skipped;
        println!(
            "cell {cell:>4}: normalization {:>3}x, {chunks:>5} occupied chunks, {wall:>7.3} s",
            report.row_reassignments
        );
        let mut j = Json::obj();
        j.set("cell", Json::Num(cell as f64))
            .set(
                "normalization_factor",
                Json::Num(report.row_reassignments as f64),
            )
            .set("chunks_occupied", Json::Num(chunks as f64))
            .set("wall_s", Json::Num(wall));
        norm_series.push(j);
    }

    let speedup = results[2].2 / results[0].2.max(1e-12);
    println!("\nchunks/s scaling (4 shards vs 1): {speedup:.2}x   (target >= 1.2x)");

    let mut j = Json::obj();
    j.set("bench", Json::Str("plane_scaling".to_string()))
        .set("operand", Json::Str(name.to_string()))
        .set("n", Json::Num(n as f64))
        .set("shard_sweep", Json::Arr(shard_series))
        .set("normalization_sweep", Json::Arr(norm_series))
        .set("rel_err_l2_vs_reference", Json::Num(rel))
        .set("shard_scaling", Json::Num(speedup))
        .set("deterministic", Json::Bool(deterministic));
    args.write_result("BENCH_plane_scaling.json", &j.pretty());

    assert!(
        deterministic,
        "one-shot results must be bit-identical across shard counts"
    );
    assert!(rel < 0.1, "rel error {rel} vs banded reference");
    // Wall-clock scaling is load-sensitive on shared runners: hard-assert
    // only when explicitly requested.
    let hard_assert = std::env::var("MELISO_BENCH_ASSERT").as_deref() == Ok("1");
    if hard_assert {
        assert!(speedup >= 1.2, "chunks/s scaling {speedup:.2}x < 1.2x");
        println!("\nPASS: 4-shard plane is {speedup:.2}x the single-shard chunk throughput");
    } else {
        println!(
            "\nDONE (scaling threshold reported, not asserted — set MELISO_BENCH_ASSERT=1 to \
             enforce >= 1.2x)"
        );
    }
}
