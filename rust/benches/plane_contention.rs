//! Concurrent-admission benchmark: N clients hammering M resident
//! operands on ONE shared execution plane through clone-able
//! [`PlaneHandle`]s (`meliso::plane`).
//!
//! Quantifies what the shared-handle redesign exists for:
//!
//! * **chunks/s under contention** — 8 client threads × 4 resident
//!   operands, batches admitted through `&self` with no plane-wide lock,
//!   against a *serialized baseline* that funnels every batch through one
//!   admission mutex (the old `&mut self` surface);
//! * **p99 batch latency** — the tail cost of head-of-line blocking the
//!   serialized plane pays and the concurrent plane does not;
//! * **determinism** — 8 operand streams solved under 1-, 2- and 8-way
//!   client concurrency and every placement policy must produce
//!   bit-identical results (always asserted: execution noise is
//!   counter-based per `(operand, solve, chunk)`, so scheduling cannot
//!   leak into the numerics).
//!
//! The wall-clock contention threshold (concurrent admission at least
//! 2x the serialized chunks/s) only asserts when `MELISO_BENCH_ASSERT=1`,
//! like `plane_scaling` — shared single-core CI runners cannot express
//! admission parallelism, so CI reports the numbers (and uploads
//! `BENCH_plane_contention.json`) without flaking.
//!
//! Usage: `cargo bench --bench plane_contention [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::Instant;

const CLIENTS: usize = 8;
const OPERANDS: usize = 4;

fn dense_sources(count: usize, n: usize, seed: u64) -> Vec<Arc<dyn MatrixSource>> {
    (0..count)
        .map(|m| {
            Arc::new(DenseSource::new(Matrix::standard_normal(n, n, seed + m as u64)))
                as Arc<dyn MatrixSource>
        })
        .collect()
}

struct RunStats {
    wall_s: f64,
    chunks_per_s: f64,
    mean_ms: f64,
    p99_ms: f64,
}

impl RunStats {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("wall_s", Json::Num(self.wall_s))
            .set("chunks_per_s", Json::Num(self.chunks_per_s))
            .set("batch_mean_ms", Json::Num(self.mean_ms))
            .set("batch_p99_ms", Json::Num(self.p99_ms));
        j
    }
}

/// 8 clients (2 per operand) issue `batches` batches each against one
/// shared plane.  `serialize` funnels every admission through a single
/// mutex — the plane-wide lock the old `&mut self` surface forced.
fn contention_run(
    srcs: &[Arc<dyn MatrixSource>],
    config: &SystemConfig,
    opts: &SolveOptions,
    batches: usize,
    batch: usize,
    serialize: bool,
) -> RunStats {
    let plane = PlaneHandle::build(srcs[0].as_ref(), config, opts, backend()).unwrap();
    let residencies: Vec<(OperandId, usize)> = srcs
        .iter()
        .map(|s| {
            let (id, p) = plane.program(s.as_ref()).unwrap();
            (id, p.chunks_resident)
        })
        .collect();
    // Pre-generate every client's inputs so the timed region is admission
    // and execution only.
    let inputs: Vec<Vec<Vec<Vector>>> = (0..CLIENTS)
        .map(|c| {
            let n = srcs[c % OPERANDS].ncols();
            (0..batches)
                .map(|b| {
                    (0..batch)
                        .map(|v| {
                            let seed = ((c as u64) << 32) ^ (b * batch + v) as u64;
                            Vector::standard_normal(n, seed)
                        })
                        .collect()
                })
                .collect()
        })
        .collect();
    let admission = Mutex::new(());
    let t0 = Instant::now();
    let latencies: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let plane = plane.clone();
                let (id, _) = residencies[c % OPERANDS];
                let xs = &inputs[c];
                let admission = &admission;
                scope.spawn(move || {
                    let mut lats = Vec::with_capacity(batches);
                    for batch_xs in xs {
                        let t = Instant::now();
                        if serialize {
                            let _gate = admission.lock().unwrap();
                            plane.execute_batch(id, batch_xs).unwrap();
                        } else {
                            plane.execute_batch(id, batch_xs).unwrap();
                        }
                        lats.push(t.elapsed().as_secs_f64());
                    }
                    lats
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let chunks: usize = (0..CLIENTS)
        .map(|c| residencies[c % OPERANDS].1 * batches)
        .sum();
    let mut sorted = latencies.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize];
    RunStats {
        wall_s,
        chunks_per_s: chunks as f64 / wall_s.max(1e-12),
        mean_ms: latencies.iter().sum::<f64>() / latencies.len() as f64 * 1e3,
        p99_ms: pct(0.99) * 1e3,
    }
}

/// Solve 8 operand streams (each stream served in-order by exactly one
/// thread) split across `threads` concurrent clients, and return every
/// result's raw bits, per stream per solve.
fn det_run(
    srcs: &[Arc<dyn MatrixSource>],
    config: &SystemConfig,
    opts: &SolveOptions,
    solves: usize,
    threads: usize,
) -> Vec<Vec<Vec<u64>>> {
    let plane = PlaneHandle::build(srcs[0].as_ref(), config, opts, backend()).unwrap();
    let ids: Vec<OperandId> = srcs
        .iter()
        .map(|s| plane.program(s.as_ref()).unwrap().0)
        .collect();
    let streams = srcs.len();
    let per_thread = streams.div_ceil(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let plane = plane.clone();
                let ids = &ids;
                let srcs = srcs;
                scope.spawn(move || {
                    let lo = t * per_thread;
                    let hi = ((t + 1) * per_thread).min(streams);
                    // Round-robin the thread's streams so concurrent
                    // threads interleave operands as much as possible.
                    let mut out: Vec<(usize, Vec<Vec<u64>>)> =
                        (lo..hi).map(|s| (s, Vec::new())).collect();
                    for k in 0..solves {
                        for (s, ys) in out.iter_mut() {
                            let x = Vector::standard_normal(
                                srcs[*s].ncols(),
                                0xDE7 + (*s as u64) * 131 + k as u64,
                            );
                            let batch = plane
                                .execute_batch(ids[*s], std::slice::from_ref(&x))
                                .unwrap();
                            ys.push(
                                batch.solves[0].y.data().iter().map(|v| v.to_bits()).collect(),
                            );
                        }
                    }
                    out
                })
            })
            .collect();
        let mut all: Vec<Vec<Vec<u64>>> = vec![Vec::new(); streams];
        for h in handles {
            for (s, ys) in h.join().expect("det thread") {
                all[s] = ys;
            }
        }
        all
    })
}

fn main() {
    let args = BenchArgs::parse();
    let (n, batches, batch, det_solves) = if args.quick {
        (64, 6, 2, 2)
    } else {
        (128, 16, 4, 3)
    };
    let config = SystemConfig::new(2, 2, 32);
    let opts = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
        .with_workers(4)
        .with_ground_truth(false);

    println!(
        "# plane contention: {CLIENTS} clients x {OPERANDS} operands ({n}x{n}) on one shared \
         plane, batch {batch}, {batches} batches/client\n"
    );

    // --- serialized baseline vs concurrent admission --------------------
    let srcs = dense_sources(OPERANDS, n, 0xC0);
    let serialized = contention_run(&srcs, &config, &opts, batches, batch, true);
    println!(
        "serialized admission: {:>8.1} chunks/s, batch mean {:>7.2} ms, p99 {:>7.2} ms  ({:.3} s)",
        serialized.chunks_per_s, serialized.mean_ms, serialized.p99_ms, serialized.wall_s
    );
    let concurrent = contention_run(&srcs, &config, &opts, batches, batch, false);
    println!(
        "concurrent admission: {:>8.1} chunks/s, batch mean {:>7.2} ms, p99 {:>7.2} ms  ({:.3} s)",
        concurrent.chunks_per_s, concurrent.mean_ms, concurrent.p99_ms, concurrent.wall_s
    );
    let speedup = concurrent.chunks_per_s / serialized.chunks_per_s.max(1e-12);
    println!("\nchunks/s vs serialized baseline: {speedup:.2}x   (target >= 2x)");

    // --- determinism: 1/2/8-way concurrency x every placement -----------
    let det_srcs = dense_sources(CLIENTS, 48, 0xD0);
    let placements = [
        Placement::RoundRobin,
        Placement::LoadBalanced,
        Placement::SparsityAware,
        Placement::TimingAware,
    ];
    let reference = det_run(
        &det_srcs,
        &config,
        &opts.clone().with_placement(Placement::RoundRobin),
        det_solves,
        1,
    );
    let mut deterministic = true;
    for threads in [1usize, 2, 8] {
        for placement in placements {
            let got = det_run(
                &det_srcs,
                &config,
                &opts.clone().with_placement(placement),
                det_solves,
                threads,
            );
            let ok = got == reference;
            deterministic &= ok;
            println!(
                "determinism: {threads}-way, {:<15} bit-identical: {ok}",
                placement.name()
            );
        }
    }

    let mut j = Json::obj();
    j.set("bench", Json::Str("plane_contention".to_string()))
        .set("clients", Json::Num(CLIENTS as f64))
        .set("operands", Json::Num(OPERANDS as f64))
        .set("n", Json::Num(n as f64))
        .set("batch", Json::Num(batch as f64))
        .set("batches_per_client", Json::Num(batches as f64))
        .set("serialized", serialized.to_json())
        .set("concurrent", concurrent.to_json())
        .set("speedup_chunks_per_s", Json::Num(speedup))
        .set("deterministic", Json::Bool(deterministic));
    args.write_result("BENCH_plane_contention.json", &j.pretty());

    assert!(
        deterministic,
        "results must be bit-identical across concurrency levels and placements"
    );
    // Admission parallelism is invisible on single-core shared runners:
    // hard-assert only when explicitly requested.
    let hard_assert = std::env::var("MELISO_BENCH_ASSERT").as_deref() == Ok("1");
    if hard_assert {
        assert!(
            speedup >= 2.0,
            "concurrent admission {speedup:.2}x < 2x serialized baseline"
        );
        println!("\nPASS: concurrent admission is {speedup:.2}x the serialized baseline");
    } else {
        println!(
            "\nDONE (contention threshold reported, not asserted — set MELISO_BENCH_ASSERT=1 \
             to enforce >= 2x)"
        );
    }
}
