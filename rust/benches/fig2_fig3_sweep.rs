//! Regenerates **Figures 2, 3, S1, S2**: the effect of the
//! `adjustableWriteandVerify` iteration count k on relative error norms,
//! write energy and write latency — without (Fig 2/S1) and with (Fig 3/S2)
//! the two-tier error correction, on Iperturb (Fig 2/3) and bcsstk02
//! (Fig S1/S2).
//!
//! Usage: `cargo bench --bench fig2_fig3_sweep [-- --fig 2|3|s1|s2 --reps N]`
//! (no `--fig` runs all four).  Series go to stdout and CSVs under
//! `bench_results/`.

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;

struct FigSpec {
    name: &'static str,
    matrix: &'static str,
    ec: bool,
}

const FIGS: &[FigSpec] = &[
    FigSpec { name: "fig2", matrix: "iperturb66", ec: false },
    FigSpec { name: "fig3", matrix: "iperturb66", ec: true },
    FigSpec { name: "figs1", matrix: "bcsstk02", ec: false },
    FigSpec { name: "figs2", matrix: "bcsstk02", ec: true },
];

fn main() {
    let args = BenchArgs::parse();
    let reps = args.reps_or(2, 3, 100);
    let which = args
        .rest
        .iter()
        .position(|a| a == "--fig")
        .and_then(|i| args.rest.get(i + 1))
        .map(|s| format!("fig{}", s.trim_start_matches("fig")));

    // The paper sweeps k = 0..20; default keeps a representative subset so
    // the bench completes quickly (use --full + --fig for the exact sweep).
    let ks: Vec<usize> = if args.full {
        (0..=20).collect()
    } else if args.quick {
        vec![0, 2, 5, 11]
    } else {
        vec![0, 1, 2, 3, 5, 8, 11, 15, 20]
    };

    let backend = backend();
    for fig in FIGS {
        if let Some(w) = &which {
            if w != fig.name {
                continue;
            }
        }
        println!(
            "\n# {} — adjustableWriteandVerify sweep on {} ({}, {reps} reps)",
            fig.name,
            fig.matrix,
            if fig.ec { "with EC" } else { "no EC" },
        );
        let source = registry::build(fig.matrix).unwrap();
        let x = Vector::standard_normal(source.ncols(), 0x5eed);
        let mut csv = String::from("k,device,eps_l2,eps_inf,ew_j,lw_s\n");
        println!(
            "{:>3}  {:<10} {:>12} {:>12} {:>12} {:>12}",
            "k", "device", "eps_l2", "eps_inf", "E_w(J)", "L_w(s)"
        );
        for &k in &ks {
            for material in Material::ALL {
                let opts = SolveOptions::default()
                    .with_device(material)
                    .with_ec(fig.ec)
                    .with_wv_iters(k);
                let solver =
                    Meliso::with_backend(SystemConfig::single_mca(128), opts, backend.clone());
                let reports = solver.replicate(source.as_ref(), &x, reps).unwrap();
                let s = ReplicationSummary::from_reports(&reports);
                println!(
                    "{k:>3}  {:<10} {:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e}",
                    material.name(),
                    s.rel_err_l2,
                    s.rel_err_inf,
                    s.ew_mean,
                    s.lw_mean
                );
                csv.push_str(&format!(
                    "{k},{},{:.6e},{:.6e},{:.6e},{:.6e}\n",
                    material.name(),
                    s.rel_err_l2,
                    s.rel_err_inf,
                    s.ew_mean,
                    s.lw_mean
                ));
            }
        }
        args.write_result(&format!("{}.csv", fig.name), &csv);
    }
}
