//! Observability overhead budget: the `obs` layer promises to be
//! zero-cost when disabled and cheap when armed.
//!
//! The disabled fast path is one relaxed atomic load per instrumentation
//! site (`obs::metrics_on()` / `obs::span_start()`), so this bench
//! measures that check directly, scales it by a deliberately generous
//! per-solve site count, and **asserts** the product stays under 2% of a
//! representative distributed solve's wall clock.  The armed levels
//! (metrics, trace) are reported informationally — they buy data with
//! time, which is fine, but the disabled budget is a hard contract.
//!
//! Usage: `cargo bench --bench obs_overhead [-- --quick]`

use meliso::bench::{BenchArgs, BenchRunner};
use meliso::matrices::DenseSource;
use meliso::obs::{self, ObsLevel};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

/// Generous upper bound on instrumentation checks one plane solve can
/// hit: every stage of every chunk re-checking the level a handful of
/// times, padded by an order of magnitude.
const CHECKS_PER_SOLVE: f64 = 4096.0;

/// Hard ceiling on the estimated disabled-path share of solve wall.
const DISABLED_BUDGET: f64 = 0.02;

fn main() {
    let args = BenchArgs::parse();
    let runner = if args.quick {
        BenchRunner { warmup_iters: 1, sample_iters: 3 }
    } else {
        BenchRunner::default()
    };
    println!(
        "# observability overhead (disabled-path budget {:.0}%)\n",
        DISABLED_BUDGET * 100.0
    );

    obs::set_level(ObsLevel::Off);
    let src = DenseSource::new(Matrix::standard_normal(128, 128, 9));
    let x = Vector::standard_normal(128, 10);
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_workers(2)
        .with_wv_iters(1);
    let solver = Meliso::with_backend(
        SystemConfig::new(2, 2, 64),
        opts,
        Arc::new(NativeBackend::new()),
    );

    let off = runner.run("solve/obs-off", || {
        let _ = solver.solve_source(&src, &x).unwrap();
    });
    println!("{}", off.throughput_line(1.0, "solve"));

    // The disabled fast path, measured directly.
    let checks = 10_000_000u64;
    let t0 = Instant::now();
    let mut armed = 0u64;
    for _ in 0..checks {
        if black_box(obs::metrics_on()) {
            armed += 1;
        }
    }
    let per_check_s = t0.elapsed().as_secs_f64() / checks as f64;
    assert_eq!(armed, 0, "level should be Off during the check bench");

    let overhead = per_check_s * CHECKS_PER_SOLVE / off.mean_s.max(1e-12);
    println!(
        "disabled check: {:.2} ns/site; {:.0} sites/solve -> {:.4}% of solve wall",
        per_check_s * 1e9,
        CHECKS_PER_SOLVE,
        overhead * 100.0
    );
    assert!(
        overhead < DISABLED_BUDGET,
        "disabled-path observability overhead {:.3}% exceeds the {:.0}% budget",
        overhead * 100.0,
        DISABLED_BUDGET * 100.0
    );

    // Armed levels, informational: what metrics/trace collection costs.
    obs::set_level(ObsLevel::Metrics);
    let metrics = runner.run("solve/obs-metrics", || {
        let _ = solver.solve_source(&src, &x).unwrap();
    });
    println!("{}", metrics.throughput_line(1.0, "solve"));

    obs::set_level(ObsLevel::Trace);
    obs::recorder().clear();
    let trace = runner.run("solve/obs-trace", || {
        let _ = solver.solve_source(&src, &x).unwrap();
    });
    println!("{}", trace.throughput_line(1.0, "solve"));
    let (events, dropped) = obs::recorder().snapshot();
    obs::set_level(ObsLevel::Off);

    println!(
        "\narmed deltas vs off: metrics {:+.2}%, trace {:+.2}% ({} spans retained, {} dropped)",
        (metrics.mean_s / off.mean_s.max(1e-12) - 1.0) * 100.0,
        (trace.mean_s / off.mean_s.max(1e-12) - 1.0) * 100.0,
        events.len(),
        dropped
    );
}
