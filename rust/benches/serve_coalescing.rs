//! Serving-front-door coalescing benchmark: the cross-client gather
//! window (`meliso::serve::coalesce`) against the per-request baseline.
//!
//! Quantifies what the coalescer exists for:
//!
//! * **chunks/s coalesced vs per-request** — N solve requests against one
//!   resident operand, folded into `max_batch`-sized windows and executed
//!   as single `solve_batch` chunk walks, against the same N requests
//!   issued one `solve` at a time (each paying its own plane round
//!   trip);
//! * **bit-identity** (always asserted): the coalesced arm must produce
//!   exactly the per-request arm's bytes, solve index by solve index —
//!   execution noise is counter-based, so folding requests into one
//!   window may never change the numerics.
//!
//! The wall-clock threshold (coalesced at least 2x the per-request
//! chunks/s) only asserts when `MELISO_BENCH_ASSERT=1`, like
//! `plane_contention` — shared CI runners report the numbers (and upload
//! `BENCH_serve_coalescing.json`) without flaking.
//!
//! Usage: `cargo bench --bench serve_coalescing [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::serve::coalesce::{await_reply, Coalescer, SolveRequest};
use meliso::server::fingerprint;
use meliso::util::json::Json;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct RunStats {
    wall_s: f64,
    chunks_per_s: f64,
    /// Raw result bits per solve, in solve-index order.
    bits: Vec<Vec<u64>>,
}

impl RunStats {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("wall_s", Json::Num(self.wall_s))
            .set("chunks_per_s", Json::Num(self.chunks_per_s));
        j
    }
}

/// Per-request baseline: every solve pays its own plane round trip.
fn per_request_run(solver: &Meliso, src: &Arc<dyn MatrixSource>, xs: &[Vector]) -> RunStats {
    let session = solver.open_session(src.clone()).unwrap();
    let chunks = session.program_report().chunks_resident;
    let t0 = Instant::now();
    let bits: Vec<Vec<u64>> = xs
        .iter()
        .map(|x| {
            let out = session.solve(x).unwrap();
            out.y.data().iter().map(|v| v.to_bits()).collect()
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    RunStats {
        wall_s,
        chunks_per_s: (chunks * xs.len()) as f64 / wall_s.max(1e-12),
        bits,
    }
}

/// Coalesced arm: the same requests submitted through the gather window
/// in `batch`-sized bursts, each burst folding into one `solve_batch`.
/// `max_batch == batch` closes every window as soon as the burst is in,
/// so the measured wall clock is execution, not idle window time.
fn coalesced_run(
    solver: &Meliso,
    src: &Arc<dyn MatrixSource>,
    xs: &[Vector],
    batch: usize,
) -> RunStats {
    let session = Arc::new(solver.open_session(src.clone()).unwrap());
    let chunks = session.program_report().chunks_resident;
    let fp = fingerprint(src.as_ref());
    let coalescer = Coalescer::start(Duration::from_millis(50), batch, xs.len().max(1));
    let t0 = Instant::now();
    let mut bits: Vec<Vec<u64>> = Vec::with_capacity(xs.len());
    for burst in xs.chunks(batch) {
        let replies: Vec<mpsc::Receiver<_>> = burst
            .iter()
            .map(|x| {
                let (tx, rx) = mpsc::sync_channel(1);
                coalescer
                    .submit(SolveRequest {
                        fp,
                        session: session.clone(),
                        x: x.clone(),
                        reply: tx,
                    })
                    .unwrap();
                rx
            })
            .collect();
        for rx in &replies {
            let out = await_reply(rx, Duration::from_secs(600)).unwrap();
            bits.push(out.y.data().iter().map(|v| v.to_bits()).collect());
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    coalescer.shutdown();
    RunStats {
        wall_s,
        chunks_per_s: (chunks * xs.len()) as f64 / wall_s.max(1e-12),
        bits,
    }
}

fn main() {
    let args = BenchArgs::parse();
    let (n, requests, batch) = if args.quick { (64, 32, 16) } else { (128, 64, 16) };
    let config = SystemConfig::new(2, 2, 32);
    let opts = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
        .with_workers(4)
        .with_ground_truth(false);
    let solver = Meliso::with_backend(config, opts, backend());
    let src: Arc<dyn MatrixSource> =
        Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 0x5E)));
    let xs: Vec<Vector> = (0..requests)
        .map(|k| Vector::standard_normal(n, 0xC0A1 + k as u64))
        .collect();

    println!(
        "# serve coalescing: {requests} solve requests against one resident {n}x{n} operand, \
         window batch {batch}\n"
    );

    let per_request = per_request_run(&solver, &src, &xs);
    println!(
        "per-request: {:>10.1} chunks/s  ({:.3} s)",
        per_request.chunks_per_s, per_request.wall_s
    );
    let coalesced = coalesced_run(&solver, &src, &xs, batch);
    println!(
        "coalesced:   {:>10.1} chunks/s  ({:.3} s)",
        coalesced.chunks_per_s, coalesced.wall_s
    );
    let speedup = coalesced.chunks_per_s / per_request.chunks_per_s.max(1e-12);
    println!("\nchunks/s vs per-request baseline: {speedup:.2}x   (target >= 2x)");

    let bit_identical = coalesced.bits == per_request.bits;
    println!("bit-identical to per-request solves: {bit_identical}");

    let mut j = Json::obj();
    j.set("bench", Json::Str("serve_coalescing".to_string()))
        .set("n", Json::Num(n as f64))
        .set("requests", Json::Num(requests as f64))
        .set("batch", Json::Num(batch as f64))
        .set("per_request", per_request.to_json())
        .set("coalesced", coalesced.to_json())
        .set("speedup_chunks_per_s", Json::Num(speedup))
        .set("bit_identical", Json::Bool(bit_identical));
    args.write_result("BENCH_serve_coalescing.json", &j.pretty());

    assert!(
        bit_identical,
        "coalesced windows must be bit-identical to per-request solves"
    );
    // Batch amortization can be muted on single-core shared runners:
    // hard-assert only when explicitly requested.
    let hard_assert = std::env::var("MELISO_BENCH_ASSERT").as_deref() == Ok("1");
    if hard_assert {
        assert!(
            speedup >= 2.0,
            "coalesced serving {speedup:.2}x < 2x the per-request baseline"
        );
        println!("\nPASS: coalesced serving is {speedup:.2}x the per-request baseline");
    } else {
        println!(
            "\nDONE (coalescing threshold reported, not asserted — set MELISO_BENCH_ASSERT=1 \
             to enforce >= 2x)"
        );
    }
}
