//! Regenerates **Table 1**: device performance for MVM with and without the
//! two-tier error correction, on M1 (bcsstk02, κ≈4325) and M2 (Iperturb,
//! κ≈1.23), averaged over replications.
//!
//! Usage: `cargo bench --bench table1 [-- --reps N | --quick | --full]`
//! (`--full` = the paper's 100 replications).

use meliso::bench::{backend, BenchArgs, BenchRunner};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::metrics::table::TableBuilder;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;
use meliso::util::sci;

fn main() {
    let args = BenchArgs::parse();
    let reps = args.reps_or(3, 10, 100);
    let backend = backend();
    // EC columns use the converged write–verify protocol (supplementary:
    // "k=5 is sufficient for optimal performance").
    let ec_k = 5;

    println!("# Table 1 — MVM with/without error correction ({reps} reps)\n");
    let mut csv = String::from("matrix,device,ec,eps_l2,eps_inf,ew_j,lw_s\n");

    for (label, matrix) in [("M1 (bcsstk02)", "bcsstk02"), ("M2 (Iperturb)", "iperturb66")] {
        let source = registry::build(matrix).unwrap();
        let x = Vector::standard_normal(source.ncols(), 0x5eed);
        let mut t = TableBuilder::new(
            &format!("{label}, {reps} replications"),
            &["eps_l2", "eps_inf", "E_w (J)", "L_w (s)"],
        );
        for ec in [false, true] {
            for material in Material::ALL {
                // The paper benchmarks EpiRAM only without EC (it is the
                // high-accuracy reference device).
                if ec && material == Material::EpiRam {
                    continue;
                }
                let opts = SolveOptions::default()
                    .with_device(material)
                    .with_ec(ec)
                    .with_wv_iters(if ec { ec_k } else { 0 });
                let solver =
                    Meliso::with_backend(SystemConfig::single_mca(128), opts, backend.clone());
                let reports = solver.replicate(source.as_ref(), &x, reps).unwrap();
                let s = ReplicationSummary::from_reports(&reports);
                let row = format!(
                    "{} {}",
                    material.name(),
                    if ec { "[EC]" } else { "     " }
                );
                t.row(
                    &row,
                    vec![
                        sci(s.rel_err_l2),
                        sci(s.rel_err_inf),
                        sci(s.ew_mean),
                        sci(s.lw_mean),
                    ],
                );
                csv.push_str(&format!(
                    "{matrix},{},{},{:.6},{:.6},{:.6e},{:.6e}\n",
                    material.name(),
                    ec,
                    s.rel_err_l2,
                    s.rel_err_inf,
                    s.ew_mean,
                    s.lw_mean
                ));
            }
        }
        print!("{}", t.render());
        println!();
    }
    args.write_result("table1.csv", &csv);

    // Timing of the end-to-end Table 1 cell (criterion-style stats).
    let source = registry::build("bcsstk02").unwrap();
    let x = Vector::standard_normal(66, 1);
    let solver = Meliso::with_backend(
        SystemConfig::single_mca(128),
        SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_wv_iters(ec_k),
        backend,
    );
    let stats = BenchRunner::quick().run("table1/taox_ec_solve_66", || {
        let _ = solver.solve_source(source.as_ref(), &x).unwrap();
    });
    println!("{}", stats.throughput_line(1.0, "solve"));
}
