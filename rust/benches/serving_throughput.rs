//! Serving-path benchmark: resident crossbar sessions vs repeated
//! one-shot solves.
//!
//! Quantifies the program-once / solve-many amortization that the serving
//! subsystem (`meliso::server`) exists for:
//!
//! * **wall-clock** — one-shot re-runs `adjustableWriteandVerify` for the
//!   operand (and the denoiser) on every call; a resident session pays
//!   only the input-vector encode and the crossbar reads,
//! * **write energy** — the matrix write (n² cells) is paid once; each
//!   served solve writes only vector-scale cell counts,
//! * **determinism** — for a fixed seed, a batch of N vectors is
//!   bit-identical to N sequential solves on an identically-programmed
//!   session (counter-based execution streams).
//!
//! The determinism check always asserts (it is seed-stable).  The hard
//! wall-clock and write-energy thresholds (both >= 10x) only assert when
//! `MELISO_BENCH_ASSERT=1` — on shared CI runners the wall-clock side is
//! load-dependent, so CI runs report the numbers (and uploads
//! `BENCH_serving_throughput.json`) without spuriously failing the job.
//!
//! Usage: `cargo bench --bench serving_throughput [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::util::json::Json;
use std::time::Instant;

fn main() {
    let args = BenchArgs::parse();
    let solves = args.reps_or(8, 24, 96);
    let batch = 4usize;

    let source = registry::build("iperturb66").unwrap();
    let n = source.ncols();
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_wv_iters(2)
        .with_workers(2)
        .with_seed(42);
    let solver = Meliso::with_backend(SystemConfig::single_mca(128), opts, backend());
    let xs: Vec<Vector> = (0..solves)
        .map(|i| Vector::standard_normal(n, 1000 + i as u64))
        .collect();

    println!("# serving throughput: resident session vs one-shot ({solves} solves)\n");

    // --- one-shot reference: every solve re-programs the operand -------
    let t0 = Instant::now();
    let mut oneshot_write_j = 0.0;
    for x in &xs {
        let r = solver.solve_source(source.as_ref(), x).unwrap();
        oneshot_write_j += r.ew_total;
    }
    let oneshot_s = t0.elapsed().as_secs_f64() / solves as f64;
    let oneshot_j = oneshot_write_j / solves as f64;
    println!(
        "one-shot : {:>9.3} ms/solve   write {:.3e} J/solve",
        oneshot_s * 1e3,
        oneshot_j
    );

    // --- resident session: program once, then serve --------------------
    let t1 = Instant::now();
    let session = solver.open_session(source.clone()).unwrap();
    let program_s = t1.elapsed().as_secs_f64();
    let t2 = Instant::now();
    for chunk in xs.chunks(batch) {
        session.solve_batch(chunk).unwrap();
    }
    let resident_s = t2.elapsed().as_secs_f64() / solves as f64;
    let report = session.report();
    let resident_j = report.write_energy_per_solve_j;
    println!(
        "resident : {:>9.3} ms/solve   write {:.3e} J/solve   (program once: {:.3} s, {:.3e} J)",
        resident_s * 1e3,
        resident_j,
        program_s,
        session.program_report().write_energy_j
    );
    println!(
        "           p50 {:.3} ms, p99 {:.3} ms, {:.1} solves/s, write amortization {:.0}x",
        report.latency_p50_ms,
        report.latency_p99_ms,
        report.throughput_sps,
        report.write_amortization
    );

    // --- determinism: batch == sequential, bit for bit ------------------
    let k = solves.min(4);
    let session_seq = solver.open_session(source.clone()).unwrap();
    let seq: Vec<Vector> = xs[..k]
        .iter()
        .map(|x| session_seq.solve(x).unwrap().y)
        .collect();
    let session_batch = solver.open_session(source.clone()).unwrap();
    let bat: Vec<Vector> = session_batch
        .solve_batch(&xs[..k])
        .unwrap()
        .into_iter()
        .map(|r| r.y)
        .collect();
    let identical = seq == bat;
    println!(
        "\ndeterminism: batch-of-{k} vs {k} sequential solves bit-identical: {identical}"
    );

    let speedup = oneshot_s / resident_s.max(1e-12);
    let energy_ratio = oneshot_j / resident_j.max(f64::MIN_POSITIVE);
    println!("wall speedup       : {speedup:.1}x   (target >= 10x)");
    println!("write energy ratio : {energy_ratio:.1}x   (target >= 10x)");

    let mut j = Json::obj();
    j.set("bench", Json::Str("serving_throughput".to_string()))
        .set("solves", Json::Num(solves as f64))
        .set("oneshot_per_solve_s", Json::Num(oneshot_s))
        .set("oneshot_write_j_per_solve", Json::Num(oneshot_j))
        .set("resident_per_solve_s", Json::Num(resident_s))
        .set("resident_write_j_per_solve", Json::Num(resident_j))
        .set("program_wall_s", Json::Num(program_s))
        .set("wall_speedup", Json::Num(speedup))
        .set("write_energy_ratio", Json::Num(energy_ratio))
        .set("batch_bit_identical", Json::Bool(identical))
        .set("serving", report.to_json());
    args.write_result("BENCH_serving_throughput.json", &j.pretty());

    assert!(
        identical,
        "batched and sequential resident solves must be bit-identical"
    );
    // The wall-clock and amortization thresholds are load-sensitive on
    // shared runners: hard-assert only when explicitly requested.
    let hard_assert = std::env::var("MELISO_BENCH_ASSERT").as_deref() == Ok("1");
    if hard_assert {
        assert!(speedup >= 10.0, "wall speedup {speedup:.1}x < 10x");
        assert!(
            energy_ratio >= 10.0,
            "write-energy ratio {energy_ratio:.1}x < 10x"
        );
        println!(
            "\nPASS: resident serving is {speedup:.1}x faster and {energy_ratio:.1}x cheaper in \
             write energy per solve"
        );
    } else {
        println!(
            "\nDONE (thresholds reported, not asserted — set MELISO_BENCH_ASSERT=1 to enforce \
             >= 10x)"
        );
    }
}
