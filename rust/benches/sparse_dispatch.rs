//! Sparse dispatch benchmark: does sparsity-aware planning and placement
//! earn its keep on *irregular* (non-banded) structure?
//!
//! For a banded operand and three irregular CSR patterns (arrowhead,
//! power-law, block-diagonal) at the same dimension, this bench records:
//!
//! * **planned vs total chunks** — how many grid chunks
//!   `ChunkPlan::nonzero_chunks` actually dispatches (always asserted
//!   `<` total for every operand, banded *and* irregular: the acceptance
//!   point of serving real sparsity),
//! * **per-policy shard load** — the max occupied-chunk load any one
//!   shard carries under round-robin / load-balanced / sparsity-aware
//!   placement (deterministic, so the LPT advantage is asserted, not just
//!   reported: sparsity-aware max load ≤ round-robin max load on the
//!   skewed patterns, arrowhead and power-law),
//! * **chunks/s** — one-shot wall-clock throughput per policy
//!   (reporting-only: shared runners are load-noisy),
//! * **determinism** — bit-identical `y` across all three placement
//!   policies for a fixed seed (always asserted).
//!
//! Emits `BENCH_sparse_dispatch.json` under `bench_results/`.
//!
//! Usage: `cargo bench --bench sparse_dispatch [-- --quick]`

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::{generators, BandedSource, MatrixSource};
use meliso::prelude::*;
use meliso::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = BenchArgs::parse();
    let n: usize = if args.quick { 1024 } else { 4096 };
    let cell: usize = if args.quick { 64 } else { 128 };
    let shards = 4usize;
    let seed = 0x4D454C49u64;
    let system = SystemConfig::new(4, 4, cell);
    let base = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
        .with_workers(shards)
        .with_ground_truth(false);
    let x = Vector::standard_normal(n, 7);

    // (name, irregular?, source): one banded control + three irregular
    // patterns, all ~the same conditioning so wall clocks are comparable.
    let operands: Vec<(&str, bool, Arc<dyn MatrixSource>)> = vec![
        (
            "banded",
            false,
            Arc::new(BandedSource::new(n, 24, 4.0, 1.0e2, 0.2, seed ^ 21)),
        ),
        (
            "arrowhead",
            true,
            Arc::new(generators::arrowhead_csr(n, 4.0, 1.0e2, 0.2, seed ^ 22)),
        ),
        (
            "powerlaw",
            true,
            Arc::new(generators::power_law_csr(n, 3, 4.0, 1.0e2, 0.2, seed ^ 23)),
        ),
        (
            "blockdiag",
            true,
            Arc::new(generators::block_diag_csr(n, 64, 4.0, 1.0e2, 0.2, seed ^ 24)),
        ),
    ];
    let placements = [
        Placement::RoundRobin,
        Placement::LoadBalanced,
        Placement::SparsityAware,
    ];

    println!("# sparse dispatch: {n}x{n} operands on 4x4 tiles of {cell}², {shards} shards\n");

    let mut op_series = Vec::new();
    for (name, irregular, src) in &operands {
        let plan = meliso::virtualization::ChunkPlan::new(system.geometry(), n, n);
        let total = plan.total_chunks();
        let mut occupied = vec![0usize; plan.geometry.mcas()];
        for spec in plan.nonzero_chunks(src.as_ref()) {
            occupied[spec.mca_index] += 1;
        }
        let planned: usize = occupied.iter().sum();

        println!(
            "{name}: {planned} planned of {total} chunks ({:.1}% occupied)",
            100.0 * planned as f64 / total as f64
        );
        assert!(
            planned < total,
            "{name}: planning must skip empty chunks ({planned} of {total})"
        );

        let mut max_loads = std::collections::BTreeMap::new();
        let mut results: Vec<Vector> = Vec::new();
        let mut policy_series = Vec::new();
        for placement in placements {
            // Deterministic load metric: occupied chunks per shard under
            // this policy's MCA->shard assignment.
            let assign = placement.policy().assign(&plan, src.as_ref(), shards);
            let mut loads = vec![0usize; shards];
            for (mca, &s) in assign.iter().enumerate() {
                loads[s] += occupied[mca];
            }
            let max_load = *loads.iter().max().unwrap();
            max_loads.insert(placement.name(), max_load);

            let solver =
                Meliso::with_backend(system, base.clone().with_placement(placement), backend());
            let t = Instant::now();
            let report = solver.solve_source(src.as_ref(), &x).unwrap();
            let wall = t.elapsed().as_secs_f64();
            // The plane dispatched exactly the planned chunk set.
            assert_eq!(
                planned,
                report.chunks_total - report.chunks_skipped,
                "{name}/{}: dispatched chunks != planned",
                placement.name()
            );
            let cps = planned as f64 / wall.max(1e-12);
            println!(
                "  {:<16} max shard load {max_load:>4} (ideal {:>4})  {wall:>7.3} s  {cps:>9.1} chunks/s",
                placement.name(),
                planned.div_ceil(shards),
            );
            let mut j = Json::obj();
            j.set("placement", Json::Str(placement.name().to_string()))
                .set("max_shard_load", Json::Num(max_load as f64))
                .set("wall_s", Json::Num(wall))
                .set("chunks_per_s", Json::Num(cps));
            policy_series.push(j);
            results.push(report.y);
        }

        // Bit-identical across placement policies (always asserted).
        let deterministic = results.iter().all(|y| *y == results[0]);
        assert!(deterministic, "{name}: placement policy changed the result");

        // On skewed irregular structure the LPT policy earns its keep:
        // its max occupied-chunk shard load must not exceed round-robin's.
        // (Near-uniform distributions — the banded control, block-diagonal
        // — can tie either way by a chunk, so those only report.)
        let rr = max_loads["round-robin"];
        let sa = max_loads["sparsity-aware"];
        println!("  -> sparsity-aware max load {sa} vs round-robin {rr}");
        if matches!(*name, "arrowhead" | "powerlaw") {
            assert!(sa <= rr, "{name}: sparsity-aware max load {sa} > round-robin {rr}");
        }

        let mut j = Json::obj();
        j.set("operand", Json::Str(name.to_string()))
            .set("irregular", Json::Bool(*irregular))
            .set("chunks_total", Json::Num(total as f64))
            .set("chunks_planned", Json::Num(planned as f64))
            .set("policies", Json::Arr(policy_series))
            .set("deterministic", Json::Bool(deterministic));
        op_series.push(j);
        println!();
    }

    let mut j = Json::obj();
    j.set("bench", Json::Str("sparse_dispatch".to_string()))
        .set("n", Json::Num(n as f64))
        .set("cell", Json::Num(cell as f64))
        .set("shards", Json::Num(shards as f64))
        .set("operands", Json::Arr(op_series));
    args.write_result("BENCH_sparse_dispatch.json", &j.pretty());

    println!("PASS: planned < total on every operand, bit-identical across placements");
}
