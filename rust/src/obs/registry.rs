//! Process-wide metrics registry: counters, gauges and fixed-bucket
//! histograms with static label sets.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`s onto
//! lock-free atomic cells: registration (`counter()`/`gauge()`/
//! `histogram()`) takes the registry mutex once, after which recording is
//! pure atomics.  Hot paths cache their handles (see
//! `plane/shard.rs`); cold paths just re-register — get-or-create is
//! idempotent and returns a handle onto the same cell.
//!
//! Values are `f64` stored as bits in an [`AtomicU64`] (Prometheus
//! counters are floats; seconds and joules need fractions).  Counter adds
//! use a CAS loop, which only ever runs when observability is enabled.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default latency buckets (seconds): 10 µs → 10 s, roughly log-spaced.
pub const LATENCY_BUCKETS: &[f64] = &[
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25,
    0.5, 1.0, 2.5, 5.0, 10.0,
];

fn f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Monotone float counter.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1.0);
    }

    /// Add `v` (callers keep counters monotone: `v >= 0`).
    pub fn add(&self, v: f64) {
        f64_add(&self.cell, v);
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }
}

/// Last-write-wins float gauge.
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.cell.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }
}

struct HistogramCore {
    /// Upper bounds of the finite buckets (ascending); an implicit `+Inf`
    /// bucket follows.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) observation counts; `len() == bounds.len() + 1`.
    buckets: Vec<AtomicU64>,
    /// Sum of observations (f64 bits).
    sum: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket histogram.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

impl Histogram {
    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let idx = self
            .core
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.core.bounds.len());
        self.core.buckets[idx].fetch_add(1, Ordering::Relaxed);
        f64_add(&self.core.sum, v);
        self.core.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }
}

/// Metric family kind (drives the Prometheus `# TYPE` line).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone counter.
    Counter,
    /// Last-write-wins gauge.
    Gauge,
    /// Fixed-bucket histogram.
    Histogram,
}

impl MetricKind {
    /// Prometheus type keyword.
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

enum Series {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<HistogramCore>),
}

struct Family {
    help: String,
    kind: MetricKind,
    series: BTreeMap<Vec<(String, String)>, Series>,
}

/// A metrics registry.  Most code uses the process-wide [`global`]
/// instance; tests construct their own.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

fn canonical_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    out.sort();
    out
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn family<'a>(
        families: &'a mut BTreeMap<String, Family>,
        name: &str,
        help: &str,
        kind: MetricKind,
    ) -> &'a mut Family {
        let fam = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name} registered as {} and {}",
            fam.kind.name(),
            kind.name()
        );
        fam
    }

    /// Get-or-create a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let key = canonical_labels(labels);
        let mut families = self.families.lock().unwrap();
        let fam = Self::family(&mut families, name, help, MetricKind::Counter);
        let series = fam
            .series
            .entry(key)
            .or_insert_with(|| Series::Counter(Arc::new(AtomicU64::new(0f64.to_bits()))));
        match series {
            Series::Counter(cell) => Counter { cell: cell.clone() },
            _ => unreachable!("kind checked above"),
        }
    }

    /// Get-or-create a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let key = canonical_labels(labels);
        let mut families = self.families.lock().unwrap();
        let fam = Self::family(&mut families, name, help, MetricKind::Gauge);
        let series = fam
            .series
            .entry(key)
            .or_insert_with(|| Series::Gauge(Arc::new(AtomicU64::new(0f64.to_bits()))));
        match series {
            Series::Gauge(cell) => Gauge { cell: cell.clone() },
            _ => unreachable!("kind checked above"),
        }
    }

    /// Get-or-create a histogram series.  If the series already exists its
    /// original buckets win (`bounds` must be ascending).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histogram {
        let key = canonical_labels(labels);
        let mut families = self.families.lock().unwrap();
        let fam = Self::family(&mut families, name, help, MetricKind::Histogram);
        let series = fam.series.entry(key).or_insert_with(|| {
            debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
            Series::Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
                sum: AtomicU64::new(0f64.to_bits()),
                count: AtomicU64::new(0),
            }))
        });
        match series {
            Series::Histogram(core) => Histogram { core: core.clone() },
            _ => unreachable!("kind checked above"),
        }
    }

    /// A point-in-time copy of every family and series.
    pub fn snapshot(&self) -> Snapshot {
        let families = self.families.lock().unwrap();
        Snapshot {
            families: families
                .iter()
                .map(|(name, fam)| FamilySnapshot {
                    name: name.clone(),
                    help: fam.help.clone(),
                    kind: fam.kind,
                    series: fam
                        .series
                        .iter()
                        .map(|(labels, series)| SeriesSnapshot {
                            labels: labels.clone(),
                            value: match series {
                                Series::Counter(c) => SeriesValue::Counter(f64::from_bits(
                                    c.load(Ordering::Relaxed),
                                )),
                                Series::Gauge(g) => SeriesValue::Gauge(f64::from_bits(
                                    g.load(Ordering::Relaxed),
                                )),
                                Series::Histogram(h) => SeriesValue::Histogram(HistogramSnapshot {
                                    bounds: h.bounds.clone(),
                                    counts: h
                                        .buckets
                                        .iter()
                                        .map(|b| b.load(Ordering::Relaxed))
                                        .collect(),
                                    sum: f64::from_bits(h.sum.load(Ordering::Relaxed)),
                                    count: h.count.load(Ordering::Relaxed),
                                }),
                            },
                        })
                        .collect(),
                })
                .collect(),
        }
    }
}

/// The process-wide registry every instrumentation site records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Point-in-time registry contents (see [`Registry::snapshot`]).
pub struct Snapshot {
    /// One entry per metric family, name-ordered.
    pub families: Vec<FamilySnapshot>,
}

/// One metric family: a name, its kind/help, and its label series.
pub struct FamilySnapshot {
    /// Metric name (`meliso_*`).
    pub name: String,
    /// `# HELP` text.
    pub help: String,
    /// Counter / gauge / histogram.
    pub kind: MetricKind,
    /// Series, ordered by canonical label set.
    pub series: Vec<SeriesSnapshot>,
}

/// One labeled series inside a family.
pub struct SeriesSnapshot {
    /// Canonical (key-sorted) label pairs.
    pub labels: Vec<(String, String)>,
    /// The recorded value.
    pub value: SeriesValue,
}

/// Snapshotted value of one series.
pub enum SeriesValue {
    /// Counter value.
    Counter(f64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram state.
    Histogram(HistogramSnapshot),
}

/// Snapshotted histogram state (per-bucket counts are **not** cumulative;
/// the exporter accumulates them).
#[derive(Clone)]
pub struct HistogramSnapshot {
    /// Finite bucket upper bounds, ascending.
    pub bounds: Vec<f64>,
    /// Per-bucket observation counts; the final entry is the `+Inf` bucket.
    pub counts: Vec<u64>,
    /// Sum of all observations.
    pub sum: f64,
    /// Total observations.
    pub count: u64,
}

impl HistogramSnapshot {
    /// Nearest-rank quantile estimate from the bucket counts, interpolating
    /// linearly within the landing bucket.  `q` in `[0, 1]`.  Returns NaN
    /// when the histogram is empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let prev = cum;
            cum += c;
            if cum >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // +Inf bucket: the best point estimate is the last
                    // finite bound (or the mean for a bound-less histogram).
                    return self.bounds.last().copied().unwrap_or(self.sum
                        / self.count as f64);
                };
                let frac = if c == 0 {
                    0.0
                } else {
                    (rank - prev) as f64 / c as f64
                };
                return lo + (hi - lo) * frac;
            }
        }
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_one_cell() {
        let r = Registry::new();
        let a = r.counter("m_total", "h", &[("shard", "0")]);
        let b = r.counter("m_total", "h", &[("shard", "0")]);
        a.inc();
        b.add(2.5);
        assert_eq!(a.value(), 3.5);
        // A different label set is a different cell.
        let c = r.counter("m_total", "h", &[("shard", "1")]);
        assert_eq!(c.value(), 0.0);
    }

    #[test]
    fn label_order_does_not_split_series() {
        let r = Registry::new();
        let a = r.counter("m_total", "h", &[("a", "1"), ("b", "2")]);
        let b = r.counter("m_total", "h", &[("b", "2"), ("a", "1")]);
        a.inc();
        assert_eq!(b.value(), 1.0);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let r = Registry::new();
        let g = r.gauge("g", "h", &[]);
        g.set(4.0);
        g.set(2.0);
        assert_eq!(g.value(), 2.0);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let r = Registry::new();
        let h = r.histogram("h_seconds", "h", &[], &[0.1, 1.0, 10.0]);
        for v in [0.05, 0.5, 0.5, 5.0, 50.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        let snap = r.snapshot();
        let fam = &snap.families[0];
        let SeriesValue::Histogram(hs) = &fam.series[0].value else {
            panic!("expected histogram");
        };
        assert_eq!(hs.counts, vec![1, 2, 1, 1]);
        assert_eq!(hs.count, 5);
        assert!((hs.sum - 56.05).abs() < 1e-12);
        // p50 lands in the (0.1, 1.0] bucket.
        let p50 = hs.quantile(0.5);
        assert!(p50 > 0.1 && p50 <= 1.0, "p50 = {p50}");
        // p100 lands in +Inf and clamps to the last finite bound.
        assert_eq!(hs.quantile(1.0), 10.0);
    }

    #[test]
    fn empty_histogram_quantile_is_nan() {
        let r = Registry::new();
        let h = r.histogram("h_seconds", "h", &[], &[1.0]);
        drop(h);
        let snap = r.snapshot();
        let SeriesValue::Histogram(hs) = &snap.families[0].series[0].value else {
            panic!("expected histogram");
        };
        assert!(hs.quantile(0.5).is_nan());
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("m", "h", &[]);
        let _ = r.gauge("m", "h", &[]);
    }
}
