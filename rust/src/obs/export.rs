//! Exporters: registry snapshots as Prometheus text exposition or
//! [`Json`], and flight-recorder traces as Chrome trace-event files.
//!
//! The Prometheus writer follows the text exposition format: one
//! `# HELP` / `# TYPE` pair per family, label values escaped (`\\`, `\"`,
//! `\n`), histograms rendered as *cumulative* `_bucket` series closed by
//! `le="+Inf"`, plus `_sum` and `_count`.  The golden-file test in
//! `rust/tests/obs_end_to_end.rs` pins the format.
//!
//! File writers go through a same-directory temp file + rename, so a
//! `serve-bench --metrics-out` loop can refresh the snapshot while a
//! concurrent `meliso status` reads it without ever seeing a torn file.

use crate::obs::registry::{MetricKind, SeriesValue, Snapshot};
use crate::util::json::Json;
use std::path::Path;

/// Render a number the way Prometheus expects (integers without a
/// fraction, everything else via Rust's shortest-roundtrip float).
fn fmt_num(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the exposition format.
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape HELP text (only `\\` and newline are special there).
fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render a snapshot in the Prometheus text exposition format.
pub fn prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for fam in &snap.families {
        out.push_str(&format!("# HELP {} {}\n", fam.name, escape_help(&fam.help)));
        out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.name()));
        for series in &fam.series {
            match &series.value {
                SeriesValue::Counter(v) | SeriesValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        fam.name,
                        label_block(&series.labels, None),
                        fmt_num(*v)
                    ));
                }
                SeriesValue::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, c) in h.counts.iter().enumerate() {
                        cum += c;
                        let le = if i < h.bounds.len() {
                            fmt_num(h.bounds[i])
                        } else {
                            "+Inf".to_string()
                        };
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            fam.name,
                            label_block(&series.labels, Some(("le", &le))),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        fam.name,
                        label_block(&series.labels, None),
                        fmt_num(h.sum)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        fam.name,
                        label_block(&series.labels, None),
                        cum
                    ));
                }
            }
        }
    }
    out
}

/// Render a snapshot as a JSON document (`meliso status` reads this).
pub fn to_json(snap: &Snapshot, uptime_s: f64) -> Json {
    let mut metrics = Json::obj();
    for fam in &snap.families {
        let mut series_items = Vec::with_capacity(fam.series.len());
        for series in &fam.series {
            let mut labels = Json::obj();
            for (k, v) in &series.labels {
                labels.set(k, Json::Str(v.clone()));
            }
            let mut item = Json::obj();
            item.set("labels", labels);
            match &series.value {
                SeriesValue::Counter(v) | SeriesValue::Gauge(v) => {
                    item.set("value", Json::Num(*v));
                }
                SeriesValue::Histogram(h) => {
                    item.set("sum", Json::Num(h.sum))
                        .set("count", Json::Num(h.count as f64))
                        .set(
                            "bounds",
                            Json::Arr(h.bounds.iter().map(|&b| Json::Num(b)).collect()),
                        )
                        .set(
                            "counts",
                            Json::Arr(h.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
                        );
                }
            }
            series_items.push(item);
        }
        let mut fam_obj = Json::obj();
        fam_obj
            .set("help", Json::Str(fam.help.clone()))
            .set("type", Json::Str(fam.kind.name().into()))
            .set("series", Json::Arr(series_items));
        metrics.set(&fam.name, fam_obj);
    }
    let mut doc = Json::obj();
    doc.set("schema", Json::Num(1.0))
        .set("uptime_s", Json::Num(uptime_s))
        .set("metrics", metrics);
    doc
}

/// Write `content` to `path` atomically (same-directory temp + rename).
fn write_atomic(path: &str, content: &str) -> Result<(), String> {
    let p = Path::new(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() && !dir.exists() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("creating {}: {e}", dir.display()))?;
        }
    }
    let tmp = format!("{path}.tmp.{}", std::process::id());
    std::fs::write(&tmp, content).map_err(|e| format!("writing {tmp}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("renaming {tmp} -> {path}: {e}"))
}

/// Snapshot the global registry and write it to `path`: JSON when the
/// path ends in `.json` (what `meliso status` reads), Prometheus text
/// otherwise.  A `meliso_obs_uptime_seconds` gauge is stamped into the
/// snapshot so readers can turn busy-seconds counters into fractions.
pub fn write_metrics_file(path: &str) -> Result<(), String> {
    let uptime = crate::obs::uptime_s();
    crate::obs::global()
        .gauge(
            crate::obs::names::UPTIME,
            "Seconds since the observability epoch, set at snapshot time",
            &[],
        )
        .set(uptime);
    let snap = crate::obs::global().snapshot();
    let content = if path.ends_with(".json") {
        to_json(&snap, uptime).pretty() + "\n"
    } else {
        prometheus(&snap)
    };
    write_atomic(path, &content)
}

/// Write the global flight recorder's retained spans to `path` as a
/// Chrome trace-event JSON document.
pub fn write_trace_file(path: &str) -> Result<(), String> {
    let doc = crate::obs::recorder().chrome_trace();
    write_atomic(path, &(doc.pretty() + "\n"))
}

/// Histogram invariant checks shared by tests: cumulative buckets are
/// monotone and the `+Inf` bucket equals `_count`.
pub fn check_histogram_invariants(snap: &Snapshot) -> Result<(), String> {
    for fam in &snap.families {
        if fam.kind != MetricKind::Histogram {
            continue;
        }
        for series in &fam.series {
            let SeriesValue::Histogram(h) = &series.value else {
                return Err(format!("{}: non-histogram series", fam.name));
            };
            if h.counts.len() != h.bounds.len() + 1 {
                return Err(format!("{}: bucket/bound arity mismatch", fam.name));
            }
            let total: u64 = h.counts.iter().sum();
            if total != h.count {
                return Err(format!(
                    "{}: +Inf cumulative {} != count {}",
                    fam.name, total, h.count
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    #[test]
    fn prometheus_escapes_label_values() {
        let r = Registry::new();
        let c = r.counter("m_total", "help", &[("op", "a\\b\"c\nd")]);
        c.inc();
        let text = prometheus(&r.snapshot());
        assert!(
            text.contains(r#"m_total{op="a\\b\"c\nd"} 1"#),
            "got: {text}"
        );
    }

    #[test]
    fn prometheus_histogram_buckets_are_cumulative() {
        let r = Registry::new();
        let h = r.histogram("lat_seconds", "latency", &[], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = prometheus(&r.snapshot());
        assert!(text.contains("lat_seconds_bucket{le=\"0.1\"} 1"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"1\"} 2"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("lat_seconds_count 3"), "{text}");
        check_histogram_invariants(&r.snapshot()).unwrap();
    }

    #[test]
    fn json_export_parses_back() {
        let r = Registry::new();
        r.counter("c_total", "c", &[("shard", "0")]).add(2.0);
        r.gauge("g", "g", &[]).set(7.5);
        r.histogram("h_seconds", "h", &[], &[1.0]).observe(0.5);
        let doc = to_json(&r.snapshot(), 12.5);
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(back.get("uptime_s").unwrap().as_f64(), Some(12.5));
        let metrics = back.get("metrics").unwrap();
        let c = metrics.get("c_total").unwrap();
        assert_eq!(c.get("type").unwrap().as_str(), Some("counter"));
        let series = c.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series[0].get("value").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            series[0]
                .get("labels")
                .unwrap()
                .get("shard")
                .unwrap()
                .as_str(),
            Some("0")
        );
        let h = metrics.get("h_seconds").unwrap();
        let hs = &h.get("series").unwrap().as_arr().unwrap()[0];
        assert_eq!(hs.get("count").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn fmt_num_renders_integers_and_floats() {
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(0.25), "0.25");
        assert_eq!(fmt_num(f64::INFINITY), "+Inf");
    }
}
