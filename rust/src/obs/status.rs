//! The `meliso status` surface: turn a metrics snapshot (the JSON file a
//! `--metrics-out *.json` run refreshes) into a one-screen operational
//! summary — plane occupancy, per-shard busy fractions, cache hit rate,
//! solve p50/p99 and the write/read energy split.
//!
//! The reader is deliberately decoupled from the live registry: it
//! consumes the exported [`Json`] document, so `meliso status` works
//! against a snapshot file refreshed by a separate `serve-bench` process.

use crate::obs::names;
use crate::obs::registry::HistogramSnapshot;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One shard row of the status table.
pub struct ShardStatus {
    /// Shard label (the `shard` metric label).
    pub shard: String,
    /// Seconds spent processing jobs.
    pub busy_s: f64,
    /// Chunk executions.
    pub chunks: f64,
    /// `busy_s / uptime` (NaN when uptime is unknown).
    pub busy_frac: f64,
}

/// Everything `meliso status` reports, assembled from a metrics snapshot.
pub struct StatusReport {
    /// Snapshot uptime (seconds since the producing process's epoch).
    pub uptime_s: f64,
    /// Tile slots currently held across all MCAs.
    pub slots_in_use: Option<f64>,
    /// Highest per-MCA slot count ever needed.
    pub slot_high_water: Option<f64>,
    /// Operands resident on the plane.
    pub resident_operands: Option<f64>,
    /// Chunks resident on the plane.
    pub resident_chunks: Option<f64>,
    /// Operand evictions/retirements.
    pub evictions: Option<f64>,
    /// Per-shard busy rows, shard-ordered.
    pub shards: Vec<ShardStatus>,
    /// Operand-cache hits.
    pub cache_hits: Option<f64>,
    /// Operand-cache misses.
    pub cache_misses: Option<f64>,
    /// Operand-cache plane rebuilds.
    pub cache_rebuilds: Option<f64>,
    /// `hits / (hits + misses)` (None until the cache served a lookup).
    pub cache_hit_rate: Option<f64>,
    /// Served solves (histogram count).
    pub solve_count: u64,
    /// Per-vector latency p50, milliseconds.
    pub solve_p50_ms: Option<f64>,
    /// Per-vector latency p99, milliseconds.
    pub solve_p99_ms: Option<f64>,
    /// Per-vector latency mean, milliseconds.
    pub solve_mean_ms: Option<f64>,
    /// Failed served batches.
    pub solve_errors: Option<f64>,
    /// Serve-path write energy, joules.
    pub energy_write_j: Option<f64>,
    /// Serve-path read energy, joules.
    pub energy_read_j: Option<f64>,
    /// Front-door HTTP requests handled, all routes.
    pub serve_requests: Option<f64>,
    /// Front-door requests rejected before execution (admission).
    pub serve_rejected: Option<f64>,
    /// Requests currently admitted and executing on the front door.
    pub serve_inflight: Option<f64>,
    /// Coalesced `execute_batch` windows dispatched.
    pub serve_coalesced_batches: Option<f64>,
    /// Solve requests folded into coalesced windows.
    pub serve_coalesced_solves: Option<f64>,
    /// Mean solves per coalesced window (the amortization factor).
    pub serve_coalesce_factor: Option<f64>,
}

fn family<'a>(doc: &'a Json, name: &str) -> Option<&'a Json> {
    doc.get("metrics")?.get(name)
}

fn series<'a>(fam: &'a Json) -> &'a [Json] {
    fam.get("series").and_then(|s| s.as_arr()).unwrap_or(&[])
}

/// Sum of `value` across every series of a family (counters/gauges).
fn sum_values(doc: &Json, name: &str) -> Option<f64> {
    let fam = family(doc, name)?;
    Some(
        series(fam)
            .iter()
            .filter_map(|s| s.get("value").and_then(|v| v.as_f64()))
            .sum(),
    )
}

/// Sum of `value` across series matching `label == value`.
fn sum_where(doc: &Json, name: &str, label: &str, value: &str) -> Option<f64> {
    let fam = family(doc, name)?;
    Some(
        series(fam)
            .iter()
            .filter(|s| {
                s.get("labels")
                    .and_then(|l| l.get(label))
                    .and_then(|v| v.as_str())
                    == Some(value)
            })
            .filter_map(|s| s.get("value").and_then(|v| v.as_f64()))
            .sum(),
    )
}

/// `label value -> summed counter` across a family.
fn values_by_label(doc: &Json, name: &str, label: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let Some(fam) = family(doc, name) else {
        return out;
    };
    for s in series(fam) {
        let Some(key) = s
            .get("labels")
            .and_then(|l| l.get(label))
            .and_then(|v| v.as_str())
        else {
            continue;
        };
        let v = s.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
        *out.entry(key.to_string()).or_insert(0.0) += v;
    }
    out
}

/// Merge every series of a histogram family into one snapshot (series
/// share the registered bounds, so bucket-wise addition is exact).
fn merged_histogram(doc: &Json, name: &str) -> Option<HistogramSnapshot> {
    let fam = family(doc, name)?;
    let mut merged: Option<HistogramSnapshot> = None;
    for s in series(fam) {
        let bounds: Vec<f64> = s
            .get("bounds")?
            .as_arr()?
            .iter()
            .filter_map(|b| b.as_f64())
            .collect();
        let counts: Vec<u64> = s
            .get("counts")?
            .as_arr()?
            .iter()
            .filter_map(|c| c.as_f64())
            .map(|c| c as u64)
            .collect();
        let sum = s.get("sum")?.as_f64()?;
        let count = s.get("count")?.as_f64()? as u64;
        match &mut merged {
            None => {
                merged = Some(HistogramSnapshot {
                    bounds,
                    counts,
                    sum,
                    count,
                })
            }
            Some(m) if m.bounds == bounds && m.counts.len() == counts.len() => {
                for (a, b) in m.counts.iter_mut().zip(&counts) {
                    *a += b;
                }
                m.sum += sum;
                m.count += count;
            }
            Some(_) => return None,
        }
    }
    merged
}

impl StatusReport {
    /// Assemble a report from an exported metrics JSON document.
    pub fn from_json(doc: &Json) -> Result<StatusReport, String> {
        if doc.get("metrics").and_then(|m| m.as_obj()).is_none() {
            return Err("not a metrics snapshot (missing top-level \"metrics\" object)".into());
        }
        let uptime_s = doc
            .get("uptime_s")
            .and_then(|v| v.as_f64())
            .or_else(|| sum_values(doc, names::UPTIME))
            .unwrap_or(f64::NAN);

        let busy = values_by_label(doc, names::SHARD_BUSY_SECONDS, "shard");
        let chunks = values_by_label(doc, names::SHARD_CHUNKS, "shard");
        let mut shard_keys: Vec<String> = busy.keys().chain(chunks.keys()).cloned().collect();
        shard_keys.sort_by_key(|k| k.parse::<u64>().unwrap_or(u64::MAX));
        shard_keys.dedup();
        let shards = shard_keys
            .into_iter()
            .map(|k| {
                let busy_s = busy.get(&k).copied().unwrap_or(0.0);
                ShardStatus {
                    busy_frac: busy_s / uptime_s,
                    busy_s,
                    chunks: chunks.get(&k).copied().unwrap_or(0.0),
                    shard: k,
                }
            })
            .collect();

        let cache_hits = sum_values(doc, names::CACHE_HITS);
        let cache_misses = sum_values(doc, names::CACHE_MISSES);
        let cache_hit_rate = match (cache_hits, cache_misses) {
            (Some(h), Some(m)) if h + m > 0.0 => Some(h / (h + m)),
            _ => None,
        };

        let solve = merged_histogram(doc, names::SOLVE_LATENCY);
        let (solve_count, p50, p99, mean) = match &solve {
            Some(h) if h.count > 0 => (
                h.count,
                Some(h.quantile(0.5) * 1e3),
                Some(h.quantile(0.99) * 1e3),
                Some(h.sum / h.count as f64 * 1e3),
            ),
            _ => (0, None, None, None),
        };

        Ok(StatusReport {
            uptime_s,
            slots_in_use: sum_values(doc, names::PLANE_SLOTS_IN_USE),
            slot_high_water: sum_values(doc, names::PLANE_SLOT_HIGH_WATER),
            resident_operands: sum_values(doc, names::PLANE_RESIDENT_OPERANDS),
            resident_chunks: sum_values(doc, names::PLANE_RESIDENT_CHUNKS),
            evictions: sum_values(doc, names::PLANE_EVICTIONS),
            shards,
            cache_hits,
            cache_misses,
            cache_rebuilds: sum_values(doc, names::CACHE_REBUILDS),
            cache_hit_rate,
            solve_count,
            solve_p50_ms: p50,
            solve_p99_ms: p99,
            solve_mean_ms: mean,
            solve_errors: sum_values(doc, names::SOLVE_ERRORS),
            energy_write_j: sum_where(doc, names::ENERGY_JOULES, "kind", "write"),
            energy_read_j: sum_where(doc, names::ENERGY_JOULES, "kind", "read"),
            serve_requests: sum_values(doc, names::SERVE_REQUESTS),
            serve_rejected: sum_values(doc, names::SERVE_REJECTED),
            serve_inflight: sum_values(doc, names::SERVE_INFLIGHT),
            serve_coalesced_batches: sum_values(doc, names::SERVE_COALESCED_BATCHES),
            serve_coalesced_solves: sum_values(doc, names::SERVE_COALESCED_SOLVES),
            serve_coalesce_factor: match (
                sum_values(doc, names::SERVE_COALESCED_SOLVES),
                sum_values(doc, names::SERVE_COALESCED_BATCHES),
            ) {
                (Some(s), Some(b)) if b > 0.0 => Some(s / b),
                _ => None,
            },
        })
    }

    /// Machine-readable form (`meliso status --json`).
    pub fn to_json(&self) -> Json {
        fn opt(v: Option<f64>) -> Json {
            v.map(Json::Num).unwrap_or(Json::Null)
        }
        let mut plane = Json::obj();
        plane
            .set("tile_slots_in_use", opt(self.slots_in_use))
            .set("tile_slot_high_water", opt(self.slot_high_water))
            .set("resident_operands", opt(self.resident_operands))
            .set("resident_chunks", opt(self.resident_chunks))
            .set("evictions", opt(self.evictions));
        let shards = self
            .shards
            .iter()
            .map(|s| {
                let mut row = Json::obj();
                row.set("shard", Json::Str(s.shard.clone()))
                    .set("busy_s", Json::Num(s.busy_s))
                    .set("chunks", Json::Num(s.chunks))
                    .set("busy_frac", Json::Num(s.busy_frac));
                row
            })
            .collect();
        let mut cache = Json::obj();
        cache
            .set("hits", opt(self.cache_hits))
            .set("misses", opt(self.cache_misses))
            .set("rebuilds", opt(self.cache_rebuilds))
            .set("hit_rate", opt(self.cache_hit_rate));
        let mut solves = Json::obj();
        solves
            .set("count", Json::Num(self.solve_count as f64))
            .set("p50_ms", opt(self.solve_p50_ms))
            .set("p99_ms", opt(self.solve_p99_ms))
            .set("mean_ms", opt(self.solve_mean_ms))
            .set("errors", opt(self.solve_errors));
        let mut energy = Json::obj();
        energy
            .set("write_j", opt(self.energy_write_j))
            .set("read_j", opt(self.energy_read_j));
        let mut serve = Json::obj();
        serve
            .set("requests", opt(self.serve_requests))
            .set("rejected", opt(self.serve_rejected))
            .set("inflight", opt(self.serve_inflight))
            .set("coalesced_batches", opt(self.serve_coalesced_batches))
            .set("coalesced_solves", opt(self.serve_coalesced_solves))
            .set("coalesce_factor", opt(self.serve_coalesce_factor));
        let mut doc = Json::obj();
        doc.set("uptime_s", Json::Num(self.uptime_s))
            .set("plane", plane)
            .set("shards", Json::Arr(shards))
            .set("cache", cache)
            .set("solves", solves)
            .set("energy", energy)
            .set("serve", serve);
        doc
    }

    /// Human-readable status table.
    pub fn render(&self) -> String {
        fn cell(v: Option<f64>) -> String {
            match v {
                Some(v) if v.is_finite() => {
                    if v.fract() == 0.0 && v.abs() < 1e15 {
                        format!("{}", v as i64)
                    } else {
                        format!("{v:.3}")
                    }
                }
                _ => "-".to_string(),
            }
        }
        fn sci(v: Option<f64>) -> String {
            match v {
                Some(v) if v.is_finite() => format!("{v:.3e}"),
                _ => "-".to_string(),
            }
        }
        let mut out = String::new();
        out.push_str(&format!(
            "meliso status  (snapshot uptime {:.1} s)\n\n",
            self.uptime_s
        ));
        out.push_str("plane\n");
        out.push_str(&format!(
            "  tile slots in use   {}\n",
            cell(self.slots_in_use)
        ));
        out.push_str(&format!(
            "  slot high water     {}\n",
            cell(self.slot_high_water)
        ));
        out.push_str(&format!(
            "  resident operands   {}\n",
            cell(self.resident_operands)
        ));
        out.push_str(&format!(
            "  resident chunks     {}\n",
            cell(self.resident_chunks)
        ));
        out.push_str(&format!("  evictions           {}\n", cell(self.evictions)));
        out.push_str("\nshards          busy s      chunks      busy %\n");
        if self.shards.is_empty() {
            out.push_str("  (no shard activity recorded)\n");
        }
        for s in &self.shards {
            let frac = if s.busy_frac.is_finite() {
                format!("{:.1}%", s.busy_frac * 100.0)
            } else {
                "-".to_string()
            };
            out.push_str(&format!(
                "  shard {:<6} {:<11.3} {:<11} {}\n",
                s.shard, s.busy_s, s.chunks as u64, frac
            ));
        }
        out.push_str(&format!(
            "\ncache           hits {}   misses {}   hit rate {}   rebuilds {}\n",
            cell(self.cache_hits),
            cell(self.cache_misses),
            self.cache_hit_rate
                .map(|r| format!("{:.1}%", r * 100.0))
                .unwrap_or_else(|| "-".to_string()),
            cell(self.cache_rebuilds),
        ));
        out.push_str(&format!(
            "solves          count {}   p50 {} ms   p99 {} ms   mean {} ms   errors {}\n",
            self.solve_count,
            cell(self.solve_p50_ms),
            cell(self.solve_p99_ms),
            cell(self.solve_mean_ms),
            cell(self.solve_errors),
        ));
        out.push_str(&format!(
            "energy          write {} J   read {} J\n",
            sci(self.energy_write_j),
            sci(self.energy_read_j),
        ));
        out.push_str(&format!(
            "serve           requests {}   rejected {}   inflight {}   coalesced {}/{} (x{})\n",
            cell(self.serve_requests),
            cell(self.serve_rejected),
            cell(self.serve_inflight),
            cell(self.serve_coalesced_solves),
            cell(self.serve_coalesced_batches),
            cell(self.serve_coalesce_factor),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::to_json;
    use crate::obs::registry::{Registry, LATENCY_BUCKETS};

    fn sample_doc() -> Json {
        let r = Registry::new();
        r.counter(names::SHARD_BUSY_SECONDS, "h", &[("shard", "0")])
            .add(2.0);
        r.counter(names::SHARD_BUSY_SECONDS, "h", &[("shard", "1")])
            .add(1.0);
        r.counter(names::SHARD_CHUNKS, "h", &[("shard", "0")]).add(8.0);
        r.gauge(names::PLANE_SLOTS_IN_USE, "h", &[]).set(6.0);
        r.gauge(names::PLANE_RESIDENT_OPERANDS, "h", &[]).set(2.0);
        r.counter(names::CACHE_HITS, "h", &[]).add(3.0);
        r.counter(names::CACHE_MISSES, "h", &[]).add(1.0);
        let h = r.histogram(
            names::SOLVE_LATENCY,
            "h",
            &[("operand", "op0")],
            LATENCY_BUCKETS,
        );
        for _ in 0..100 {
            h.observe(2e-3);
        }
        r.counter(names::ENERGY_JOULES, "h", &[("operand", "op0"), ("kind", "write")])
            .add(1e-3);
        r.counter(names::ENERGY_JOULES, "h", &[("operand", "op0"), ("kind", "read")])
            .add(2e-5);
        r.counter(names::SERVE_REQUESTS, "h", &[("route", "solve")])
            .add(12.0);
        r.counter(names::SERVE_REQUESTS, "h", &[("route", "status")])
            .add(3.0);
        r.counter(names::SERVE_REJECTED, "h", &[("reason", "global_budget")])
            .add(2.0);
        r.gauge(names::SERVE_INFLIGHT, "h", &[]).set(1.0);
        r.counter(names::SERVE_COALESCED_BATCHES, "h", &[]).add(4.0);
        r.counter(names::SERVE_COALESCED_SOLVES, "h", &[]).add(12.0);
        to_json(&r.snapshot(), 10.0)
    }

    #[test]
    fn report_assembles_all_sections() {
        let report = StatusReport::from_json(&sample_doc()).unwrap();
        assert_eq!(report.uptime_s, 10.0);
        assert_eq!(report.slots_in_use, Some(6.0));
        assert_eq!(report.resident_operands, Some(2.0));
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.shards[0].shard, "0");
        assert!((report.shards[0].busy_frac - 0.2).abs() < 1e-12);
        assert_eq!(report.cache_hit_rate, Some(0.75));
        assert_eq!(report.solve_count, 100);
        let p50 = report.solve_p50_ms.unwrap();
        assert!(p50 > 1.0 && p50 <= 2.5, "p50 = {p50}");
        assert_eq!(report.energy_write_j, Some(1e-3));
        assert_eq!(report.energy_read_j, Some(2e-5));
        assert_eq!(report.serve_requests, Some(15.0));
        assert_eq!(report.serve_rejected, Some(2.0));
        assert_eq!(report.serve_inflight, Some(1.0));
        assert_eq!(report.serve_coalesced_batches, Some(4.0));
        assert_eq!(report.serve_coalesced_solves, Some(12.0));
        assert_eq!(report.serve_coalesce_factor, Some(3.0));
    }

    #[test]
    fn report_round_trips_to_json() {
        let report = StatusReport::from_json(&sample_doc()).unwrap();
        let doc = report.to_json();
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(
            back.get("plane")
                .unwrap()
                .get("tile_slots_in_use")
                .unwrap()
                .as_f64(),
            Some(6.0)
        );
        assert_eq!(back.get("shards").unwrap().as_arr().unwrap().len(), 2);
        assert!(back
            .get("solves")
            .unwrap()
            .get("p50_ms")
            .unwrap()
            .as_f64()
            .is_some());
        assert_eq!(
            back.get("serve").unwrap().get("requests").unwrap().as_f64(),
            Some(15.0)
        );
        assert_eq!(
            back.get("serve")
                .unwrap()
                .get("coalesce_factor")
                .unwrap()
                .as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn render_tolerates_missing_families() {
        let doc = to_json(&Registry::new().snapshot(), 1.0);
        let report = StatusReport::from_json(&doc).unwrap();
        let text = report.render();
        assert!(text.contains("tile slots in use   -"), "{text}");
        assert!(text.contains("no shard activity"), "{text}");
    }

    #[test]
    fn rejects_non_snapshot_documents() {
        assert!(StatusReport::from_json(&Json::parse("{\"x\":1}").unwrap()).is_err());
    }
}
