//! Flight recorder: a bounded ring buffer of timestamped span events,
//! serializing to Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable).
//!
//! Spans name the six pipeline stages of a plane solve — plan, extract,
//! encode, execute, gather, reduce — and carry a [`Lane`]: the leader
//! thread or one shard.  In the rendered trace each lane is a thread row,
//! so leader-side tile-extraction serialization shows up visually as a
//! dense `extract` band with idle shard rows underneath it.
//!
//! The ring is bounded (`MELISO_TRACE_CAP`, default 65 536 events):
//! recording beyond capacity drops the *oldest* events and counts them, so
//! a long `serve-bench` loop keeps the most recent window instead of
//! growing without bound.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

/// Default ring capacity (events) when `MELISO_TRACE_CAP` is unset.
pub const DEFAULT_TRACE_CAP: usize = 65_536;

/// The six pipeline stages of a plane solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Chunk-plan + placement derivation (leader).
    Plan,
    /// Tile extraction + dispatch of one chunk (leader).
    Extract,
    /// Write–verify matrix encode of one chunk (shard).
    Encode,
    /// EC-corrected MVM of one chunk against a vector batch (shard).
    Execute,
    /// Supervised gather of partials and seals (leader).
    Gather,
    /// Deterministic chunk-order reduction (leader).
    Reduce,
}

impl Stage {
    /// Span name in the rendered trace.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Plan => "plan",
            Stage::Extract => "extract",
            Stage::Encode => "encode",
            Stage::Execute => "execute",
            Stage::Gather => "gather",
            Stage::Reduce => "reduce",
        }
    }

    /// All stages, pipeline order (used by coverage tests).
    pub const ALL: [Stage; 6] = [
        Stage::Plan,
        Stage::Extract,
        Stage::Encode,
        Stage::Execute,
        Stage::Gather,
        Stage::Reduce,
    ];
}

/// Which thread row a span belongs to in the rendered trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The plane leader (plan / extract / gather / reduce).
    Leader,
    /// Shard thread `n` (encode / execute).
    Shard(usize),
}

impl Lane {
    /// Chrome trace `tid`: leader is 0, shard `n` is `n + 1`.
    pub fn tid(self) -> u64 {
        match self {
            Lane::Leader => 0,
            Lane::Shard(s) => s as u64 + 1,
        }
    }

    /// Human-readable row name for trace metadata.
    pub fn label(self) -> String {
        match self {
            Lane::Leader => "leader".to_string(),
            Lane::Shard(s) => format!("shard {s}"),
        }
    }
}

/// One completed span.
pub struct SpanEvent {
    /// Pipeline stage.
    pub stage: Stage,
    /// Thread row.
    pub lane: Lane,
    /// Start, microseconds since the process trace epoch.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Extra key/value context (chunk coordinates, operand, batch size).
    pub args: Vec<(&'static str, String)>,
}

struct Ring {
    buf: VecDeque<SpanEvent>,
    cap: usize,
    dropped: u64,
}

/// The bounded span ring buffer.  Most code uses the process-wide
/// [`recorder`]; tests construct their own.
pub struct FlightRecorder {
    inner: Mutex<Ring>,
}

impl FlightRecorder {
    /// A recorder holding at most `cap` events.
    pub fn with_capacity(cap: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(Ring {
                buf: VecDeque::with_capacity(cap.min(4096)),
                cap: cap.max(1),
                dropped: 0,
            }),
        }
    }

    /// Push one event, evicting the oldest when full.  Also mirrors the
    /// event to the [`log`](crate::util::log) stream at trace level, so
    /// `MELISO_LOG=trace` interleaves span events with the rest of the
    /// operational log.
    pub fn record(&self, ev: SpanEvent) {
        crate::log_trace!(
            "obs::trace",
            "span {} lane={} ts_us={} dur_us={}",
            ev.stage.name(),
            ev.lane.label(),
            ev.ts_us,
            ev.dur_us
        );
        let mut ring = self.inner.lock().unwrap();
        if ring.buf.len() >= ring.cap {
            ring.buf.pop_front();
            ring.dropped += 1;
        }
        ring.buf.push_back(ev);
    }

    /// Copy out the retained events plus the dropped-event count.
    pub fn snapshot(&self) -> (Vec<SpanEvent>, u64) {
        let ring = self.inner.lock().unwrap();
        let events = ring
            .buf
            .iter()
            .map(|e| SpanEvent {
                stage: e.stage,
                lane: e.lane,
                ts_us: e.ts_us,
                dur_us: e.dur_us,
                args: e.args.clone(),
            })
            .collect();
        (events, ring.dropped)
    }

    /// Drop every retained event and reset the dropped count.
    pub fn clear(&self) {
        let mut ring = self.inner.lock().unwrap();
        ring.buf.clear();
        ring.dropped = 0;
    }

    /// Render the retained events as a Chrome trace-event document.
    pub fn chrome_trace(&self) -> Json {
        let (events, dropped) = self.snapshot();
        chrome_trace_json(&events, dropped)
    }
}

/// The process-wide flight recorder (capacity from `MELISO_TRACE_CAP`).
pub fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| {
        let cap = std::env::var("MELISO_TRACE_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(DEFAULT_TRACE_CAP);
        FlightRecorder::with_capacity(cap)
    })
}

/// Serialize span events to the Chrome trace-event JSON object format:
/// one `"X"` (complete) event per span plus `"M"` metadata events naming
/// the process and each lane.  Load the file in Perfetto
/// (<https://ui.perfetto.dev>) or `chrome://tracing`.
pub fn chrome_trace_json(events: &[SpanEvent], dropped: u64) -> Json {
    let mut lanes: Vec<Lane> = Vec::new();
    for ev in events {
        if !lanes.contains(&ev.lane) {
            lanes.push(ev.lane);
        }
    }
    lanes.sort_by_key(|l| l.tid());

    let mut items = Vec::with_capacity(events.len() + lanes.len() + 1);
    let mut proc_meta = Json::obj();
    proc_meta
        .set("name", Json::Str("process_name".into()))
        .set("ph", Json::Str("M".into()))
        .set("pid", Json::Num(1.0))
        .set("tid", Json::Num(0.0));
    let mut args = Json::obj();
    args.set("name", Json::Str("meliso".into()));
    proc_meta.set("args", args);
    items.push(proc_meta);

    for lane in &lanes {
        let mut meta = Json::obj();
        meta.set("name", Json::Str("thread_name".into()))
            .set("ph", Json::Str("M".into()))
            .set("pid", Json::Num(1.0))
            .set("tid", Json::Num(lane.tid() as f64));
        let mut args = Json::obj();
        args.set("name", Json::Str(lane.label()));
        meta.set("args", args);
        items.push(meta);
    }

    for ev in events {
        let mut item = Json::obj();
        item.set("name", Json::Str(ev.stage.name().into()))
            .set("cat", Json::Str("meliso".into()))
            .set("ph", Json::Str("X".into()))
            .set("ts", Json::Num(ev.ts_us as f64))
            .set("dur", Json::Num(ev.dur_us.max(1) as f64))
            .set("pid", Json::Num(1.0))
            .set("tid", Json::Num(ev.lane.tid() as f64));
        let mut args = Json::obj();
        for (k, v) in &ev.args {
            args.set(k, Json::Str(v.clone()));
        }
        item.set("args", args);
        items.push(item);
    }

    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(items))
        .set("displayTimeUnit", Json::Str("ms".into()));
    let mut other = Json::obj();
    other.set("dropped_events", Json::Num(dropped as f64));
    doc.set("otherData", other);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stage: Stage, lane: Lane, ts_us: u64) -> SpanEvent {
        SpanEvent {
            stage,
            lane,
            ts_us,
            dur_us: 5,
            args: vec![("chunk", "(0,1)".to_string())],
        }
    }

    #[test]
    fn ring_drops_oldest_beyond_capacity() {
        let rec = FlightRecorder::with_capacity(2);
        rec.record(ev(Stage::Plan, Lane::Leader, 0));
        rec.record(ev(Stage::Extract, Lane::Leader, 1));
        rec.record(ev(Stage::Gather, Lane::Leader, 2));
        let (events, dropped) = rec.snapshot();
        assert_eq!(dropped, 1);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].stage, Stage::Extract);
        assert_eq!(events[1].stage, Stage::Gather);
    }

    #[test]
    fn chrome_trace_has_metadata_and_lanes() {
        let rec = FlightRecorder::with_capacity(16);
        rec.record(ev(Stage::Extract, Lane::Leader, 0));
        rec.record(ev(Stage::Execute, Lane::Shard(0), 3));
        rec.record(ev(Stage::Execute, Lane::Shard(1), 4));
        let doc = rec.chrome_trace();
        // Round-trips through the JSON parser.
        let back = Json::parse(&doc.compact()).unwrap();
        let items = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process meta + 3 lane metas + 3 spans.
        assert_eq!(items.len(), 7);
        let metas: Vec<_> = items
            .iter()
            .filter(|i| i.get("ph").unwrap().as_str() == Some("M"))
            .collect();
        assert_eq!(metas.len(), 4);
        let spans: Vec<_> = items
            .iter()
            .filter(|i| i.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(spans.len(), 3);
        // Shard lanes are distinct tids offset past the leader's 0.
        assert_eq!(spans[1].get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(spans[2].get("tid").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn clear_resets_ring_and_dropped() {
        let rec = FlightRecorder::with_capacity(1);
        rec.record(ev(Stage::Plan, Lane::Leader, 0));
        rec.record(ev(Stage::Plan, Lane::Leader, 1));
        rec.clear();
        let (events, dropped) = rec.snapshot();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }
}
