//! Observability: a process-wide metrics registry and a flight recorder.
//!
//! Two halves, both dependency-free and both **zero-cost when disabled**:
//!
//! * [`registry`] — counters, gauges and fixed-bucket histograms with
//!   static label sets (operand, shard, method), snapshotted on demand and
//!   exported as Prometheus text or [`Json`](crate::util::json::Json)
//!   ([`export`]).
//! * [`trace`] — a bounded ring buffer of timestamped span events
//!   (plan → extract → encode → execute → gather → reduce, per chunk batch
//!   and per shard) serializing to Chrome trace-event JSON, loadable in
//!   Perfetto / `chrome://tracing`.
//!
//! The gate is a single [`AtomicU8`] level check
//! ([`metrics_on`] / [`trace_on`]), the same discipline as
//! [`crate::util::log`]: when observability is off, every instrumentation
//! site reduces to one relaxed atomic load — no clocks, no locks, no
//! allocation (`benches/obs_overhead.rs` asserts the disabled-path cost
//! stays under 2% of the hotpath solve).
//!
//! **Determinism contract.** Recording only *reads* wall clocks and
//! *writes* to side-band atomics and ring buffers. It never draws from an
//! RNG stream, never reorders jobs, and never touches a value on the data
//! path — so results are bit-identical with observability fully enabled or
//! fully disabled (covered by `rust/tests/obs_end_to_end.rs`).
//!
//! Enable via `MELISO_OBS=off|metrics|trace` or programmatically with
//! [`set_level`] (the CLI `--metrics-out` / `--trace-out` flags do the
//! latter).

pub mod export;
pub mod registry;
pub mod status;
pub mod trace;

pub use registry::{global, Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};
pub use status::StatusReport;
pub use trace::{recorder, FlightRecorder, Lane, SpanEvent, Stage};

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// How much the observability layer records.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum ObsLevel {
    /// Record nothing; every instrumentation site is one atomic load.
    Off = 0,
    /// Update the metrics registry (counters/gauges/histograms).
    Metrics = 1,
    /// Metrics plus flight-recorder span events.
    Trace = 2,
}

impl ObsLevel {
    fn from_env(s: &str) -> ObsLevel {
        match s.to_ascii_lowercase().as_str() {
            "metrics" => ObsLevel::Metrics,
            "trace" | "full" => ObsLevel::Trace,
            _ => ObsLevel::Off,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn level() -> ObsLevel {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return match raw {
            0 => ObsLevel::Off,
            1 => ObsLevel::Metrics,
            _ => ObsLevel::Trace,
        };
    }
    let lv = std::env::var("MELISO_OBS")
        .map(|s| ObsLevel::from_env(&s))
        .unwrap_or(ObsLevel::Off);
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

/// Override the level programmatically (CLI `--metrics-out`/`--trace-out`).
pub fn set_level(lv: ObsLevel) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Is the metrics registry recording?  (One relaxed atomic load.)
#[inline]
pub fn metrics_on() -> bool {
    level() >= ObsLevel::Metrics
}

/// Is the flight recorder recording?  (One relaxed atomic load.)
#[inline]
pub fn trace_on() -> bool {
    level() >= ObsLevel::Trace
}

/// Process-wide monotonic epoch all trace timestamps are relative to
/// (pinned on first use).
pub(crate) fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Seconds since the process trace epoch (used as metrics uptime).
pub fn uptime_s() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// `Some(Instant)` when metrics are on — the idiom for timing a section
/// without paying a clock read when observability is disabled.
#[inline]
pub fn metrics_clock() -> Option<Instant> {
    if metrics_on() {
        Some(Instant::now())
    } else {
        None
    }
}

/// A started (and timestamped) flight-recorder span.  Obtained from
/// [`span_start`]; [`finish`](SpanTimer::finish) records the event.
pub struct SpanTimer {
    t0: Instant,
    ts_us: u64,
}

/// Start a span if tracing is on (one relaxed atomic load otherwise).
#[inline]
pub fn span_start() -> Option<SpanTimer> {
    if !trace_on() {
        return None;
    }
    let ts_us = now_us();
    Some(SpanTimer {
        t0: Instant::now(),
        ts_us,
    })
}

impl SpanTimer {
    /// Close the span and push it onto the flight recorder.
    pub fn finish(self, stage: Stage, lane: Lane, args: Vec<(&'static str, String)>) {
        let dur_us = self.t0.elapsed().as_micros() as u64;
        recorder().record(SpanEvent {
            stage,
            lane,
            ts_us: self.ts_us,
            dur_us,
            args,
        });
    }
}

/// Canonical metric names, shared by instrumentation sites, the exporters
/// and the `meliso status` reader.
pub mod names {
    /// Per-shard seconds spent processing jobs (counter, label `shard`).
    pub const SHARD_BUSY_SECONDS: &str = "meliso_shard_busy_seconds_total";
    /// Per-shard seconds spent blocked waiting for work (counter, label `shard`).
    pub const SHARD_IDLE_SECONDS: &str = "meliso_shard_idle_seconds_total";
    /// Jobs processed per shard (counter, label `shard`).
    pub const SHARD_JOBS: &str = "meliso_shard_jobs_total";
    /// Chunk executions per shard — one per (chunk, vector) (counter, label `shard`).
    pub const SHARD_CHUNKS: &str = "meliso_shard_chunks_executed_total";
    /// MCAs a shard claimed from another worker's batch queue (counter, label `shard`).
    pub const SHARD_STEALS: &str = "meliso_shard_steals_total";
    /// Sub-MCA steal participations: a shard joined the chunk grid of an
    /// MCA it does not own and executed at least one chunk (counter,
    /// label `shard`).
    pub const SUBMCA_STEALS: &str = "meliso_subMCA_steals_total";
    /// Per-shard seconds spent in the fused extract+encode stage —
    /// materializing a tile from its chunk descriptor and write–verifying
    /// it onto the crossbar (counter, label `shard`).
    pub const SHARD_ENCODE_SECONDS: &str = "meliso_shard_encode_seconds_total";
    /// Seconds the leader spent in supervised gathers (counter).
    pub const PLANE_GATHER_WAIT: &str = "meliso_plane_gather_wait_seconds_total";
    /// Tiles extracted + dispatched by the leader (counter).
    pub const PLANE_TILES_EXTRACTED: &str = "meliso_plane_tiles_extracted_total";
    /// Seconds the leader spent extracting/dispatching tiles (counter).
    pub const PLANE_EXTRACT_SECONDS: &str = "meliso_plane_extract_seconds_total";
    /// Tile slots currently held across all MCAs (gauge).
    pub const PLANE_SLOTS_IN_USE: &str = "meliso_plane_tile_slots_in_use";
    /// Highest per-MCA slot count ever needed (gauge).
    pub const PLANE_SLOT_HIGH_WATER: &str = "meliso_plane_tile_slot_high_water";
    /// Operands currently resident on the plane (gauge).
    pub const PLANE_RESIDENT_OPERANDS: &str = "meliso_plane_resident_operands";
    /// Chunks currently resident on the plane (gauge).
    pub const PLANE_RESIDENT_CHUNKS: &str = "meliso_plane_resident_chunks";
    /// Operand evictions/retirements from the plane (counter).
    pub const PLANE_EVICTIONS: &str = "meliso_plane_evictions_total";
    /// Operand-cache session reuses (counter).
    pub const CACHE_HITS: &str = "meliso_cache_hits_total";
    /// Operand-cache programming misses (counter).
    pub const CACHE_MISSES: &str = "meliso_cache_misses_total";
    /// Operand-cache LRU evictions (counter).
    pub const CACHE_EVICTIONS: &str = "meliso_cache_evictions_total";
    /// Operand-cache plane rebuilds after failure (counter).
    pub const CACHE_REBUILDS: &str = "meliso_cache_rebuilds_total";
    /// Per-vector served solve latency (histogram, label `operand`).
    pub const SOLVE_LATENCY: &str = "meliso_solve_latency_seconds";
    /// Whole-batch solve latency (histogram, label `operand`).
    pub const BATCH_LATENCY: &str = "meliso_batch_latency_seconds";
    /// Failed served batches (counter, label `operand`).
    pub const SOLVE_ERRORS: &str = "meliso_solve_errors_total";
    /// Serve-path energy split (counter, labels `operand`, `kind`=write|read).
    pub const ENERGY_JOULES: &str = "meliso_energy_joules_total";
    /// Iterative-solver iterations (counter, label `method`).
    pub const ITER_ITERATIONS: &str = "meliso_iterative_iterations_total";
    /// Iterative-solver final relative residual (gauge, label `method`).
    pub const ITER_RESIDUAL: &str = "meliso_iterative_final_rel_residual";
    /// Serving latency samples overwritten by the stats ring buffer (counter).
    pub const SAMPLES_DROPPED: &str = "meliso_serving_latency_samples_dropped_total";
    /// HTTP requests handled by the serving front door (counter, label `route`).
    pub const SERVE_REQUESTS: &str = "meliso_serve_requests_total";
    /// Front-door requests rejected before execution (counter, label `reason`).
    pub const SERVE_REJECTED: &str = "meliso_serve_rejected_total";
    /// Coalesced `execute_batch` windows dispatched by the front door (counter).
    pub const SERVE_COALESCED_BATCHES: &str = "meliso_serve_coalesced_batches_total";
    /// Solve requests folded into coalesced windows (counter).
    pub const SERVE_COALESCED_SOLVES: &str = "meliso_serve_coalesced_solves_total";
    /// Requests currently admitted and executing on the front door (gauge).
    pub const SERVE_INFLIGHT: &str = "meliso_serve_inflight_requests";
    /// Seconds since the observability epoch, set at snapshot time (gauge).
    pub const UPTIME: &str = "meliso_obs_uptime_seconds";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parses_from_env_strings() {
        assert_eq!(ObsLevel::from_env("metrics"), ObsLevel::Metrics);
        assert_eq!(ObsLevel::from_env("TRACE"), ObsLevel::Trace);
        assert_eq!(ObsLevel::from_env("full"), ObsLevel::Trace);
        assert_eq!(ObsLevel::from_env("off"), ObsLevel::Off);
        assert_eq!(ObsLevel::from_env("bogus"), ObsLevel::Off);
    }

    #[test]
    fn level_ordering_gates_both_halves() {
        assert!(ObsLevel::Off < ObsLevel::Metrics);
        assert!(ObsLevel::Metrics < ObsLevel::Trace);
    }

    #[test]
    fn epoch_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
