//! Public solver API (the MelisoPy-equivalent front door, DESIGN.md S11).
//!
//! ```no_run
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("add32").unwrap();
//! let x = meliso::linalg::Vector::standard_normal(a.ncols(), 1);
//! let solver = Meliso::new(SystemConfig::tiles_8x8(1024),
//!                          SolveOptions::default()).unwrap();
//! let report = solver.solve_source(a.as_ref(), &x).unwrap();
//! println!("{}", report.to_json().pretty());
//! ```

use crate::config::{BackendKind, SolveOptions, SystemConfig};
use crate::coordinator;
use crate::linalg::{Matrix, Vector};
use crate::matrices::{DenseSource, MatrixSource};
use crate::metrics::SolveReport;
use crate::runtime::native::NativeBackend;
use crate::runtime::pjrt::default_artifact_dir;
use crate::runtime::service::PjrtBackend;
use crate::runtime::Backend;
use std::sync::Arc;

/// The MELISO+ solver: a configured multi-MCA system plus solve options.
pub struct Meliso {
    config: SystemConfig,
    opts: SolveOptions,
    backend: Backend,
}

impl Meliso {
    /// Build a solver; starts the PJRT runtime service when requested
    /// (set `MELISO_ARTIFACTS` to point elsewhere than `./artifacts`).
    pub fn new(config: SystemConfig, opts: SolveOptions) -> Result<Meliso, String> {
        let backend: Backend = match opts.backend {
            BackendKind::Native => Arc::new(NativeBackend::new()),
            BackendKind::Pjrt => {
                let dir = default_artifact_dir();
                match PjrtBackend::start(&dir) {
                    Ok(b) => Arc::new(b),
                    Err(e) => {
                        return Err(format!(
                            "failed to start PJRT runtime from {} ({e}); run `make artifacts` \
                             or use the native backend",
                            dir.display()
                        ))
                    }
                }
            }
        };
        Ok(Meliso {
            config,
            opts,
            backend,
        })
    }

    /// Build with an explicit backend (tests, ablations).
    pub fn with_backend(config: SystemConfig, opts: SolveOptions, backend: Backend) -> Meliso {
        Meliso {
            config,
            opts,
            backend,
        }
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    pub fn config(&self) -> &SystemConfig {
        &self.config
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Override solve options (builder style).
    pub fn reconfigure(mut self, opts: SolveOptions) -> Meliso {
        self.opts = opts;
        self
    }

    /// Solve `Ax = b` in-memory for a streamable operand.
    pub fn solve_source(
        &self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, String> {
        coordinator::solve_distributed(source, x, &self.config, &self.opts, self.backend.clone())
    }

    /// Convenience for dense in-memory operands.
    pub fn solve(&self, a: &Matrix, x: &Vector) -> Result<SolveReport, String> {
        let src = DenseSource::new(a.clone());
        self.solve_source(&src, x)
    }

    /// Run `reps` independent replications (fresh seeds) and return all
    /// reports — the paper averages 100 replications per cell of Table 1.
    pub fn replicate(
        &self,
        source: &dyn MatrixSource,
        x: &Vector,
        reps: usize,
    ) -> Result<Vec<SolveReport>, String> {
        let mut reports = Vec::with_capacity(reps);
        for r in 0..reps {
            let mut opts = self.opts.clone();
            opts.seed = self
                .opts
                .seed
                .wrapping_add((r as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let report = coordinator::solve_distributed(
                source,
                x,
                &self.config,
                &opts,
                self.backend.clone(),
            )?;
            reports.push(report);
        }
        Ok(reports)
    }
}

/// Summary statistics over replications (mean of each reported metric).
pub struct ReplicationSummary {
    pub reps: usize,
    pub rel_err_l2: f64,
    pub rel_err_inf: f64,
    pub ew_mean: f64,
    pub lw_mean: f64,
}

impl ReplicationSummary {
    pub fn from_reports(reports: &[SolveReport]) -> ReplicationSummary {
        let n = reports.len().max(1) as f64;
        ReplicationSummary {
            reps: reports.len(),
            rel_err_l2: reports.iter().map(|r| r.rel_err_l2).sum::<f64>() / n,
            rel_err_inf: reports.iter().map(|r| r.rel_err_inf).sum::<f64>() / n,
            ew_mean: reports.iter().map(|r| r.ew_mean).sum::<f64>() / n,
            lw_mean: reports.iter().map(|r| r.lw_mean).sum::<f64>() / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;

    fn native_solver(config: SystemConfig, opts: SolveOptions) -> Meliso {
        Meliso::with_backend(config, opts, Arc::new(NativeBackend::new()))
    }

    #[test]
    fn solve_dense_roundtrip() {
        let a = Matrix::standard_normal(64, 64, 1);
        let x = Vector::standard_normal(64, 2);
        let solver = native_solver(
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let report = solver.solve(&a, &x).unwrap();
        assert!(report.rel_err_l2 < 0.1);
        assert_eq!(report.y.len(), 64);
    }

    #[test]
    fn replicate_varies_seeds() {
        let a = Matrix::standard_normal(32, 32, 3);
        let x = Vector::standard_normal(32, 4);
        let solver = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        let src = DenseSource::new(a);
        let reports = solver.replicate(&src, &x, 3).unwrap();
        assert_eq!(reports.len(), 3);
        // Different seeds -> different noise draws -> different errors.
        assert_ne!(reports[0].rel_err_l2, reports[1].rel_err_l2);
        let summary = ReplicationSummary::from_reports(&reports);
        assert!(summary.rel_err_l2 > 0.0);
        assert_eq!(summary.reps, 3);
    }

    #[test]
    fn pjrt_missing_artifacts_is_clean_error() {
        std::env::set_var("MELISO_ARTIFACTS", "/nonexistent-dir");
        let r = Meliso::new(SystemConfig::single_mca(32), SolveOptions::default());
        std::env::remove_var("MELISO_ARTIFACTS");
        assert!(r.is_err());
        let msg = r.err().unwrap();
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
