//! Public solver API (the MelisoPy-equivalent front door, DESIGN.md S11).
//!
//! ```
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("iperturb66").unwrap();
//! let x = Vector::standard_normal(a.ncols(), 1);
//! let solver = Meliso::new(
//!     SystemConfig::single_mca(128),
//!     SolveOptions::default().with_backend(BackendKind::Native),
//! )
//! .unwrap();
//! let report = solver.solve_source(a.as_ref(), &x).unwrap();
//! assert!(report.rel_err_l2 < 0.5);
//! ```

use crate::config::{BackendKind, SolveOptions, SystemConfig};
use crate::coordinator;
use crate::iterative::{self, IterOptions};
use crate::linalg::{Matrix, Vector};
use crate::matrices::{DenseSource, MatrixSource};
use crate::metrics::{ConvergenceReport, SolveReport};
use crate::plane::{PlaneError, PlaneHandle};
use crate::runtime::native::NativeBackend;
use crate::runtime::pjrt::default_artifact_dir;
use crate::runtime::service::PjrtBackend;
use crate::runtime::Backend;
use crate::server::{MvmOperator, Session};
use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Why a front-door solver call failed.
///
/// Plane-level failures carry the full [`PlaneError`] so embedders can
/// match on the cause (stale operand vs. capacity vs. dead shard);
/// `From<MelisoError> for String` keeps string-typed callers (the CLI)
/// working through `?`.
#[derive(Debug, Clone, PartialEq)]
pub enum MelisoError {
    /// The execution plane refused or failed the operation.
    Plane(PlaneError),
    /// The runtime backend could not be brought up (missing PJRT
    /// artifacts, service start failure).
    Backend(String),
    /// Caller-supplied arguments were rejected before touching the grid.
    InvalidInput(String),
    /// An iterative solve or replication sweep failed.
    Solver(String),
}

impl fmt::Display for MelisoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MelisoError::Plane(e) => write!(f, "{e}"),
            MelisoError::Backend(e) => write!(f, "{e}"),
            MelisoError::InvalidInput(e) => write!(f, "{e}"),
            MelisoError::Solver(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for MelisoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MelisoError::Plane(e) => Some(e),
            _ => None,
        }
    }
}

impl From<PlaneError> for MelisoError {
    fn from(e: PlaneError) -> MelisoError {
        MelisoError::Plane(e)
    }
}

impl From<MelisoError> for String {
    fn from(e: MelisoError) -> String {
        e.to_string()
    }
}

/// The MELISO+ solver: a configured multi-MCA system plus solve options.
pub struct Meliso {
    config: SystemConfig,
    opts: SolveOptions,
    backend: Backend,
}

impl Meliso {
    /// Build a solver; starts the PJRT runtime service when requested
    /// (set `MELISO_ARTIFACTS` to point elsewhere than `./artifacts`).
    pub fn new(config: SystemConfig, opts: SolveOptions) -> Result<Meliso, MelisoError> {
        let dir = default_artifact_dir();
        Meliso::new_with_artifacts(config, opts, &dir)
    }

    /// Build a solver with an explicit artifact directory (no environment
    /// lookup — embedders and tests pass the path directly).
    pub fn new_with_artifacts(
        config: SystemConfig,
        opts: SolveOptions,
        dir: &Path,
    ) -> Result<Meliso, MelisoError> {
        let backend: Backend = match opts.backend {
            BackendKind::Native => Arc::new(NativeBackend::new()),
            BackendKind::Pjrt => match PjrtBackend::start(dir) {
                Ok(b) => Arc::new(b),
                Err(e) => {
                    return Err(MelisoError::Backend(format!(
                        "failed to start PJRT runtime from {} ({e}); run `make artifacts` \
                         or use the native backend",
                        dir.display()
                    )))
                }
            },
        };
        Ok(Meliso {
            config,
            opts,
            backend,
        })
    }

    /// Build with an explicit backend (tests, ablations).
    pub fn with_backend(config: SystemConfig, opts: SolveOptions, backend: Backend) -> Meliso {
        Meliso {
            config,
            opts,
            backend,
        }
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    pub fn config(&self) -> &SystemConfig {
        &self.config
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Override solve options (builder style).
    pub fn reconfigure(mut self, opts: SolveOptions) -> Meliso {
        self.opts = opts;
        self
    }

    /// Solve `Ax = b` in-memory for a streamable operand (one-shot: a
    /// fresh [`crate::plane::ExecutionPlane`] programs, executes and tears
    /// down).  With `opts.ground_truth` unset, the O(m·n) exact reference
    /// is skipped and `rel_err_*` are NaN — the at-scale mode for
    /// operands like `banded65k`.
    pub fn solve_source(
        &self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, MelisoError> {
        Ok(coordinator::solve_distributed(
            source,
            x,
            &self.config,
            &self.opts,
            self.backend.clone(),
        )?)
    }

    /// Convenience for dense in-memory operands.
    pub fn solve(&self, a: &Matrix, x: &Vector) -> Result<SolveReport, MelisoError> {
        let src = DenseSource::new(a.clone());
        self.solve_source(&src, x)
    }

    /// Open a resident serving session on a fresh dedicated plane:
    /// program `source` onto the grid once, then serve unlimited `solve` /
    /// `solve_batch` calls against it (see [`crate::server`]).  The
    /// expensive write–verify pass is paid here; per-solve cost drops to
    /// input-vector encodes plus reads.  To host several operands on one
    /// shard pool, use [`build_plane`](Self::build_plane) +
    /// [`open_session_on`](Self::open_session_on) instead.
    ///
    /// ```
    /// use meliso::prelude::*;
    ///
    /// let a = meliso::matrices::registry::build("iperturb66").unwrap();
    /// let solver = Meliso::new(
    ///     SystemConfig::single_mca(128),
    ///     SolveOptions::default().with_backend(BackendKind::Native),
    /// )
    /// .unwrap();
    /// let session = solver.open_session(a.clone()).unwrap(); // programs here
    /// let out = session.solve(&Vector::standard_normal(66, 9)).unwrap();
    /// assert_eq!(out.y.len(), 66);
    /// ```
    pub fn open_session(&self, source: Arc<dyn MatrixSource>) -> Result<Session, MelisoError> {
        Ok(Session::open(
            source,
            self.config,
            self.opts.clone(),
            self.backend.clone(),
        )?)
    }

    /// Build a shared multi-tenant execution plane sized for `source`'s
    /// chunk plan and return its clone-able [`PlaneHandle`].  Program any
    /// number of operands onto it with
    /// [`open_session_on`](Self::open_session_on) (or
    /// [`PlaneHandle::program`] directly) — they serve interleaved,
    /// *concurrent* batches from one shard pool, bit-identical to
    /// dedicated planes.
    pub fn build_plane(&self, source: &dyn MatrixSource) -> Result<PlaneHandle, MelisoError> {
        Ok(PlaneHandle::build(
            source,
            &self.config,
            &self.opts,
            self.backend.clone(),
        )?)
    }

    /// Open a resident serving session as a residency on an existing
    /// shared plane (see [`build_plane`](Self::build_plane)).
    pub fn open_session_on(
        &self,
        plane: &PlaneHandle,
        source: Arc<dyn MatrixSource>,
    ) -> Result<Session, MelisoError> {
        Ok(Session::open_on(plane.clone(), source)?)
    }

    /// Solve the linear **system** `Ax = b` with an iterative method whose
    /// every matrix–vector product is served by a resident crossbar
    /// session: `A` is write–verified onto the grid exactly once, then all
    /// solver iterations are read-only (see [`crate::iterative`]).
    ///
    /// Residual bookkeeping is exact f64 on the host, and iterative
    /// refinement (enabled by default through
    /// [`IterOptions::max_refinements`]) lets low-precision devices reach
    /// tolerances far below their per-MVM error floor.
    ///
    /// ```
    /// use meliso::prelude::*;
    ///
    /// let a = meliso::matrices::registry::build("spd64").unwrap();
    /// let b = a.matvec(&Vector::standard_normal(a.ncols(), 7));
    /// let opts = SolveOptions::default()
    ///     .with_device(Material::EpiRam)
    ///     .with_wv_iters(4)
    ///     .with_backend(BackendKind::Native);
    /// let solver = Meliso::new(SystemConfig::single_mca(64), opts).unwrap();
    /// let report = solver
    ///     .solve_system(a, &b, &IterOptions::default().with_method(Method::Cg))
    ///     .unwrap();
    /// assert!(report.converged && report.rel_residual <= 1e-6);
    /// ```
    pub fn solve_system(
        &self,
        source: Arc<dyn MatrixSource>,
        b: &Vector,
        iter_opts: &IterOptions,
    ) -> Result<ConvergenceReport, MelisoError> {
        // Validate before programming: opening a session pays the full
        // write–verify pass, which a bad input must not trigger.
        if source.nrows() != source.ncols() {
            return Err(MelisoError::InvalidInput(format!(
                "iterative methods need a square operand, got {}x{}",
                source.nrows(),
                source.ncols()
            )));
        }
        if b.len() != source.ncols() {
            return Err(MelisoError::InvalidInput(format!(
                "b has length {}, A is {}x{}",
                b.len(),
                source.nrows(),
                source.ncols()
            )));
        }
        // meliso-lint: allow(clock) -- solve wall-clock for the report, not for results
        let start = std::time::Instant::now();
        let session = self.open_session(source.clone())?;
        let outcome = iterative::solve_system(&session, Some(source.as_ref()), b, iter_opts)
            .map_err(MelisoError::Solver)?;
        let program = session.program_report();
        let serving = session.report();
        Ok(ConvergenceReport {
            method: iter_opts.method.to_string(),
            x: outcome.x,
            converged: outcome.converged,
            tol: iter_opts.tol,
            rel_residual: outcome.rel_residual,
            iterations: outcome.iterations,
            refinements: outcome.refinements,
            mvms: outcome.mvms,
            residual_history: outcome.history,
            programming_passes: session.programming_passes(),
            program_energy_j: program.write_energy_j,
            solve_write_energy_j: serving.solve_write_energy_j,
            read_energy_j: serving.solve_read_energy_j,
            wall_seconds: start.elapsed().as_secs_f64(),
        })
    }

    /// Per-replication seed: the same derivation whether replications run
    /// serially or in parallel.
    fn replication_seed(&self, r: usize) -> u64 {
        self.opts
            .seed
            .wrapping_add((r as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Run `reps` independent replications (fresh seeds) and return all
    /// reports — the paper averages 100 replications per cell of Table 1.
    ///
    /// Replications are embarrassingly parallel, so they fan out over up
    /// to `opts.workers` scoped threads; each replication's seed is a pure
    /// function of its index, so the returned reports are identical to a
    /// serial run.
    pub fn replicate(
        &self,
        source: &dyn MatrixSource,
        x: &Vector,
        reps: usize,
    ) -> Result<Vec<SolveReport>, MelisoError> {
        if reps == 0 {
            return Ok(Vec::new());
        }
        let lanes = self.opts.workers.max(1).min(reps);
        // Keep the total thread budget at ~opts.workers: each lane's inner
        // coordinator gets a proportional share (results are worker-count
        // independent, so this cannot change any report).
        let inner_workers = (self.opts.workers.max(1) / lanes).max(1);
        let solve_rep = |r: usize, workers: usize| {
            let mut opts = self.opts.clone();
            opts.seed = self.replication_seed(r);
            opts.workers = workers;
            coordinator::solve_distributed(source, x, &self.config, &opts, self.backend.clone())
        };
        if lanes <= 1 {
            let mut reports = Vec::with_capacity(reps);
            for r in 0..reps {
                reports.push(solve_rep(r, self.opts.workers)?);
            }
            return Ok(reports);
        }
        let mut slots: Vec<Option<Result<SolveReport, PlaneError>>> =
            std::iter::repeat_with(|| None).take(reps).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(lanes);
            for lane in 0..lanes {
                let solve_rep = &solve_rep;
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut r = lane;
                    while r < reps {
                        out.push((r, solve_rep(r, inner_workers)));
                        r += lanes;
                    }
                    out
                }));
            }
            for h in handles {
                if let Ok(pairs) = h.join() {
                    for (r, res) in pairs {
                        slots[r] = Some(res);
                    }
                }
            }
        });
        let mut reports = Vec::with_capacity(reps);
        for (r, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(report)) => reports.push(report),
                Some(Err(e)) => {
                    return Err(MelisoError::Solver(format!("replication {r}: {e}")))
                }
                None => {
                    return Err(MelisoError::Solver(format!(
                        "replication {r} worker panicked"
                    )))
                }
            }
        }
        Ok(reports)
    }
}

/// Summary statistics over replications (mean of each reported metric).
pub struct ReplicationSummary {
    pub reps: usize,
    pub rel_err_l2: f64,
    pub rel_err_inf: f64,
    pub ew_mean: f64,
    pub lw_mean: f64,
}

impl ReplicationSummary {
    pub fn from_reports(reports: &[SolveReport]) -> ReplicationSummary {
        let n = reports.len().max(1) as f64;
        ReplicationSummary {
            reps: reports.len(),
            rel_err_l2: reports.iter().map(|r| r.rel_err_l2).sum::<f64>() / n,
            rel_err_inf: reports.iter().map(|r| r.rel_err_inf).sum::<f64>() / n,
            ew_mean: reports.iter().map(|r| r.ew_mean).sum::<f64>() / n,
            lw_mean: reports.iter().map(|r| r.lw_mean).sum::<f64>() / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;

    fn native_solver(config: SystemConfig, opts: SolveOptions) -> Meliso {
        Meliso::with_backend(config, opts, Arc::new(NativeBackend::new()))
    }

    #[test]
    fn solve_dense_roundtrip() {
        let a = Matrix::standard_normal(64, 64, 1);
        let x = Vector::standard_normal(64, 2);
        let solver = native_solver(
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let report = solver.solve(&a, &x).unwrap();
        assert!(report.rel_err_l2 < 0.1);
        assert_eq!(report.y.len(), 64);
    }

    #[test]
    fn replicate_varies_seeds() {
        let a = Matrix::standard_normal(32, 32, 3);
        let x = Vector::standard_normal(32, 4);
        let solver = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        let src = DenseSource::new(a);
        let reports = solver.replicate(&src, &x, 3).unwrap();
        assert_eq!(reports.len(), 3);
        // Different seeds -> different noise draws -> different errors.
        assert_ne!(reports[0].rel_err_l2, reports[1].rel_err_l2);
        let summary = ReplicationSummary::from_reports(&reports);
        assert!(summary.rel_err_l2 > 0.0);
        assert_eq!(summary.reps, 3);
    }

    #[test]
    fn pjrt_missing_artifacts_is_clean_error() {
        // Pass the artifact dir explicitly: mutating MELISO_ARTIFACTS via
        // set_var/remove_var under the parallel test runner races with any
        // concurrent test that reads the environment.
        let r = Meliso::new_with_artifacts(
            SystemConfig::single_mca(32),
            SolveOptions::default(),
            Path::new("/nonexistent-dir"),
        );
        assert!(r.is_err());
        let err = r.err().unwrap();
        assert!(
            matches!(err, MelisoError::Backend(_)),
            "expected Backend error, got {err:?}"
        );
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn open_session_front_door() {
        let a = Matrix::standard_normal(32, 32, 5);
        let x = Vector::standard_normal(32, 6);
        let solver = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a.clone()));
        let session = solver.open_session(src).unwrap();
        let out = session.solve(&x).unwrap();
        let b = a.matvec(&x);
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
        assert_eq!(session.report().solves, 1);
    }

    #[test]
    fn shared_plane_sessions_via_front_door() {
        let a = Matrix::standard_normal(32, 32, 7);
        let c = Matrix::standard_normal(32, 32, 8);
        let solver = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let src_a: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a.clone()));
        let src_c: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(c.clone()));
        let plane = solver.build_plane(src_a.as_ref()).unwrap();
        let sa = solver.open_session_on(&plane, src_a).unwrap();
        let sc = solver.open_session_on(&plane, src_c).unwrap();
        assert_eq!(plane.resident_operands(), 2);
        let x = Vector::standard_normal(32, 9);
        let ba = a.matvec(&x);
        let ya = sa.solve(&x).unwrap().y;
        assert!(ya.sub(&ba).norm_l2() / ba.norm_l2() < 0.1);
        let bc = c.matvec(&x);
        let yc = sc.solve(&x).unwrap().y;
        assert!(yc.sub(&bc).norm_l2() / bc.norm_l2() < 0.1);
    }

    #[test]
    fn solve_system_cg_refines_past_device_floor() {
        use crate::iterative::Method;
        let source = crate::matrices::registry::build("spd64").unwrap();
        let x_star = Vector::standard_normal(64, 21);
        let b = source.matvec(&x_star);
        let solver = native_solver(
            SystemConfig::single_mca(64),
            SolveOptions::default()
                .with_device(Material::EpiRam)
                .with_wv_iters(3)
                .with_workers(2)
                .with_seed(42),
        );
        let opts = IterOptions::default()
            .with_method(Method::Cg)
            .with_tol(1e-4)
            .with_max_iters(40)
            .with_inner_tol(1e-2)
            .with_refinements(30);
        let report = solver.solve_system(source, &b, &opts).unwrap();
        assert!(
            report.converged,
            "rel {} after {} refinements",
            report.rel_residual, report.refinements
        );
        assert!(report.rel_residual <= 1e-4);
        // One programming pass for the whole solve, many read-only MVMs.
        assert_eq!(report.programming_passes, 1);
        assert!(report.mvms > 0);
        assert!(report.program_energy_j > 0.0);
        // The exact outer residuals improve from start to finish.
        assert!(report.residual_history.first().unwrap() > report.residual_history.last().unwrap());
        // And the true solution error tracks the residual on a κ=20 operand.
        let err = report.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-2, "{err}");
    }

    #[test]
    fn solve_system_gmres_on_nonsymmetric() {
        use crate::iterative::Method;
        let source = crate::matrices::registry::build("nonsym64").unwrap();
        let x_star = Vector::standard_normal(64, 23);
        let b = source.matvec(&x_star);
        let solver = native_solver(
            SystemConfig::single_mca(64),
            SolveOptions::default()
                .with_device(Material::EpiRam)
                .with_wv_iters(3)
                .with_workers(2)
                .with_seed(7),
        );
        let opts = IterOptions::default()
            .with_method(Method::Gmres)
            .with_tol(1e-3)
            .with_max_iters(48)
            .with_restart(24)
            .with_inner_tol(1e-2)
            .with_refinements(30);
        let report = solver.solve_system(source, &b, &opts).unwrap();
        assert!(
            report.converged,
            "rel {} after {} refinements",
            report.rel_residual, report.refinements
        );
        assert_eq!(report.programming_passes, 1);
    }

    #[test]
    fn solve_system_rejects_rectangular_operand() {
        let a = Matrix::standard_normal(16, 8, 25);
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        let solver = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let b = Vector::standard_normal(8, 26);
        let err = solver
            .solve_system(src, &b, &IterOptions::default())
            .unwrap_err();
        assert!(
            matches!(err, MelisoError::InvalidInput(_)),
            "expected InvalidInput, got {err:?}"
        );
        assert!(err.to_string().contains("square"), "{err}");
    }

    #[test]
    fn plane_errors_surface_through_the_front_door() {
        // An unsupported cell size is a plane-level refusal and must
        // arrive as MelisoError::Plane with the inner cause intact.
        let a = Matrix::standard_normal(16, 16, 27);
        let solver = native_solver(SystemConfig::single_mca(48), SolveOptions::default());
        let src = DenseSource::new(a);
        let err = solver.build_plane(&src).unwrap_err();
        assert!(
            matches!(
                err,
                MelisoError::Plane(PlaneError::UnsupportedCell { cell: 48, .. })
            ),
            "{err:?}"
        );
        assert!(err.to_string().contains("cell size 48"), "{err}");
        // And std::error::Error::source exposes the plane cause.
        use std::error::Error;
        assert!(err.source().is_some());
    }

    #[test]
    fn parallel_replicate_matches_serial() {
        let a = Matrix::standard_normal(32, 32, 7);
        let x = Vector::standard_normal(32, 8);
        let src = DenseSource::new(a);
        // workers=1 forces the serial path; workers=4 fans out — the
        // per-replication seeds are index-derived, so reports must agree.
        let serial = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_workers(1),
        )
        .replicate(&src, &x, 4)
        .unwrap();
        let parallel = native_solver(
            SystemConfig::single_mca(32),
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_workers(4),
        )
        .replicate(&src, &x, 4)
        .unwrap();
        assert_eq!(serial.len(), 4);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.y, p.y);
            assert_eq!(s.rel_err_l2, p.rel_err_l2);
        }
    }
}
