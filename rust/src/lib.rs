//! # MELISO+ — In-Memory Linear Solver
//!
//! A full-stack, distributed framework for energy-efficient RRAM in-memory
//! computing with integrated two-tier error correction, reproducing
//! *"Harnessing the Full Potential of RRAMs through Scalable and Distributed
//! In-Memory Computing with Integrated Error Correction"* (CS.DC 2025).
//!
//! The full paper-concept → module tour (and the life of a solve through
//! both execution paths) lives in `docs/ARCHITECTURE.md`; the short map:
//!
//! ## Architecture (four layers)
//!
//! * **L3 (this crate)** — the coordinator: RRAM device & crossbar (MCA)
//!   simulation, `adjustableWriteandVerify` programming protocols, the
//!   virtualization layer (zero-padding / block partitioning / chunk
//!   scheduling / address mapping), energy & latency accounting, metrics,
//!   CLI and config.
//! * **Execution plane** — [`plane`]: the single sharded scatter/gather
//!   runtime behind both one-shot solves and resident sessions, served
//!   through the clone-able [`plane::PlaneHandle`] (every admission method
//!   takes `&self`, so concurrent clients share one shard pool and fail
//!   with typed [`plane::PlaneError`]s).  A [`plane::PlacementPolicy`]
//!   groups MCAs into long-lived shard threads, the leader streams
//!   occupied chunks through the sparsity-aware
//!   [`virtualization::ChunkPlan::nonzero_chunks`] enumeration with
//!   double-buffered extraction (chunk `N + 1` extracts while chunk `N`
//!   dispatches — a 65,536² banded operand solves without ever
//!   materializing densely), batch workers steal whole MCAs from each
//!   other when irregular sparsity unbalances their queues, and results
//!   reduce in deterministic chunk order, bit-reproducible for a fixed
//!   seed across shard counts, placement policies, concurrency levels and
//!   steal orders.
//! * **Serving layer** — [`server`]: program-once / solve-many resident
//!   crossbar sessions ([`server::Session`]) with batched MVM, long-lived
//!   worker pools, an LRU operand cache for multi-tenant residency
//!   ([`server::OperandCache`]), and throughput/latency/energy serving
//!   metrics ([`metrics::serving`]).  This is the request path for
//!   repeated solves against the same operand — the conductance write is
//!   paid once, each solve costs only input encodes and reads.
//! * **Solver layer** — [`iterative`]: Jacobi/Richardson, CG and
//!   GMRES(m) solvers for `Ax = b` whose every MVM is served by a
//!   resident session through the backend-agnostic
//!   [`server::MvmOperator`] trait, with exact f64 residual bookkeeping
//!   and iterative refinement ([`solver::Meliso::solve_system`]).
//! * **L2/L1 (python/compile, build-time only)** — the JAX compute graph and
//!   Pallas crossbar kernels, AOT-lowered to HLO-text artifacts.
//! * **Runtime bridge** — [`runtime`] loads `artifacts/*.hlo.txt` through the
//!   PJRT CPU client (`xla` crate, behind the `pjrt` feature) and executes
//!   them on the request path.  Python never runs at request time.
//!
//! ## Module index
//!
//! | module | role |
//! |---|---|
//! | [`bench`] | in-house benchmark harness (warmup, robust stats, JSON emission) |
//! | [`cli`] | hand-rolled argv parser behind the `meliso` binary |
//! | [`config`] | [`config::SystemConfig`] / [`config::SolveOptions`], minimal-TOML loading |
//! | [`coordinator`] | thin facade over the one-shot plane path (historic entry point) |
//! | [`device`] | RRAM material models, pulse physics, extended non-idealities |
//! | [`ec`] | two-tier error correction and the per-tile [`ec::TileExecutor`] |
//! | [`iterative`] | Jacobi/Richardson/CG/GMRES over resident sessions + refinement |
//! | [`linalg`] | dense [`linalg::Matrix`]/[`linalg::Vector`], LU, Krylov workspaces |
//! | [`matrices`] | operand substrate: [`matrices::MatrixSource`], [`matrices::BandedSource`], [`matrices::sparse::CsrSource`], generators, Matrix-Market IO, the named [`matrices::registry`] |
//! | [`mca`] | multi-crossbar-array simulation: write–verify, energy ledgers |
//! | [`metrics`] | solve/serving/convergence reports, error norms, tables |
//! | [`obs`] | observability: process-wide metrics registry + flight recorder, Prometheus/Chrome-trace export, the `meliso status` surface |
//! | [`plane`] | the sharded execution plane behind [`plane::PlaneHandle`]: placement, dispatch, work stealing, supervised gathers, multi-operand residency |
//! | [`runtime`] | execution backends: pure-Rust native twin, PJRT artifact engine |
//! | [`serve`] | the network front door: std-only HTTP server, cross-client request coalescing, admission control (`meliso serve`) |
//! | [`server`] | resident [`server::Session`]s, [`server::OperandCache`], serving metrics |
//! | [`solver`] | the [`solver::Meliso`] front door: one-shot, sessions, `Ax = b` |
//! | [`testing`] | property-test mini-framework and fault-injection helpers |
//! | [`util`] | vendored substrates: rng, json, toml, logging |
//! | [`virtualization`] | chunk planning: [`virtualization::ChunkPlan`], geometry, sparsity-aware enumeration |
//!
//! ## Quickstart (one-shot)
//!
//! ```
//! use meliso::prelude::*;
//!
//! let matrix = meliso::matrices::registry::build("iperturb66").unwrap();
//! let x = Vector::standard_normal(matrix.ncols(), 7);
//! let cfg = SolveOptions::default()
//!     .with_device(Material::TaOxHfOx)
//!     .with_ec(true)
//!     .with_backend(BackendKind::Native);
//! let report = Meliso::new(SystemConfig::single_mca(128), cfg).unwrap()
//!     .solve_source(matrix.as_ref(), &x).unwrap();
//! assert!(report.rel_err_l2 < 0.5);
//! ```
//!
//! ## Quickstart (resident session, program once / solve many)
//!
//! ```
//! use meliso::prelude::*;
//!
//! let matrix = meliso::matrices::registry::build("iperturb66").unwrap();
//! let opts = SolveOptions::default().with_backend(BackendKind::Native);
//! let solver = Meliso::new(SystemConfig::single_mca(128), opts).unwrap();
//! let session = solver.open_session(matrix.clone()).unwrap();   // write-verify once
//! for seed in 0..8 {
//!     let x = Vector::standard_normal(matrix.ncols(), seed);
//!     let out = session.solve(&x).unwrap();                     // reads only
//!     assert_eq!(out.y.len(), matrix.nrows());
//! }
//! assert_eq!(session.report().solves, 8);
//! ```
//!
//! ## Quickstart (one plane, many tenants, concurrent batches)
//!
//! A [`plane::PlaneHandle`] is clone-able and every admission method takes
//! `&self`, so sessions for different operands share one shard pool and
//! solve concurrently — results stay bit-identical to dedicated planes:
//!
//! ```
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("iperturb66").unwrap();
//! let b = meliso::matrices::registry::build("spd64").unwrap();
//! let opts = SolveOptions::default()
//!     .with_workers(2)
//!     .with_backend(BackendKind::Native);
//! let solver = Meliso::new(SystemConfig::new(2, 2, 64), opts).unwrap();
//! let plane = solver.build_plane(a.as_ref()).unwrap();          // one shard pool
//! let sa = solver.open_session_on(&plane, a.clone()).unwrap();  // residency 1
//! let sb = solver.open_session_on(&plane, b.clone()).unwrap();  // residency 2
//! std::thread::scope(|s| {
//!     s.spawn(|| sa.solve(&Vector::standard_normal(a.ncols(), 1)).unwrap());
//!     s.spawn(|| sb.solve(&Vector::standard_normal(b.ncols(), 2)).unwrap());
//! });
//! assert_eq!(plane.resident_operands(), 2);
//! ```
//!
//! ## Quickstart (solving Ax = b iteratively)
//!
//! Every Krylov iteration is one in-memory MVM against the resident
//! operand — the write–verify pass is paid once for the whole solve, and
//! exact f64 host-side refinement drives the residual far below the
//! device's per-MVM error floor (see [`iterative`]):
//!
//! ```
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("spd64").unwrap();
//! let b = a.matvec(&Vector::standard_normal(a.ncols(), 7));
//! let opts = SolveOptions::default()
//!     .with_device(Material::EpiRam)
//!     .with_wv_iters(4)
//!     .with_backend(BackendKind::Native);
//! let solver = Meliso::new(SystemConfig::single_mca(64), opts).unwrap();
//! let report = solver
//!     .solve_system(a, &b, &IterOptions::default().with_method(Method::Cg))
//!     .unwrap();
//! assert!(report.converged && report.programming_passes == 1);
//! ```
//!
//! ## Quickstart (real sparse operands)
//!
//! Irregular sparsity — a Matrix-Market file or a procedural CSR pattern —
//! runs the same paths, with planning and dispatch restricted to the
//! occupied chunks ([`matrices::sparse`]):
//!
//! ```no_run
//! use meliso::prelude::*;
//!
//! // Registry route: `mtx:<path>` (or any name ending in `.mtx`).
//! let a = meliso::matrices::registry::build("mtx:data/operand.mtx").unwrap();
//! let opts = SolveOptions::default()
//!     .with_placement(Placement::SparsityAware)
//!     .with_backend(BackendKind::Native);
//! let solver = Meliso::new(SystemConfig::new(4, 4, 256), opts).unwrap();
//! let b = a.matvec(&Vector::standard_normal(a.ncols(), 1));
//! let report = solver.solve_system(a, &b, &IterOptions::default()).unwrap();
//! assert!(report.converged);
//! ```

#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod ec;
pub mod iterative;
pub mod linalg;
pub mod matrices;
pub mod mca;
pub mod metrics;
pub mod obs;
pub mod plane;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod solver;
pub mod testing;
pub mod util;
pub mod virtualization;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{BackendKind, SolveOptions, SystemConfig};
    pub use crate::device::materials::Material;
    pub use crate::ec::DenoiseMode;
    pub use crate::iterative::{IterOptions, Method, MvmOperator};
    pub use crate::linalg::{Matrix, Vector};
    pub use crate::matrices::CsrSource;
    pub use crate::metrics::{ConvergenceReport, SolveReport};
    pub use crate::plane::{ExecutionPlane, OperandId, Placement, PlaneError, PlaneHandle};
    pub use crate::server::Session;
    pub use crate::solver::{Meliso, MelisoError};
}
