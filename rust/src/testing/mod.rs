//! Mini property-based testing framework (proptest stand-in, DESIGN.md S14).
//!
//! A [`PropRunner`] drives a closure over many generated cases from a
//! deterministic seed; on failure it reports the case index and seed so the
//! exact case replays.  Generation helpers cover the domains the MELISO+
//! invariants quantify over (dims, scales, materials, geometries).

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct PropRunner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropRunner {
    fn default() -> Self {
        PropRunner {
            cases: 32,
            seed: 0xC0FFEE,
        }
    }
}

impl PropRunner {
    pub fn new(cases: usize, seed: u64) -> PropRunner {
        PropRunner { cases, seed }
    }

    /// Run `property` over `cases` generated inputs.  The closure receives
    /// a per-case RNG and the case index; it returns `Err(msg)` to fail.
    ///
    /// Panics with a replayable diagnostic on the first failure.
    pub fn run<F>(&self, name: &str, mut property: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        let mut root = Rng::new(self.seed);
        for case in 0..self.cases {
            let mut case_rng = root.fork(case as u64);
            if let Err(msg) = property(&mut case_rng, case) {
                panic!(
                    "property {name:?} failed at case {case}/{} (seed {:#x}): {msg}",
                    self.cases, self.seed
                );
            }
        }
    }
}

/// Generation helpers.
pub mod gen {
    use crate::device::materials::Material;
    use crate::linalg::{Matrix, Vector};
    use crate::util::rng::Rng;

    /// Uniform choice from a slice.
    pub fn choice<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
        &items[rng.below(items.len())]
    }

    /// Dimension that is a multiple of `step`, in `[step, max]`.
    pub fn dim_multiple(rng: &mut Rng, step: usize, max: usize) -> usize {
        let k = 1 + rng.below(max / step);
        k * step
    }

    /// Random material.
    pub fn material(rng: &mut Rng) -> Material {
        *choice(rng, &Material::ALL)
    }

    /// Matrix with entries scaled by a magnitude drawn from a log-uniform
    /// range (exercises the conductance-scaling logic).
    pub fn scaled_matrix(rng: &mut Rng, n: usize) -> Matrix {
        let log_scale = rng.uniform_range(-3.0, 4.0);
        let scale = 10f64.powf(log_scale);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, scale * rng.normal());
            }
        }
        m
    }

    /// Standard-normal vector.
    pub fn vector(rng: &mut Rng, n: usize) -> Vector {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Vector::from_vec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_passes_trivial_property() {
        PropRunner::new(16, 1).run("trivial", |rng, _| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {u}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed at case 0")]
    fn runner_reports_failures() {
        PropRunner::new(4, 2).run("always-fails", |_, _| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let collect = |seed| {
            let mut root = Rng::new(seed);
            let mut rng = root.fork(0);
            gen::scaled_matrix(&mut rng, 4).data().to_vec()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
    }

    #[test]
    fn dim_multiple_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let d = gen::dim_multiple(&mut rng, 8, 64);
            assert!(d % 8 == 0 && (8..=64).contains(&d));
        }
    }
}
