//! Mini property-based testing framework (proptest stand-in, DESIGN.md S14).
//!
//! A [`PropRunner`] drives a closure over many generated cases from a
//! deterministic seed; on failure it reports the case index and seed so the
//! exact case replays.  Generation helpers cover the domains the MELISO+
//! invariants quantify over (dims, scales, materials, geometries).

use crate::util::rng::Rng;

pub mod sched;

/// Configuration for a property run.
pub struct PropRunner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropRunner {
    fn default() -> Self {
        PropRunner {
            cases: 32,
            seed: 0xC0FFEE,
        }
    }
}

impl PropRunner {
    pub fn new(cases: usize, seed: u64) -> PropRunner {
        PropRunner { cases, seed }
    }

    /// Run `property` over `cases` generated inputs.  The closure receives
    /// a per-case RNG and the case index; it returns `Err(msg)` to fail.
    ///
    /// Panics with a replayable diagnostic on the first failure.
    pub fn run<F>(&self, name: &str, mut property: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        let mut root = Rng::new(self.seed);
        for case in 0..self.cases {
            let mut case_rng = root.fork(case as u64);
            if let Err(msg) = property(&mut case_rng, case) {
                panic!(
                    "property {name:?} failed at case {case}/{} (seed {:#x}): {msg}",
                    self.cases, self.seed
                );
            }
        }
    }
}

/// Generation helpers.
pub mod gen {
    use crate::device::materials::Material;
    use crate::linalg::{Matrix, Vector};
    use crate::util::rng::Rng;

    /// Uniform choice from a slice.
    pub fn choice<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
        &items[rng.below(items.len())]
    }

    /// Dimension that is a multiple of `step`, in `[step, max]`.
    pub fn dim_multiple(rng: &mut Rng, step: usize, max: usize) -> usize {
        let k = 1 + rng.below(max / step);
        k * step
    }

    /// Random material.
    pub fn material(rng: &mut Rng) -> Material {
        *choice(rng, &Material::ALL)
    }

    /// Matrix with entries scaled by a magnitude drawn from a log-uniform
    /// range (exercises the conductance-scaling logic).
    pub fn scaled_matrix(rng: &mut Rng, n: usize) -> Matrix {
        let log_scale = rng.uniform_range(-3.0, 4.0);
        let scale = 10f64.powf(log_scale);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, scale * rng.normal());
            }
        }
        m
    }

    /// Standard-normal vector.
    pub fn vector(rng: &mut Rng, n: usize) -> Vector {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Vector::from_vec(v)
    }
}

/// Fault-injection doubles for the execution plane's supervision tests:
/// a matrix source whose `block` panics on a chosen chunk (leader-side
/// walk faults), a backend that panics mid-read (true shard-thread
/// panics), a backend that returns clean errors on demand (chunk-level
/// failures that must leave the plane serviceable), and a backend whose
/// reads park at a gate so a test can hold a batch in flight.
///
/// These live in the library (not `#[cfg(test)]`) so the
/// `fault_tolerance` integration suite and unit tests share one set of
/// poisons; they are never constructed on production paths.
pub mod faults {
    use crate::linalg::{Matrix, Vector};
    use crate::matrices::{DenseSource, MatrixSource};
    use crate::runtime::{EcMvmRequest, EcMvmResponse, ExecBackend};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex, PoisonError};

    /// A dense operand whose `block` **panics** when the extraction covers
    /// `poison = (row0, col0)` — simulates a corrupt chunk on the leader's
    /// streaming walk.
    pub struct PanicSource {
        inner: DenseSource,
        poison: (usize, usize),
    }

    impl PanicSource {
        /// Poison the chunk whose origin is `(row0, col0)`.
        pub fn new(matrix: Matrix, poison: (usize, usize)) -> PanicSource {
            PanicSource {
                inner: DenseSource::new(matrix),
                poison,
            }
        }
    }

    impl MatrixSource for PanicSource {
        fn nrows(&self) -> usize {
            self.inner.nrows()
        }

        fn ncols(&self) -> usize {
            self.inner.ncols()
        }

        fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
            let (pr, pc) = self.poison;
            if r0 <= pr && pr < r0 + h && c0 <= pc && pc < c0 + w {
                panic!("injected poisoned block at ({pr},{pc})");
            }
            self.inner.block(r0, c0, h, w)
        }

        fn matvec(&self, x: &Vector) -> Vector {
            self.inner.matvec(x)
        }

        fn max_abs(&self) -> f64 {
            self.inner.max_abs()
        }
    }

    /// Shared switch controlling an injected backend fault.
    #[derive(Clone)]
    pub struct FaultHandle(Arc<AtomicBool>);

    impl FaultHandle {
        /// Arm (`true`) or disarm (`false`) the fault for subsequent reads.
        pub fn fail_next_reads(&self, armed: bool) {
            self.0.store(armed, Ordering::SeqCst);
        }

        fn armed(&self) -> bool {
            self.0.load(Ordering::SeqCst)
        }
    }

    /// What an armed [`FaultBackend`] does on the next tile read.
    #[derive(Clone, Copy)]
    enum FaultMode {
        /// Return `Err("injected backend failure")` — a recoverable
        /// chunk-level failure: the plane must drain the batch cleanly and
        /// keep serving.
        Error,
        /// `panic!` inside the shard thread — the supervised gather must
        /// convert it into a clean error instead of hanging.
        Panic,
    }

    /// Backend wrapper that injects a fault into every tile read while
    /// armed; build with [`erroring`](FaultBackend::erroring) or
    /// [`panicking`](FaultBackend::panicking).
    pub struct FaultBackend<B: ExecBackend> {
        inner: B,
        handle: FaultHandle,
        mode: FaultMode,
    }

    impl<B: ExecBackend> FaultBackend<B> {
        fn with_mode(inner: B, mode: FaultMode) -> FaultBackend<B> {
            FaultBackend {
                inner,
                handle: FaultHandle(Arc::new(AtomicBool::new(false))),
                mode,
            }
        }

        /// Armed reads return a clean `Err`.
        pub fn erroring(inner: B) -> FaultBackend<B> {
            FaultBackend::with_mode(inner, FaultMode::Error)
        }

        /// Armed reads panic (a true shard-thread panic).
        pub fn panicking(inner: B) -> FaultBackend<B> {
            FaultBackend::with_mode(inner, FaultMode::Panic)
        }

        /// Arm from the start (builder style).
        pub fn armed(self) -> FaultBackend<B> {
            self.handle.fail_next_reads(true);
            self
        }

        pub fn handle(&self) -> FaultHandle {
            self.handle.clone()
        }

        fn check(&self, site: &str) -> Result<(), String> {
            if self.handle.armed() {
                match self.mode {
                    FaultMode::Error => return Err("injected backend failure".to_string()),
                    FaultMode::Panic => panic!("injected shard panic ({site})"),
                }
            }
            Ok(())
        }
    }

    impl<B: ExecBackend> ExecBackend for FaultBackend<B> {
        fn mvm(&self, n: usize, at: Vec<f32>, xt: Vec<f32>) -> Result<Vec<f32>, String> {
            self.check("mvm")?;
            self.inner.mvm(n, at, xt)
        }

        fn ec_mvm(&self, req: EcMvmRequest) -> Result<EcMvmResponse, String> {
            self.check("ec_mvm")?;
            self.inner.ec_mvm(req)
        }

        fn tile_sizes(&self) -> Vec<usize> {
            self.inner.tile_sizes()
        }

        fn name(&self) -> &'static str {
            "fault-injection"
        }
    }

    struct Gate {
        closed: Mutex<bool>,
        cv: Condvar,
        waiting: AtomicUsize,
    }

    /// Shared valve controlling a [`GateBackend`]: `close` makes every
    /// subsequent tile read block inside the backend until `open`.
    #[derive(Clone)]
    pub struct GateHandle(Arc<Gate>);

    impl GateHandle {
        /// Block subsequent reads until [`open`](GateHandle::open).
        pub fn close(&self) {
            *self.0.closed.lock().unwrap_or_else(PoisonError::into_inner) = true;
        }

        /// Release every blocked read and let new ones pass through.
        pub fn open(&self) {
            *self.0.closed.lock().unwrap_or_else(PoisonError::into_inner) = false;
            self.0.cv.notify_all();
        }

        /// Number of reads currently parked at the gate — poll this to know
        /// a concurrent batch has genuinely entered the backend.
        pub fn waiting(&self) -> usize {
            self.0.waiting.load(Ordering::SeqCst)
        }

        fn pass(&self) {
            let mut closed = self.0.closed.lock().unwrap_or_else(PoisonError::into_inner);
            if *closed {
                self.0.waiting.fetch_add(1, Ordering::SeqCst);
                while *closed {
                    closed = self
                        .0
                        .cv
                        .wait(closed)
                        .unwrap_or_else(PoisonError::into_inner);
                }
                self.0.waiting.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }

    /// Backend wrapper whose reads park at a [`GateHandle`] while it is
    /// closed — lets a test hold a batch demonstrably in flight (poll
    /// [`waiting`](GateHandle::waiting)), assert mid-flight behaviour, then
    /// release it.  The gate starts open, so programming passes through;
    /// close it only once the operand is resident.
    pub struct GateBackend<B: ExecBackend> {
        inner: B,
        gate: GateHandle,
    }

    impl<B: ExecBackend> GateBackend<B> {
        pub fn new(inner: B) -> GateBackend<B> {
            GateBackend {
                inner,
                gate: GateHandle(Arc::new(Gate {
                    closed: Mutex::new(false),
                    cv: Condvar::new(),
                    waiting: AtomicUsize::new(0),
                })),
            }
        }

        pub fn handle(&self) -> GateHandle {
            self.gate.clone()
        }
    }

    impl<B: ExecBackend> ExecBackend for GateBackend<B> {
        fn mvm(&self, n: usize, at: Vec<f32>, xt: Vec<f32>) -> Result<Vec<f32>, String> {
            self.gate.pass();
            self.inner.mvm(n, at, xt)
        }

        fn ec_mvm(&self, req: EcMvmRequest) -> Result<EcMvmResponse, String> {
            self.gate.pass();
            self.inner.ec_mvm(req)
        }

        fn tile_sizes(&self) -> Vec<usize> {
            self.inner.tile_sizes()
        }

        fn name(&self) -> &'static str {
            "gated"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_passes_trivial_property() {
        PropRunner::new(16, 1).run("trivial", |rng, _| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {u}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed at case 0")]
    fn runner_reports_failures() {
        PropRunner::new(4, 2).run("always-fails", |_, _| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let collect = |seed| {
            let mut root = Rng::new(seed);
            let mut rng = root.fork(0);
            gen::scaled_matrix(&mut rng, 4).data().to_vec()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
    }

    #[test]
    fn dim_multiple_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let d = gen::dim_multiple(&mut rng, 8, 64);
            assert!(d % 8 == 0 && (8..=64).contains(&d));
        }
    }
}
