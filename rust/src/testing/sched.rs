//! Exhaustive interleaving explorer: a vendored, std-only loom stand-in.
//!
//! The concurrency models in `rust/tests/loom_models.rs` need to check
//! invariants over **every** interleaving of a few modeled threads, not
//! just the ones a lucky scheduler happens to produce.  The `loom` crate
//! does this by hijacking `std::sync`; this repo builds hermetically (no
//! crates.io closure), so we model at one level up instead: a
//! [`Model`] is an explicit state machine whose `step(tid)` executes one
//! *atomic* action of thread `tid`, and [`explore`] drives a depth-first
//! search over all schedules with a visited-state set, checking the
//! model's invariant in every reachable state.
//!
//! What counts as one `step` is the modeling decision that makes this
//! sound: anything the real code does under one mutex guard or as one
//! `fetch_add` is one step; anything split across two atomic accesses
//! must be two steps.  The loom models exploit that both ways — the
//! faithful models (counter claims as single `fetch_add` steps,
//! admission check + inflight increment under one structural-lock step)
//! pass exhaustively, and deliberately *mis*-modeled variants (claim
//! split into read and write, admission check separated from the
//! increment) fail, proving the explorer finds the races the real
//! designs exclude.
//!
//! State spaces are deduplicated through a `BTreeSet`, so models must be
//! `Ord`; `max_states` caps runaway models with a clean error instead of
//! an OOM.

use std::collections::BTreeSet;

/// A concurrent system modeled as an explicit state machine.
///
/// `runnable` lists threads with a pending step; a state with no
/// runnable thread must satisfy [`is_done`](Model::is_done), otherwise
/// exploration reports a deadlock.
pub trait Model: Clone + Ord + std::fmt::Debug {
    /// Thread ids that can take a step in this state.
    fn runnable(&self) -> Vec<usize>;

    /// Execute one atomic action of thread `tid`.
    fn step(&mut self, tid: usize);

    /// Safety invariant, checked in **every** reachable state.
    fn invariant(&self) -> Result<(), String>;

    /// True when all modeled threads have terminated.
    fn is_done(&self) -> bool;

    /// Liveness/completeness check, run in every terminal state.
    fn final_check(&self) -> Result<(), String> {
        Ok(())
    }
}

/// Exploration statistics, for asserting a model actually covered a
/// non-trivial space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExploreReport {
    /// Distinct states visited.
    pub states: usize,
    /// Transitions executed (schedule edges).
    pub transitions: usize,
    /// Terminal states reached.
    pub finals: usize,
}

/// Exhaustively explore every interleaving reachable from `initial`.
///
/// Returns statistics on success; returns the first invariant violation,
/// final-check failure, deadlock, or state-space overflow as `Err`, with
/// the offending state rendered into the message.
pub fn explore<M: Model>(initial: M, max_states: usize) -> Result<ExploreReport, String> {
    let mut visited: BTreeSet<M> = BTreeSet::new();
    let mut stack: Vec<M> = vec![initial];
    let mut transitions = 0usize;
    let mut finals = 0usize;
    while let Some(state) = stack.pop() {
        if !visited.insert(state.clone()) {
            continue;
        }
        if visited.len() > max_states {
            return Err(format!(
                "state-space cap exceeded: more than {max_states} distinct states"
            ));
        }
        state
            .invariant()
            .map_err(|e| format!("invariant violated: {e}\nstate: {state:?}"))?;
        let runnable = state.runnable();
        if runnable.is_empty() {
            if !state.is_done() {
                return Err(format!("deadlock: nothing runnable\nstate: {state:?}"));
            }
            finals += 1;
            state
                .final_check()
                .map_err(|e| format!("final-state check failed: {e}\nstate: {state:?}"))?;
            continue;
        }
        for tid in runnable {
            let mut next = state.clone();
            next.step(tid);
            transitions += 1;
            stack.push(next);
        }
    }
    Ok(ExploreReport {
        states: visited.len(),
        transitions,
        finals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads incrementing a shared counter.  `atomic` models the
    /// increment as one step; the racy variant splits it into a read
    /// step and a write step, so some interleaving loses an update.
    #[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
    struct Counter {
        value: u8,
        /// Per-thread: 0 = before, 1 = mid (racy only, holds read), 2 = done.
        phase: Vec<(u8, u8)>,
        atomic: bool,
    }

    impl Counter {
        fn new(threads: usize, atomic: bool) -> Counter {
            Counter {
                value: 0,
                phase: vec![(0, 0); threads],
                atomic,
            }
        }
    }

    impl Model for Counter {
        fn runnable(&self) -> Vec<usize> {
            (0..self.phase.len())
                .filter(|&t| self.phase[t].0 != 2)
                .collect()
        }

        fn step(&mut self, tid: usize) {
            let (phase, held) = self.phase[tid];
            if self.atomic {
                self.value += 1;
                self.phase[tid] = (2, 0);
            } else if phase == 0 {
                self.phase[tid] = (1, self.value); // read
            } else {
                self.value = held + 1; // write (may clobber)
                self.phase[tid] = (2, 0);
            }
        }

        fn invariant(&self) -> Result<(), String> {
            Ok(())
        }

        fn is_done(&self) -> bool {
            self.phase.iter().all(|&(p, _)| p == 2)
        }

        fn final_check(&self) -> Result<(), String> {
            let want = self.phase.len() as u8;
            if self.value == want {
                Ok(())
            } else {
                Err(format!("lost update: {} != {want}", self.value))
            }
        }
    }

    #[test]
    fn atomic_counter_is_exact_in_all_interleavings() {
        let report = explore(Counter::new(3, true), 10_000).expect("atomic model");
        assert!(report.finals >= 1);
        assert!(report.states > 3, "trivial space: {report:?}");
    }

    #[test]
    fn explorer_finds_the_lost_update() {
        let err = explore(Counter::new(2, false), 10_000).unwrap_err();
        assert!(err.contains("lost update"), "{err}");
    }

    /// A state that is stuck but not done must be reported as deadlock.
    #[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
    struct Stuck;

    impl Model for Stuck {
        fn runnable(&self) -> Vec<usize> {
            Vec::new()
        }
        fn step(&mut self, _tid: usize) {}
        fn invariant(&self) -> Result<(), String> {
            Ok(())
        }
        fn is_done(&self) -> bool {
            false
        }
    }

    #[test]
    fn deadlocks_are_reported() {
        let err = explore(Stuck, 100).unwrap_err();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn state_cap_is_a_clean_error() {
        let err = explore(Counter::new(3, false), 4).unwrap_err();
        assert!(err.contains("state-space cap"), "{err}");
    }
}
