//! Calibrated material presets for the paper's four device systems.
//!
//! Sources for qualitative parameters: Ag-aSi (Jo et al., Nano Lett. 2010),
//! AlOx/HfO2 (Woo et al., EDL 2016), EpiRAM (Choi et al., Nat. Mater. 2018),
//! TaOx/HfOx (Wu et al., VLSI 2018).  Quantitative noise/pulse figures are
//! calibrated so the *no-EC* Table 1 (M1) magnitudes and the Fig 2/3/S1/S2
//! iteration shapes emerge from the simulator — see DESIGN.md §5.

use super::DeviceParams;

/// The four benchmarked material systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Material {
    /// Ag-aSi — slow, strongly nonlinear (2.4 / −4.88), moderate noise.
    AgASi,
    /// AlOx-HfO2 bilayer — mid energy, noisiest of the four.
    AlOxHfO2,
    /// EpiRAM (SiGe epitaxial) — the high-accuracy, high-energy benchmark.
    EpiRam,
    /// TaOx-HfOx — low precision but ultra-low energy/latency.
    TaOxHfOx,
}

impl Material {
    pub const ALL: [Material; 4] = [
        Material::AgASi,
        Material::AlOxHfO2,
        Material::EpiRam,
        Material::TaOxHfOx,
    ];

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<Material> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "ag-asi" | "agasi" | "ag" => Some(Material::AgASi),
            "alox-hfo2" | "aloxhfo2" | "alox" => Some(Material::AlOxHfO2),
            "epiram" | "epi" => Some(Material::EpiRam),
            "taox-hfox" | "taoxhfox" | "taox" => Some(Material::TaOxHfOx),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        self.params().name
    }

    pub fn params(&self) -> DeviceParams {
        match self {
            // Lw target (66² matrix, no EC): 67 rows × 120 pulses × 125 µs ≈ 1.0 s
            // Ew target: 4422 cells × 120 pulses × 7.1 pJ ≈ 3.8e-6 J
            Material::AgASi => DeviceParams {
                name: "Ag-aSi",
                levels: 97,
                sigma_prog: 0.135,
                sigma_floor: 0.006,
                sigma_d2d: 0.015,
                sigma_read: 0.004,
                alpha_ltp: 2.4,
                alpha_ltd: -4.88,
                gain_eta: 0.35,
                pulses_write: 120.0,
                e_pulse: 7.1e-12,
                t_pulse: 1.25e-4,
                e_read: 5.0e-14,
                sigma_disturb: 1.0e-4,
            },
            // Lw target: 67 × 26 × 80 µs ≈ 0.14 s; Ew: 4422 × 26 × 0.48 nJ ≈ 5.5e-5 J
            Material::AlOxHfO2 => DeviceParams {
                name: "AlOx-HfO2",
                levels: 40,
                sigma_prog: 0.40,
                sigma_floor: 0.008,
                sigma_d2d: 0.035,
                sigma_read: 0.004,
                alpha_ltp: 1.94,
                alpha_ltd: -0.61,
                gain_eta: 0.22,
                pulses_write: 26.0,
                e_pulse: 4.8e-10,
                t_pulse: 8.0e-5,
                e_read: 1.0e-13,
                sigma_disturb: 3.0e-4,
            },
            // Lw target: 67 × 50 × 13.5 µs ≈ 0.045 s; Ew: 4422 × 50 × 0.45 nJ ≈ 1.0e-4 J
            Material::EpiRam => DeviceParams {
                name: "EpiRAM",
                levels: 512,
                sigma_prog: 0.009,
                sigma_floor: 0.0011,
                sigma_d2d: 0.0012,
                sigma_read: 0.004,
                alpha_ltp: 0.5,
                alpha_ltd: -0.5,
                gain_eta: 0.18,
                pulses_write: 50.0,
                e_pulse: 4.5e-10,
                t_pulse: 1.35e-5,
                e_read: 1.0e-13,
                sigma_disturb: 9.0e-4,
            },
            // Lw target: 67 × 8 × 0.5 µs ≈ 2.7e-4 s; Ew: 4422 × 8 × 1.5 pJ ≈ 5.3e-8 J
            Material::TaOxHfOx => DeviceParams {
                name: "TaOx-HfOx",
                levels: 32,
                sigma_prog: 0.27,
                sigma_floor: 0.018,
                sigma_d2d: 0.030,
                sigma_read: 0.004,
                alpha_ltp: 0.26,
                alpha_ltd: -0.35,
                gain_eta: 0.22,
                pulses_write: 8.0,
                e_pulse: 1.5e-12,
                t_pulse: 5.0e-7,
                e_read: 2.0e-14,
                sigma_disturb: 3.0e-4,
            },
        }
    }
}

impl std::fmt::Display for Material {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Material::parse("TaOx-HfOx"), Some(Material::TaOxHfOx));
        assert_eq!(Material::parse("taox_hfox"), Some(Material::TaOxHfOx));
        assert_eq!(Material::parse("epiram"), Some(Material::EpiRam));
        assert_eq!(Material::parse("AG-ASI"), Some(Material::AgASi));
        assert_eq!(Material::parse("alox"), Some(Material::AlOxHfO2));
        assert_eq!(Material::parse("??"), None);
    }

    #[test]
    fn noise_ordering_matches_table1() {
        // No-EC M1 error ordering: EpiRAM < Ag-aSi < TaOx < AlOx.
        let sig = |m: Material| m.params().sigma_prog;
        assert!(sig(Material::EpiRam) < sig(Material::AgASi));
        assert!(sig(Material::AgASi) < sig(Material::TaOxHfOx));
        assert!(sig(Material::TaOxHfOx) < sig(Material::AlOxHfO2));
    }

    #[test]
    fn energy_ordering_matches_table1() {
        // Per-write energy ordering: TaOx < Ag-aSi < AlOx < EpiRAM.
        let e = |m: Material| {
            let p = m.params();
            p.pulses_write * p.e_pulse
        };
        assert!(e(Material::TaOxHfOx) < e(Material::AgASi));
        assert!(e(Material::AgASi) < e(Material::AlOxHfO2));
        assert!(e(Material::AlOxHfO2) < e(Material::EpiRam));
        // 3+ orders of magnitude between TaOx and EpiRAM.
        assert!(e(Material::EpiRam) / e(Material::TaOxHfOx) > 1e3);
    }

    #[test]
    fn latency_ordering_matches_table1() {
        // Per-row write latency: TaOx < EpiRAM < AlOx < Ag-aSi.
        let l = |m: Material| {
            let p = m.params();
            p.pulses_write * p.t_pulse
        };
        assert!(l(Material::TaOxHfOx) < l(Material::EpiRam));
        assert!(l(Material::EpiRam) < l(Material::AlOxHfO2));
        assert!(l(Material::AlOxHfO2) < l(Material::AgASi));
        // ≥2 orders between TaOx and EpiRAM.
        assert!(l(Material::EpiRam) / l(Material::TaOxHfOx) > 1e2);
    }

    #[test]
    fn epiram_disturb_comparable_to_floor() {
        // What makes k>0 hurt EpiRAM on bcsstk02 (Fig S1).
        let p = Material::EpiRam.params();
        assert!(p.sigma_disturb > 0.5 * p.sigma_floor);
    }

    #[test]
    fn display_names() {
        assert_eq!(Material::TaOxHfOx.to_string(), "TaOx-HfOx");
        assert_eq!(Material::EpiRam.to_string(), "EpiRAM");
    }
}
