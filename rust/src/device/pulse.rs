//! Pulse-schedule model: conductance updates, nonlinearity, energy/latency.
//!
//! RRAM conductance follows an exponential saturating trajectory under
//! identical pulses; the per-material nonlinearity coefficients (α_p / α_d)
//! bend the LTP/LTD curves.  The write–verify loop interacts with this
//! through [`DeviceParams::verify_gain`]: one verify step realizes only a
//! fraction of the requested delta on strongly nonlinear devices.
//!
//! This module converts target conductance moves into pulse counts, and
//! pulse counts into energy and latency — the quantities the paper reports
//! as `E_w` and `L_w`.

use super::DeviceParams;

/// Normalized LTP conductance after `k` of `n` identical pulses, with
/// nonlinearity `alpha` (alpha -> 0 recovers the linear ramp).
///
/// G(k) = (1 - exp(-alpha * k / n)) / (1 - exp(-alpha))
pub fn ltp_curve(alpha: f64, k: f64, n: f64) -> f64 {
    if alpha.abs() < 1e-9 {
        return (k / n).clamp(0.0, 1.0);
    }
    let num = 1.0 - (-alpha * k / n).exp();
    let den = 1.0 - (-alpha).exp();
    (num / den).clamp(0.0, 1.0)
}

/// Pulses needed to move a cell by |delta| of the normalized window,
/// given the device's mean full-range pulse count.
///
/// On a linear device this is `|delta| * pulses_write`; nonlinearity
/// inflates it near the saturated end (modeled by the mean slope of the
/// LTP curve).
pub fn pulses_for_delta(params: &DeviceParams, delta_abs: f64) -> f64 {
    let linear = delta_abs.clamp(0.0, 1.0) * params.pulses_write;
    // Mean inverse-slope of the LTP curve, ≥ 1, grows with |alpha|.
    let alpha = params.alpha_ltp.abs().max(params.alpha_ltd.abs());
    let inflation = if alpha < 1e-9 {
        1.0
    } else {
        alpha / (1.0 - (-alpha).exp())
    };
    (linear * inflation).max(1.0)
}

/// Energy/latency cost of one programming pass over a tile.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PassCost {
    /// Total write energy (J).
    pub energy_j: f64,
    /// Total write latency (s) — rows are programmed serially, cells within
    /// a row in parallel, so latency follows the *max* pulse count per row.
    pub latency_s: f64,
    /// Cells actually programmed.
    pub cells: usize,
    /// Total pulses delivered.
    pub pulses: f64,
}

impl PassCost {
    pub fn accumulate(&mut self, other: PassCost) {
        self.energy_j += other.energy_j;
        self.latency_s += other.latency_s;
        self.cells += other.cells;
        self.pulses += other.pulses;
    }
}

/// Cost of programming a full tile (initial `MCAsetWeights` pass):
/// every cell receives ~`pulses_write` pulses; rows execute serially.
pub fn full_write_cost(params: &DeviceParams, rows: usize, cols: usize) -> PassCost {
    let pulses_cell = params.pulses_write;
    let cells = rows * cols;
    PassCost {
        energy_j: cells as f64 * pulses_cell * params.e_pulse,
        latency_s: rows as f64 * pulses_cell * params.t_pulse,
        cells,
        pulses: cells as f64 * pulses_cell,
    }
}

/// Cost of an initial write touching only `nnz` populated cells across
/// `rows_touched` rows (zero cells park at G_min for free).
pub fn nnz_write_cost(params: &DeviceParams, nnz: usize, rows_touched: usize) -> PassCost {
    let pulses_cell = params.pulses_write;
    PassCost {
        energy_j: nnz as f64 * pulses_cell * params.e_pulse,
        latency_s: rows_touched as f64 * pulses_cell * params.t_pulse,
        cells: nnz,
        pulses: nnz as f64 * pulses_cell,
    }
}

/// Cost of a verify pass that rewrites `rewrites` cells spread over
/// `rows_touched` rows (partial corrective pulses).
pub fn verify_pass_cost(params: &DeviceParams, rewrites: usize, rows_touched: usize) -> PassCost {
    let pulses_cell = params.pulses_verify();
    PassCost {
        energy_j: rewrites as f64 * pulses_cell * params.e_pulse,
        latency_s: rows_touched as f64 * pulses_cell * params.t_pulse,
        cells: rewrites,
        pulses: rewrites as f64 * pulses_cell,
    }
}

/// Read (MVM) energy for one activation of an `rows x cols` tile.
pub fn read_cost(params: &DeviceParams, rows: usize, cols: usize) -> f64 {
    rows as f64 * cols as f64 * params.e_read
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;

    #[test]
    fn ltp_curve_endpoints() {
        for alpha in [0.0, 0.5, 2.4, 4.88] {
            assert!((ltp_curve(alpha, 0.0, 100.0)).abs() < 1e-12);
            assert!((ltp_curve(alpha, 100.0, 100.0) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn ltp_curve_monotone() {
        let mut last = -1.0;
        for k in 0..=50 {
            let g = ltp_curve(2.4, k as f64, 50.0);
            assert!(g >= last);
            last = g;
        }
    }

    #[test]
    fn nonlinearity_bends_curve_up_front() {
        // Strong nonlinearity front-loads conductance change.
        let linear = ltp_curve(0.0, 10.0, 100.0);
        let bent = ltp_curve(2.4, 10.0, 100.0);
        assert!(bent > linear);
    }

    #[test]
    fn pulses_scale_with_delta() {
        let p = Material::TaOxHfOx.params();
        let small = pulses_for_delta(&p, 0.1);
        let large = pulses_for_delta(&p, 0.8);
        assert!(large > small);
        assert!(small >= 1.0);
    }

    #[test]
    fn nonlinear_device_needs_more_pulses() {
        let ag = Material::AgASi.params();
        let ta = Material::TaOxHfOx.params();
        // Normalize out the base pulse count: compare inflation only.
        let infl_ag = pulses_for_delta(&ag, 0.5) / (0.5 * ag.pulses_write);
        let infl_ta = pulses_for_delta(&ta, 0.5) / (0.5 * ta.pulses_write);
        assert!(infl_ag > infl_ta);
    }

    #[test]
    fn full_write_cost_scales() {
        let p = Material::EpiRam.params();
        let small = full_write_cost(&p, 66, 66);
        let big = full_write_cost(&p, 132, 66);
        assert!((big.energy_j / small.energy_j - 2.0).abs() < 1e-9);
        assert!((big.latency_s / small.latency_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn table1_noec_energy_latency_magnitudes() {
        // DESIGN.md §5 calibration targets for a 66x66 matrix + 66 vector.
        let check = |m: Material, ew_target: f64, lw_target: f64| {
            let p = m.params();
            let mat = full_write_cost(&p, 66, 66);
            let vec = full_write_cost(&p, 1, 66);
            let ew = mat.energy_j + vec.energy_j;
            let lw = mat.latency_s + vec.latency_s;
            assert!(
                ew / ew_target < 3.0 && ew_target / ew < 3.0,
                "{m}: Ew {ew:.3e} vs target {ew_target:.3e}"
            );
            assert!(
                lw / lw_target < 3.0 && lw_target / lw < 3.0,
                "{m}: Lw {lw:.3e} vs target {lw_target:.3e}"
            );
        };
        check(Material::EpiRam, 1.0e-4, 0.0449);
        check(Material::AgASi, 3.75e-6, 1.0089);
        check(Material::AlOxHfO2, 5.52e-5, 0.1398);
        check(Material::TaOxHfOx, 5.36e-8, 2.0e-4);
    }

    #[test]
    fn verify_pass_cheaper_than_full() {
        let p = Material::AlOxHfO2.params();
        let full = full_write_cost(&p, 64, 64);
        let verify = verify_pass_cost(&p, 64 * 64, 64);
        assert!(verify.energy_j < full.energy_j);
        assert!(verify.latency_s < full.latency_s);
    }
}
