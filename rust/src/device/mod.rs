//! RRAM device models (NeuroSim+ device-layer stand-in, DESIGN.md S5).
//!
//! A [`DeviceParams`] bundle captures everything the crossbar simulator
//! needs about one material system: conductance resolution (levels),
//! programming/read/disturb noise, LTP/LTD nonlinearity, and the pulse
//! energy/latency schedule.  Four calibrated material presets live in
//! [`materials`]; [`pulse`] converts nonlinearity into closed-loop
//! write–verify convergence behaviour.

pub mod materials;
pub mod nonideal;
pub mod pulse;

/// Full parameter set for one RRAM material system.
///
/// Noise figures are *relative* (multiplicative) sigmas; energies in
/// joules, times in seconds.  See DESIGN.md §5 for the calibration story
/// (no-EC Table 1 magnitudes for M1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceParams {
    pub name: &'static str,
    /// Number of programmable conductance levels per differential-pair side.
    pub levels: u32,
    /// Initial (single-shot `MCAsetWeights`) cycle-to-cycle programming noise.
    pub sigma_prog: f64,
    /// Converged write–verify floor (quantization/retention limited).
    pub sigma_floor: f64,
    /// Device-to-device fixed-pattern variation (persistent per cell).
    pub sigma_d2d: f64,
    /// Multiplicative read noise per measured MVM output element.
    pub sigma_read: f64,
    /// LTP (potentiation) nonlinearity coefficient.
    pub alpha_ltp: f64,
    /// LTD (depression) nonlinearity coefficient (negative by convention).
    pub alpha_ltd: f64,
    /// Closed-loop gain noise of a verify-pass correction step.
    pub gain_eta: f64,
    /// Mean pulses to program a cell across its full range.
    pub pulses_write: f64,
    /// Energy per programming pulse (J).
    pub e_pulse: f64,
    /// Duration of one programming pulse (s).
    pub t_pulse: f64,
    /// Read energy per cell per MVM (J) — tracked, not in the paper's E_w.
    pub e_read: f64,
    /// Disturb noise injected into every cell by one verify pass.
    pub sigma_disturb: f64,
}

impl DeviceParams {
    /// Effective closed-loop gain of one verify correction step.
    ///
    /// Strongly asymmetric LTP/LTD curves force conservative partial steps
    /// (overshoot on the steep branch cannot be undone cheaply), modeled as
    /// `gain = exp(-(|α_p| + |α_d|) / 4)` — Ag-aSi's 2.4/−4.88 gives ≈0.16
    /// (stabilizes near k≈11, Fig 2), TaOx-HfOx's 0.26/−0.35 gives ≈0.86
    /// (stabilizes by k≈2).
    pub fn verify_gain(&self) -> f64 {
        (-(self.alpha_ltp.abs() + self.alpha_ltd.abs()) / 4.0).exp()
    }

    /// Quantization step of the normalized conductance window [0, 1].
    pub fn level_step(&self) -> f64 {
        1.0 / self.levels as f64
    }

    /// Mean pulses for a verify-pass partial rewrite: corrective deltas are
    /// small (a few level steps), so a pass costs ~1/8 of a full-range
    /// write — this is what keeps the EC energy overhead in the paper's
    /// 1.4–1.9x band (Table 1).
    pub fn pulses_verify(&self) -> f64 {
        (self.pulses_write * 0.125).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::materials::Material;
    use super::*;

    #[test]
    fn verify_gain_orders_materials() {
        let ag = Material::AgASi.params();
        let ta = Material::TaOxHfOx.params();
        let al = Material::AlOxHfO2.params();
        let epi = Material::EpiRam.params();
        assert!(ag.verify_gain() < al.verify_gain());
        assert!(al.verify_gain() < epi.verify_gain());
        assert!(epi.verify_gain() < ta.verify_gain());
        // Ag-aSi's strong nonlinearity forces a small gain.
        assert!(ag.verify_gain() < 0.25, "{}", ag.verify_gain());
        assert!(ta.verify_gain() > 0.8, "{}", ta.verify_gain());
    }

    #[test]
    fn level_step_matches_levels() {
        let p = Material::TaOxHfOx.params();
        assert!((p.level_step() - 1.0 / p.levels as f64).abs() < 1e-15);
    }

    #[test]
    fn pulses_verify_at_least_one() {
        for m in Material::ALL {
            assert!(m.params().pulses_verify() >= 1.0);
        }
    }
}
