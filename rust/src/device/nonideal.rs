//! Extended device non-idealities (the paper's §1 scalability limiters and
//! future-work items, DESIGN.md "extensions"):
//!
//! * [`AdcModel`] — peripheral ADC quantization of measured bitline
//!   currents (readout resolution, in bits, over the tile's dynamic range);
//! * [`DriftModel`] — conductance retention drift `G(t) = G0·(1+t/t0)^{−ν}`
//!   between programming and read-out;
//! * [`IrDropModel`] — sneak-path / line-resistance attenuation: cells far
//!   from the drivers see degraded effective bias, modeled as a positional
//!   first-order attenuation across the array.
//!
//! All three default to disabled so the core reproduction matches the
//! paper's error model; the ablation benches and property tests switch
//! them on.

use crate::linalg::{Matrix, Vector};

/// Peripheral ADC readout quantization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcModel {
    /// Resolution in bits; 0 disables quantization.
    pub bits: u32,
}

impl Default for AdcModel {
    fn default() -> Self {
        AdcModel { bits: 0 }
    }
}

impl AdcModel {
    pub fn new(bits: u32) -> AdcModel {
        AdcModel { bits }
    }

    pub fn enabled(&self) -> bool {
        self.bits > 0
    }

    /// Quantize a measured output vector to `bits` over its own dynamic
    /// range (peripheral auto-ranging ADC).
    pub fn quantize(&self, y: &mut Vector) {
        if !self.enabled() {
            return;
        }
        let max = y.max_abs();
        if max == 0.0 {
            return;
        }
        let levels = (1u64 << self.bits.min(52)) as f64;
        let step = 2.0 * max / levels;
        for v in y.data_mut() {
            *v = (*v / step).round() * step;
        }
    }
}

/// Conductance retention drift.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftModel {
    /// Drift exponent ν (0 disables; typical RRAM: 0.005–0.1).
    pub nu: f64,
    /// Normalized elapsed time t/t0 between write and read.
    pub elapsed: f64,
}

impl Default for DriftModel {
    fn default() -> Self {
        DriftModel {
            nu: 0.0,
            elapsed: 0.0,
        }
    }
}

impl DriftModel {
    pub fn new(nu: f64, elapsed: f64) -> DriftModel {
        DriftModel { nu, elapsed }
    }

    pub fn enabled(&self) -> bool {
        self.nu > 0.0 && self.elapsed > 0.0
    }

    /// Multiplicative retention factor applied to every conductance.
    pub fn factor(&self) -> f64 {
        if !self.enabled() {
            return 1.0;
        }
        (1.0 + self.elapsed).powf(-self.nu)
    }

    /// Age an encoded (value-domain) tile in place.
    pub fn apply(&self, encoded: &mut Matrix) {
        let f = self.factor();
        if f == 1.0 {
            return;
        }
        for v in encoded.data_mut() {
            *v *= f;
        }
    }
}

/// Line-resistance (IR-drop) attenuation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IrDropModel {
    /// Worst-corner relative attenuation α (0 disables). The cell at the
    /// far corner of the array sees `(1-α)` of its nominal bias.
    pub alpha: f64,
}

impl Default for IrDropModel {
    fn default() -> Self {
        IrDropModel { alpha: 0.0 }
    }
}

impl IrDropModel {
    pub fn new(alpha: f64) -> IrDropModel {
        IrDropModel { alpha }
    }

    pub fn enabled(&self) -> bool {
        self.alpha > 0.0
    }

    /// Positional attenuation of cell (i, j) in a rows x cols array: the
    /// voltage divider along word/bit lines grows with the distance from
    /// the drivers (row driver at j=0, sense amp at i=0).
    #[inline]
    pub fn attenuation(&self, i: usize, j: usize, rows: usize, cols: usize) -> f64 {
        if !self.enabled() {
            return 1.0;
        }
        let fi = if rows > 1 { i as f64 / (rows - 1) as f64 } else { 0.0 };
        let fj = if cols > 1 { j as f64 / (cols - 1) as f64 } else { 0.0 };
        1.0 - self.alpha * 0.5 * (fi + fj)
    }

    /// Apply the positional attenuation across an encoded tile.
    pub fn apply(&self, encoded: &mut Matrix) {
        if !self.enabled() {
            return;
        }
        let (rows, cols) = (encoded.nrows(), encoded.ncols());
        for i in 0..rows {
            for j in 0..cols {
                let att = self.attenuation(i, j, rows, cols);
                encoded.set(i, j, encoded.get(i, j) * att);
            }
        }
    }
}

/// Bundle of the optional non-idealities.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NonIdealExt {
    pub adc: AdcModel,
    pub drift: DriftModel,
    pub ir_drop: IrDropModel,
}

impl NonIdealExt {
    pub fn any_enabled(&self) -> bool {
        self.adc.enabled() || self.drift.enabled() || self.ir_drop.enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_disabled_is_identity() {
        let adc = AdcModel::default();
        let mut y = Vector::from_vec(vec![0.1234, -0.777]);
        let orig = y.clone();
        adc.quantize(&mut y);
        assert_eq!(y, orig);
    }

    #[test]
    fn adc_quantizes_to_grid() {
        let adc = AdcModel::new(4); // 16 levels over [-max, max]
        let mut y = Vector::from_vec(vec![1.0, 0.49, 0.01, -0.77]);
        adc.quantize(&mut y);
        let step = 2.0 / 16.0;
        for v in y.data() {
            let k = v / step;
            assert!((k - k.round()).abs() < 1e-9, "{v} not on grid");
        }
    }

    #[test]
    fn adc_error_shrinks_with_bits() {
        let mk = |bits| {
            let adc = AdcModel::new(bits);
            let mut y = Vector::from_vec((0..100).map(|i| (i as f64 * 0.731).sin()).collect());
            let orig = y.clone();
            adc.quantize(&mut y);
            y.sub(&orig).norm_l2()
        };
        assert!(mk(10) < mk(4));
        assert!(mk(4) < mk(2));
    }

    #[test]
    fn drift_factor_monotone_in_time() {
        let d1 = DriftModel::new(0.05, 10.0);
        let d2 = DriftModel::new(0.05, 1000.0);
        assert!(d2.factor() < d1.factor());
        assert!(d1.factor() < 1.0);
        assert_eq!(DriftModel::default().factor(), 1.0);
    }

    #[test]
    fn drift_applies_uniformly() {
        let d = DriftModel::new(0.1, 100.0);
        let mut m = Matrix::from_vec(1, 3, vec![1.0, -2.0, 0.5]);
        d.apply(&mut m);
        let f = d.factor();
        assert!((m.get(0, 0) - f).abs() < 1e-12);
        assert!((m.get(0, 1) + 2.0 * f).abs() < 1e-12);
    }

    #[test]
    fn ir_drop_attenuates_far_corner_most() {
        let ir = IrDropModel::new(0.2);
        let near = ir.attenuation(0, 0, 64, 64);
        let far = ir.attenuation(63, 63, 64, 64);
        assert_eq!(near, 1.0);
        assert!((far - 0.8).abs() < 1e-12);
        // Monotone along each axis.
        assert!(ir.attenuation(10, 0, 64, 64) > ir.attenuation(20, 0, 64, 64));
    }

    #[test]
    fn ir_drop_apply_matches_pointwise() {
        let ir = IrDropModel::new(0.3);
        let mut m = Matrix::from_fn(8, 8, |_, _| 1.0);
        ir.apply(&mut m);
        for i in 0..8 {
            for j in 0..8 {
                assert!((m.get(i, j) - ir.attenuation(i, j, 8, 8)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bundle_reports_enabled() {
        let mut ext = NonIdealExt::default();
        assert!(!ext.any_enabled());
        ext.adc = AdcModel::new(8);
        assert!(ext.any_enabled());
    }
}
