//! Two-tier error correction: the paper's `correctedMatVecMul`
//! (Supplementary Alg. 6) executed per tile (DESIGN.md S8).
//!
//! A [`TileExecutor`] bundles one MCA simulator with an execution backend
//! and runs the full per-tile pipeline:
//!
//! 1. `adjustableMatWriteandVerify(A)`, `adjustableVecWriteandVerify(x)`;
//! 2. the `Xᵀ` broadcast write needed for the `Ax̃` product (one physical
//!    row programmed, replayed by the row driver — all rows are identical);
//! 3. encode the denoiser `(I + λLᵀL)⁻¹` onto the crossbar (cached per
//!    tile size, so its write cost naturally amortizes across every tile
//!    the worker processes — the paper's M̃inv is likewise written once);
//! 4. the fused L2/L1 artifact: three crossbar products, first-order
//!    combine with read noise, in-memory denoise;
//! 5. a final measured read of the corrected output.

use crate::device::nonideal::NonIdealExt;
use crate::linalg::tridiag::Tridiag;
use crate::linalg::{Matrix, Vector};
use crate::mca::{EncodeStats, Mca, WriteVerifyOpts};
use crate::runtime::{Backend, EcMvmRequest};
use std::collections::BTreeMap;

/// How the second-order correction is applied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DenoiseMode {
    /// Paper mode: the inverse is encoded on a crossbar and applied as an
    /// in-memory MVM (noise included).
    InMemory,
    /// Ablation: exact digital Thomas solve on the first-order output.
    Digital,
    /// Ablation: first-order correction only.
    Off,
}

/// Error-correction options for a solve.
#[derive(Clone, Copy, Debug)]
pub struct EcOptions {
    /// Master switch: `false` = raw `Ãx̃` (no-EC baseline).
    pub ec: bool,
    /// Regularization λ (paper default 1e-12).
    pub lambda: f64,
    /// Difference-matrix superdiagonal h (paper default −1).
    pub h: f64,
    pub denoise: DenoiseMode,
    /// Write–verify protocol settings (`ε`, `N`, `p`).
    pub wv: WriteVerifyOpts,
    /// Optional extended non-idealities (ADC, drift, IR drop) — all
    /// disabled by default to match the paper's error model.
    pub nonideal: NonIdealExt,
}

impl Default for EcOptions {
    fn default() -> Self {
        EcOptions {
            ec: true,
            lambda: 1e-12,
            h: -1.0,
            denoise: DenoiseMode::InMemory,
            wv: WriteVerifyOpts::default(),
            nonideal: NonIdealExt::default(),
        }
    }
}

/// Result of one tile execution.
#[derive(Clone, Debug)]
pub struct TileResult {
    /// The tile's measured output (f64 for downstream aggregation).
    pub y: Vector,
    /// Matrix encode statistics (iterations, rewrites, final delta).
    pub encode: EncodeStats,
}

/// A tile operand resident on the crossbar after one write–verify pass.
///
/// Produced by [`TileExecutor::program_tile`] and consumed by any number of
/// [`TileExecutor::execute_tile`] calls: the expensive conductance write is
/// paid once, every subsequent solve only re-encodes the (cheap) input
/// vector and performs reads — the program-once / solve-many contract the
/// serving layer ([`crate::server`]) is built on.
#[derive(Clone, Debug)]
pub struct ProgrammedTile {
    /// Tile size (square, one of the artifact sizes).
    pub n: usize,
    /// True operand image `A` (f32 row-major; the EC combine needs it).
    pub a: Vec<f32>,
    /// Encoded value-domain image `Ã` (noise, quantization and any
    /// extended non-idealities applied at programming time).
    pub at: Vec<f32>,
    /// Write–verify statistics of the matrix encode.
    pub encode: EncodeStats,
}

/// Per-worker tile pipeline: one MCA + one backend + denoiser caches.
pub struct TileExecutor {
    pub mca: Mca,
    backend: Backend,
    /// Encoded (noisy) denoiser per (tile size, λ-bits) — in-memory mode.
    minv_encoded: BTreeMap<(usize, u64), Vec<f32>>,
    /// Exact operator per (tile size, λ-bits) — digital mode.
    operators: BTreeMap<(usize, u64), Tridiag>,
}

impl TileExecutor {
    pub fn new(mca: Mca, backend: Backend) -> TileExecutor {
        TileExecutor {
            mca,
            backend,
            minv_encoded: BTreeMap::new(),
            operators: BTreeMap::new(),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn lambda_key(lambda: f64) -> u64 {
        lambda.to_bits()
    }

    /// Encoded denoiser for tile size `n` (writes it on first use; the
    /// ledger records that one-time cost, amortized across later tiles).
    fn encoded_minv(&mut self, n: usize, lambda: f64, h: f64) -> Vec<f32> {
        let key = (n, Self::lambda_key(lambda));
        if let Some(m) = self.minv_encoded.get(&key) {
            return m.clone();
        }
        let op = Tridiag::denoise_operator(n, lambda, h);
        let mut inv = op.inverse();
        // Entries below a quarter of the conductance quantization step
        // encode to zero conductance anyway — drop them before programming
        // so the denoiser write costs only its resolvable support (for the
        // paper's λ=1e-12 that is just the diagonal).
        let rel_cutoff = 0.25 / self.mca.params.levels as f64;
        crate::matrices::generators::sparsify(&mut inv, rel_cutoff);
        // The denoiser is setup state, programmed once and carefully: give
        // it a deep verify budget (its encoding noise otherwise floors the
        // whole EC pipeline, since Minv ~ I multiplies p directly).
        let (encoded, _) = self
            .mca
            .write_verify_matrix(&inv, &WriteVerifyOpts::default().with_iters(12));
        let f32s = encoded.to_f32();
        self.minv_encoded.insert(key, f32s.clone());
        f32s
    }

    fn operator(&mut self, n: usize, lambda: f64, h: f64) -> &Tridiag {
        let key = (n, Self::lambda_key(lambda));
        self.operators
            .entry(key)
            .or_insert_with(|| Tridiag::denoise_operator(n, lambda, h))
    }

    /// **Programming phase**: write one (already padded, square) tile onto
    /// the crossbar through write–verify and return its resident image.
    ///
    /// This is the expensive half of the paper's `correctedMatVecMul`: the
    /// assignment scan, the `adjustableMatWriteandVerify(A)` encode, the
    /// extended non-idealities on the stored image, and (with EC on) the
    /// one-time denoiser write.  The returned [`ProgrammedTile`] can then
    /// serve unlimited [`execute_tile`](Self::execute_tile) calls.
    pub fn program_tile(&mut self, a: &Matrix, opts: &EcOptions) -> Result<ProgrammedTile, String> {
        let n = a.nrows();
        if a.ncols() != n {
            return Err(format!(
                "program_tile expects a square padded tile: A is {}x{}",
                a.nrows(),
                a.ncols(),
            ));
        }
        if !self.backend.tile_sizes().contains(&n) {
            return Err(format!(
                "tile size {n} has no artifact (available: {:?})",
                self.backend.tile_sizes()
            ));
        }

        // Assignment overhead — virtualization assigns this MCA to a new
        // chunk, which costs a tile reconfiguration scan (address decoder
        // walk + bias settling + pre-use verify read).  This is the
        // per-assignment cost that makes small cell sizes expensive in the
        // paper's Fig 4 weak-scaling study.
        self.mca.ledger.record_write(crate::device::pulse::PassCost {
            energy_j: (n * n) as f64 * self.mca.params.e_read,
            latency_s: n as f64 * self.mca.params.t_pulse * 0.25,
            cells: 0,
            pulses: n as f64 * 0.25,
        });

        // Encode the operand through write–verify.
        let (mut at, encode) = self.mca.write_verify_matrix(a, &opts.wv);

        // Extended non-idealities on the stored image (retention drift and
        // line-resistance attenuation act between write and read).
        if opts.nonideal.drift.enabled() {
            opts.nonideal.drift.apply(&mut at);
        }
        if opts.nonideal.ir_drop.enabled() {
            opts.nonideal.ir_drop.apply(&mut at);
        }

        // With EC on, the denoiser is setup state too: program it now so a
        // resident tile pays *all* its write energy up front (cached per
        // tile size, so later tiles on this executor reuse it).
        if opts.ec {
            let _ = self.encoded_minv(n, opts.lambda, opts.h);
        }

        Ok(ProgrammedTile {
            n,
            a: a.to_f32(),
            at: at.to_f32(),
            encode,
        })
    }

    /// **Execution phase**: run one input vector against a resident tile —
    /// the paper's `correctedMatVecMul` when `opts.ec`, the raw product
    /// otherwise.  Only the input-vector encode and the crossbar reads are
    /// paid here; the matrix write happened in
    /// [`program_tile`](Self::program_tile).
    pub fn execute_tile(
        &mut self,
        tile: &ProgrammedTile,
        x: &Vector,
        opts: &EcOptions,
    ) -> Result<TileResult, String> {
        let n = tile.n;
        if x.len() != n {
            return Err(format!(
                "execute_tile expects x of length {n}, got {}",
                x.len()
            ));
        }

        // Encode the input vector through write–verify (per-solve cost).
        let (xt, _) = self.mca.write_verify_vector(x, &opts.wv);

        if !opts.ec {
            // Raw path: one crossbar product, measured with read noise.
            let y = self.backend.mvm(n, tile.at.clone(), xt.to_f32())?;
            self.mca.record_read(n, n);
            let noise = self.mca.read_noise_vec(n);
            let mut y = Vector::from_vec(
                y.iter()
                    .zip(&noise)
                    .map(|(v, r)| (*v as f64) * (*r as f64))
                    .collect(),
            );
            opts.nonideal.adc.quantize(&mut y);
            return Ok(TileResult {
                y,
                encode: tile.encode,
            });
        }

        // Xᵀ broadcast write (one physical row, replayed n times).
        self.mca.ledger.record_write(crate::device::pulse::full_write_cost(
            &self.mca.params,
            1,
            n,
        ));

        // Denoiser (cached; programmed during program_tile).
        let minv = self.encoded_minv(n, opts.lambda, opts.h);

        // Fused artifact — three products + combine + denoise.
        let req = EcMvmRequest {
            n,
            a: tile.a.clone(),
            at: tile.at.clone(),
            x: x.to_f32(),
            xt: xt.to_f32(),
            minv,
            nv: self.mca.read_noise_vec(n),
            nu: self.mca.read_noise_vec(n),
            ny: self.mca.read_noise_vec(n),
        };
        let resp = self.backend.ec_mvm(req)?;
        // Four tile activations: Ãx, Ax̃, Ãx̃, M̃inv·p.
        for _ in 0..4 {
            self.mca.record_read(n, n);
        }

        // Final measurement / denoise-mode selection.
        let mut y = match opts.denoise {
            DenoiseMode::InMemory => {
                let noise = self.mca.read_noise_vec(n);
                Vector::from_vec(
                    resp.y_corr
                        .iter()
                        .zip(&noise)
                        .map(|(v, r)| (*v as f64) * (*r as f64))
                        .collect(),
                )
            }
            DenoiseMode::Digital => {
                let p = Vector::from_vec(resp.p.iter().map(|&v| v as f64).collect());
                self.operator(n, opts.lambda, opts.h).denoise(&p)
            }
            DenoiseMode::Off => Vector::from_vec(resp.p.iter().map(|&v| v as f64).collect()),
        };
        opts.nonideal.adc.quantize(&mut y);
        Ok(TileResult {
            y,
            encode: tile.encode,
        })
    }

    /// One-shot path: program then execute (the original
    /// `correctedMatVecMul` shape, used by the per-solve coordinator).
    pub fn run_tile(
        &mut self,
        a: &Matrix,
        x: &Vector,
        opts: &EcOptions,
    ) -> Result<TileResult, String> {
        let tile = self.program_tile(a, opts)?;
        self.execute_tile(&tile, x, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    fn executor(material: Material, seed: u64) -> TileExecutor {
        let mca = Mca::new(material, 128, 128, seed);
        TileExecutor::new(mca, Arc::new(NativeBackend::new()))
    }

    fn rel_err(y: &Vector, b: &Vector) -> f64 {
        y.sub(b).norm_l2() / b.norm_l2()
    }

    #[test]
    fn ec_beats_raw_by_an_order() {
        let n = 64;
        let a = Matrix::standard_normal(n, n, 21);
        let x = Vector::standard_normal(n, 22);
        let b = a.matvec(&x);

        let mut raw_errs = 0.0;
        let mut ec_errs = 0.0;
        let reps = 6;
        for s in 0..reps {
            let mut te = executor(Material::TaOxHfOx, 100 + s);
            let raw = te
                .run_tile(&a, &x, &EcOptions {
                    ec: false,
                    ..EcOptions::default()
                })
                .unwrap();
            raw_errs += rel_err(&raw.y, &b);

            let mut te = executor(Material::TaOxHfOx, 200 + s);
            let ec = te.run_tile(&a, &x, &EcOptions::default()).unwrap();
            ec_errs += rel_err(&ec.y, &b);
        }
        let (raw, ec) = (raw_errs / reps as f64, ec_errs / reps as f64);
        // On a low-κ random operand the raw error is already small, so the
        // reduction here is ~85-90%; the paper's >90% headline (validated on
        // the bcsstk02 workload in benches/table1) amplifies through κ.
        assert!(
            ec < raw * 0.2,
            "large reduction expected: raw {raw:.4}, ec {ec:.4}"
        );
    }

    #[test]
    fn rejects_non_artifact_tile() {
        let mut te = executor(Material::EpiRam, 1);
        let a = Matrix::standard_normal(66, 66, 1);
        let x = Vector::standard_normal(66, 2);
        let err = te.run_tile(&a, &x, &EcOptions::default()).unwrap_err();
        assert!(err.contains("tile size 66"), "{err}");
    }

    #[test]
    fn rejects_non_square() {
        let mut te = executor(Material::EpiRam, 1);
        let a = Matrix::standard_normal(64, 32, 1);
        let x = Vector::standard_normal(32, 2);
        assert!(te.run_tile(&a, &x, &EcOptions::default()).is_err());
    }

    #[test]
    fn minv_write_cost_amortizes() {
        let n = 32;
        let mut te = executor(Material::AlOxHfO2, 5);
        let a = Matrix::standard_normal(n, n, 3);
        let x = Vector::standard_normal(n, 4);
        te.run_tile(&a, &x, &EcOptions::default()).unwrap();
        let first = te.mca.ledger;
        te.run_tile(&a, &x, &EcOptions::default()).unwrap();
        // Second tile skips the denoiser write: strictly fewer cells and
        // strictly less energy than the first (which paid the Minv setup).
        let second_delta_cells = te.mca.ledger.cells_written - first.cells_written;
        let second_delta_e = te.mca.ledger.write_energy_j - first.write_energy_j;
        assert!(second_delta_cells < first.cells_written, "{second_delta_cells} vs {}", first.cells_written);
        assert!(second_delta_e < first.write_energy_j);
    }

    #[test]
    fn denoise_modes_all_run() {
        let n = 32;
        let a = Matrix::standard_normal(n, n, 7);
        let x = Vector::standard_normal(n, 8);
        let b = a.matvec(&x);
        for mode in [DenoiseMode::InMemory, DenoiseMode::Digital, DenoiseMode::Off] {
            let mut te = executor(Material::EpiRam, 31);
            let opts = EcOptions {
                denoise: mode,
                ..EcOptions::default()
            };
            let r = te.run_tile(&a, &x, &opts).unwrap();
            assert!(rel_err(&r.y, &b) < 0.2, "{mode:?}");
        }
    }

    #[test]
    fn ec_costs_more_energy_than_raw() {
        let n = 64;
        let a = Matrix::standard_normal(n, n, 9);
        let x = Vector::standard_normal(n, 10);
        let mut raw_te = executor(Material::TaOxHfOx, 41);
        raw_te
            .run_tile(&a, &x, &EcOptions {
                ec: false,
                ..EcOptions::default()
            })
            .unwrap();
        let mut ec_te = executor(Material::TaOxHfOx, 41);
        ec_te.run_tile(&a, &x, &EcOptions::default()).unwrap();
        assert!(ec_te.mca.ledger.write_energy_j > raw_te.mca.ledger.write_energy_j);
        assert!(ec_te.mca.ledger.write_latency_s > raw_te.mca.ledger.write_latency_s);
    }

    #[test]
    fn run_tile_equals_program_plus_execute() {
        // The one-shot path is literally program+execute, so two executors
        // with the same seed must agree bit-for-bit.
        let n = 32;
        let a = Matrix::standard_normal(n, n, 17);
        let x = Vector::standard_normal(n, 18);
        let mut one_shot = executor(Material::TaOxHfOx, 77);
        let r1 = one_shot.run_tile(&a, &x, &EcOptions::default()).unwrap();
        let mut split = executor(Material::TaOxHfOx, 77);
        let tile = split.program_tile(&a, &EcOptions::default()).unwrap();
        let r2 = split.execute_tile(&tile, &x, &EcOptions::default()).unwrap();
        assert_eq!(r1.y, r2.y);
        assert_eq!(one_shot.mca.ledger, split.mca.ledger);
    }

    #[test]
    fn program_once_execute_many_amortizes_writes() {
        let n = 64;
        let a = Matrix::standard_normal(n, n, 19);
        let x1 = Vector::standard_normal(n, 20);
        let x2 = Vector::standard_normal(n, 21);
        let mut te = executor(Material::TaOxHfOx, 91);
        let tile = te.program_tile(&a, &EcOptions::default()).unwrap();
        let program_cells = te.mca.ledger.cells_written;
        assert!(program_cells >= n * n, "{program_cells}");

        let before = te.mca.ledger;
        let y1 = te.execute_tile(&tile, &x1, &EcOptions::default()).unwrap();
        let delta = te.mca.ledger.minus(&before);
        // Per-solve writes touch only vector-scale cell counts (x encode +
        // the Xᵀ broadcast row), never the n² matrix.
        assert!(delta.cells_written < 8 * n, "{}", delta.cells_written);
        assert!(delta.write_energy_j < before.write_energy_j * 0.1);
        assert!(delta.read_energy_j > 0.0);

        // Fresh read/encode noise per solve: same input, different output.
        let y2 = te.execute_tile(&tile, &x1, &EcOptions::default()).unwrap();
        assert_ne!(y1.y, y2.y);
        let y3 = te.execute_tile(&tile, &x2, &EcOptions::default()).unwrap();
        let b = a.matvec(&x2);
        assert!(rel_err(&y3.y, &b) < 0.2);
    }

    #[test]
    fn execute_tile_rejects_wrong_x_len() {
        let n = 32;
        let a = Matrix::standard_normal(n, n, 23);
        let mut te = executor(Material::EpiRam, 3);
        let tile = te.program_tile(&a, &EcOptions::default()).unwrap();
        let x = Vector::standard_normal(16, 4);
        assert!(te.execute_tile(&tile, &x, &EcOptions::default()).is_err());
    }

    #[test]
    fn write_verify_iterations_propagate() {
        let n = 32;
        let a = Matrix::standard_normal(n, n, 11);
        let x = Vector::standard_normal(n, 12);
        let mut te = executor(Material::AgASi, 55);
        let opts = EcOptions {
            wv: WriteVerifyOpts {
                max_iters: 5,
                rel_tol: 1e-9,
                norm_inf: false,
            },
            ..EcOptions::default()
        };
        let r = te.run_tile(&a, &x, &opts).unwrap();
        assert_eq!(r.encode.iters, 5);
    }
}
