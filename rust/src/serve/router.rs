//! Request routing and handlers: the front door's endpoint surface.
//!
//! | Method | Path | Purpose |
//! |---|---|---|
//! | `POST` | `/operands` | Program an operand (registry name or `.mtx` upload) → residency handle |
//! | `POST` | `/operands/{id}/solve` | One MVM solve through the coalescing window |
//! | `POST` | `/operands/{id}/solve-system` | Iterative `Ax = b` (CG/GMRES/…) on the residency |
//! | `DELETE` | `/operands/{id}` | Evict the residency |
//! | `GET` | `/status` | [`crate::obs::StatusReport`] as JSON |
//! | `GET` | `/metrics` | Prometheus text exposition |
//! | `POST` | `/shutdown` | Begin graceful drain |
//!
//! The residency handle `{id}` is the operand's content
//! [`fingerprint`](crate::server::fingerprint) in hex: uploading the same
//! matrix twice — from any client — dedups onto one resident session
//! through the [`OperandCache`].  Handlers never panic (lint rule C2
//! applies to this module): every failure renders as a typed
//! [`ServeError`] JSON body.

use super::admission::Admission;
use super::coalesce::{await_reply, Coalescer, SolveRequest};
use super::error::ServeError;
use super::http::Request;
use super::ServeConfig;
use crate::iterative::{self, IterOptions, Method};
use crate::linalg::Vector;
use crate::matrices::{registry, MatrixSource};
use crate::obs;
use crate::server::{fingerprint, OperandCache};
use crate::solver::Meliso;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// Shared state behind every connection handler thread.
pub struct ServeState {
    solver: Meliso,
    cache: Mutex<OperandCache>,
    /// Residency registry: fingerprint → source.  Outlives cache
    /// eviction so a solve against a known-but-displaced operand can
    /// transparently re-program it (also how service resumes after a
    /// plane rebuild).
    operands: Mutex<BTreeMap<u64, Arc<dyn MatrixSource>>>,
    coalescer: Coalescer,
    admission: Admission,
    shutting_down: AtomicBool,
    request_timeout: Duration,
}

/// A fully-formed response, ready for [`super::http::write_response`].
pub struct ServeResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ServeState {
    pub fn new(solver: Meliso, cfg: &ServeConfig) -> ServeState {
        ServeState {
            solver,
            cache: Mutex::new(OperandCache::new(cfg.cache_capacity.max(1))),
            operands: Mutex::new(BTreeMap::new()),
            coalescer: Coalescer::start(cfg.window, cfg.max_batch, cfg.max_inflight.max(1)),
            admission: Admission::new(cfg.max_inflight, cfg.max_inflight_per_client),
            shutting_down: AtomicBool::new(false),
            request_timeout: cfg.request_timeout,
        }
    }

    /// Flip into drain mode: execution routes refuse with 503, the accept
    /// loop stops taking connections, in-flight requests complete.
    pub fn begin_shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
    }

    pub fn shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    /// Drain the coalescer (buffered windows complete, dispatcher joins).
    pub fn drain(&self) {
        self.coalescer.shutdown();
    }

    /// Requests currently admitted (fault tests assert this returns to 0).
    pub fn inflight(&self) -> usize {
        self.admission.inflight()
    }

    /// Dispatch one parsed request.  `client` identifies the caller for
    /// per-client admission (X-Client-Id header, else peer IP).
    pub fn handle(&self, req: &Request, client: &str) -> ServeResponse {
        let segments: Vec<&str> = req
            .path
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .collect();
        // Count the request *before* dispatch so even the first
        // `/metrics` scrape sees its own route in the exposition.
        let route = route_label(req.method.as_str(), &segments);
        if obs::metrics_on() {
            obs::global()
                .counter(
                    obs::names::SERVE_REQUESTS,
                    "HTTP requests handled by the serving front door",
                    &[("route", route)],
                )
                .inc();
        }
        let result = match (req.method.as_str(), segments.as_slice()) {
            ("GET", ["status"]) => self.get_status(),
            ("GET", ["metrics"]) => self.get_metrics(),
            ("POST", ["shutdown"]) => self.post_shutdown(),
            ("POST", ["operands"]) => self.post_operand(req, client),
            ("POST", ["operands", id, "solve"]) => self.post_solve(req, client, id),
            ("POST", ["operands", id, "solve-system"]) => self.post_solve_system(req, client, id),
            ("DELETE", ["operands", id]) => self.delete_operand(id),
            _ => Err(ServeError::NotFound(format!(
                "no route for {} {}",
                req.method, req.path
            ))),
        };
        match result {
            Ok(resp) => resp,
            Err(e) => ServeResponse {
                status: e.status(),
                content_type: "application/json",
                body: e.to_json().pretty().into_bytes(),
            },
        }
    }

    fn refuse_if_draining(&self) -> Result<(), ServeError> {
        if self.shutting_down() {
            Err(ServeError::ShuttingDown)
        } else {
            Ok(())
        }
    }

    fn get_status(&self) -> Result<ServeResponse, ServeError> {
        let doc = obs::export::to_json(&obs::global().snapshot(), obs::uptime_s());
        let report = obs::StatusReport::from_json(&doc).map_err(ServeError::Internal)?;
        Ok(json_response(200, &report.to_json()))
    }

    fn get_metrics(&self) -> Result<ServeResponse, ServeError> {
        let text = obs::export::prometheus(&obs::global().snapshot());
        Ok(ServeResponse {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: text.into_bytes(),
        })
    }

    fn post_shutdown(&self) -> Result<ServeResponse, ServeError> {
        self.begin_shutdown();
        let mut body = Json::obj();
        body.set("draining", Json::Bool(true));
        Ok(json_response(200, &body))
    }

    /// Program (or dedup onto) a residency and hand back its fingerprint.
    fn post_operand(&self, req: &Request, client: &str) -> Result<ServeResponse, ServeError> {
        self.refuse_if_draining()?;
        let _permit = self.admission.try_acquire(client)?;
        let source = load_source(&req.body)?;
        let fp = fingerprint(source.as_ref());
        let (session, cached) = {
            let mut cache = lock(&self.cache);
            let hits_before = cache.hits;
            let session = cache
                .get_or_open(&self.solver, &source)
                .map_err(ServeError::from)?;
            (session, cache.hits > hits_before)
        };
        lock(&self.operands).insert(fp, source.clone());
        let report = session.program_report();
        let mut program = Json::obj();
        program
            .set("chunks_total", Json::Num(report.chunks_total as f64))
            .set("chunks_resident", Json::Num(report.chunks_resident as f64))
            .set("mcas_used", Json::Num(report.mcas_used as f64))
            .set("mean_wv_iters", Json::Num(report.mean_wv_iters))
            .set("write_energy_j", Json::Num(report.write_energy_j))
            .set("write_latency_s", Json::Num(report.write_latency_s));
        let mut body = Json::obj();
        body.set("operand", Json::Str(format!("{fp:016x}")))
            .set("m", Json::Num(source.nrows() as f64))
            .set("n", Json::Num(source.ncols() as f64))
            .set("cached", Json::Bool(cached))
            .set("program", program);
        Ok(json_response(200, &body))
    }

    /// Resolve a residency handle to a live session, transparently
    /// re-programming a known operand after eviction or a plane rebuild.
    fn session_for(&self, fp: u64) -> Result<Arc<crate::server::Session>, ServeError> {
        let mut cache = lock(&self.cache);
        if let Some(session) = cache.find_by_fingerprint(fp) {
            return Ok(session);
        }
        let source = lock(&self.operands)
            .get(&fp)
            .cloned()
            .ok_or_else(|| ServeError::NotFound(format!("unknown operand {fp:016x}")))?;
        cache
            .get_or_open(&self.solver, &source)
            .map_err(ServeError::from)
    }

    /// One MVM solve, folded into the coalescing window.
    fn post_solve(&self, req: &Request, client: &str, id: &str) -> Result<ServeResponse, ServeError> {
        self.refuse_if_draining()?;
        let _permit = self.admission.try_acquire(client)?;
        let fp = parse_handle(id)?;
        let session = self.session_for(fp)?;
        let doc = parse_json(&req.body)?;
        let x = Vector::from_vec(vector_field(&doc, "x")?);
        let (reply, rx) = mpsc::sync_channel(1);
        self.coalescer.submit(SolveRequest {
            fp,
            session,
            x,
            reply,
        })?;
        let solve = await_reply(&rx, self.request_timeout)?;
        let mut body = Json::obj();
        body.set(
            "y",
            Json::Arr(solve.y.data().iter().map(|&v| Json::Num(v)).collect()),
        )
        .set("solve_index", Json::Num(solve.solve_index as f64))
        .set("wall_seconds", Json::Num(solve.wall_seconds));
        Ok(json_response(200, &body))
    }

    /// Iterative `Ax = b` against the residency (exact residuals from the
    /// registered source drive refinement, as in `meliso solve-system`).
    fn post_solve_system(
        &self,
        req: &Request,
        client: &str,
        id: &str,
    ) -> Result<ServeResponse, ServeError> {
        self.refuse_if_draining()?;
        let _permit = self.admission.try_acquire(client)?;
        let fp = parse_handle(id)?;
        let session = self.session_for(fp)?;
        let source = lock(&self.operands)
            .get(&fp)
            .cloned()
            .ok_or_else(|| ServeError::NotFound(format!("unknown operand {fp:016x}")))?;
        let doc = parse_json(&req.body)?;
        let b = Vector::from_vec(vector_field(&doc, "b")?);
        let opts = iter_options(&doc)?;
        if source.nrows() != source.ncols() {
            return Err(ServeError::BadRequest(format!(
                "iterative methods need a square operand, got {}x{}",
                source.nrows(),
                source.ncols()
            )));
        }
        if b.len() != source.ncols() {
            return Err(ServeError::BadRequest(format!(
                "b has length {}, operand is {}x{}",
                b.len(),
                source.nrows(),
                source.ncols()
            )));
        }
        let outcome = iterative::solve_system(&*session, Some(source.as_ref()), &b, &opts)
            .map_err(ServeError::Internal)?;
        let mut body = Json::obj();
        body.set(
            "x",
            Json::Arr(outcome.x.data().iter().map(|&v| Json::Num(v)).collect()),
        )
        .set("converged", Json::Bool(outcome.converged))
        .set("rel_residual", Json::Num(outcome.rel_residual))
        .set("iterations", Json::Num(outcome.iterations as f64))
        .set("refinements", Json::Num(outcome.refinements as f64))
        .set("mvms", Json::Num(outcome.mvms as f64));
        Ok(json_response(200, &body))
    }

    fn delete_operand(&self, id: &str) -> Result<ServeResponse, ServeError> {
        let fp = parse_handle(id)?;
        let known = lock(&self.operands).remove(&fp).is_some();
        let evicted = lock(&self.cache).evict_by_fingerprint(fp);
        if !known && !evicted {
            return Err(ServeError::NotFound(format!("unknown operand {fp:016x}")));
        }
        let mut body = Json::obj();
        body.set("evicted", Json::Bool(evicted))
            .set("operand", Json::Str(format!("{fp:016x}")));
        Ok(json_response(200, &body))
    }
}

/// Static route label for the request counter (mirrors the dispatch
/// match in [`ServeState::handle`]).
fn route_label(method: &str, segments: &[&str]) -> &'static str {
    match (method, segments) {
        ("GET", ["status"]) => "status",
        ("GET", ["metrics"]) => "metrics",
        ("POST", ["shutdown"]) => "shutdown",
        ("POST", ["operands"]) => "operands",
        ("POST", ["operands", _, "solve"]) => "solve",
        ("POST", ["operands", _, "solve-system"]) => "solve_system",
        ("DELETE", ["operands", _]) => "delete",
        _ => "other",
    }
}

fn json_response(status: u16, body: &Json) -> ServeResponse {
    ServeResponse {
        status,
        content_type: "application/json",
        body: body.pretty().into_bytes(),
    }
}

fn parse_handle(id: &str) -> Result<u64, ServeError> {
    u64::from_str_radix(id, 16)
        .map_err(|_| ServeError::BadRequest(format!("operand handle '{id}' is not a hex id")))
}

fn parse_json(body: &[u8]) -> Result<Json, ServeError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ServeError::BadRequest("request body is not UTF-8".into()))?;
    Json::parse(text).map_err(|e| ServeError::BadRequest(format!("bad JSON body: {e}")))
}

fn vector_field(doc: &Json, key: &str) -> Result<Vec<f64>, ServeError> {
    let arr = doc
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| ServeError::BadRequest(format!("body needs a numeric array '{key}'")))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| ServeError::BadRequest(format!("'{key}' holds a non-number")))
        })
        .collect()
}

fn iter_options(doc: &Json) -> Result<IterOptions, ServeError> {
    let mut opts = IterOptions::default();
    if let Some(m) = doc.get("method").and_then(Json::as_str) {
        let method = Method::parse(m)
            .ok_or_else(|| ServeError::BadRequest(format!("unknown method '{m}'")))?;
        opts = opts.with_method(method);
    }
    if let Some(v) = doc.get("tol").and_then(Json::as_f64) {
        opts = opts.with_tol(v);
    }
    if let Some(v) = doc.get("max_iters").and_then(Json::as_usize) {
        opts = opts.with_max_iters(v);
    }
    if let Some(v) = doc.get("restart").and_then(Json::as_usize) {
        opts = opts.with_restart(v);
    }
    if let Some(v) = doc.get("omega").and_then(Json::as_f64) {
        opts = opts.with_omega(v);
    }
    if let Some(v) = doc.get("refinements").and_then(Json::as_usize) {
        opts = opts.with_refinements(v);
    }
    if let Some(v) = doc.get("inner_tol").and_then(Json::as_f64) {
        opts = opts.with_inner_tol(v);
    }
    Ok(opts)
}

/// Upload sequence number — keeps concurrent `.mtx` temp files distinct
/// within the process (the name also folds in the PID).
static UPLOAD_SEQ: AtomicU64 = AtomicU64::new(0);

/// Materialize the request body as an operand: a JSON `{"name": ...}`
/// registry reference, or a raw Matrix-Market upload (spilled to a temp
/// file for the `.mtx` reader, then removed).
fn load_source(body: &[u8]) -> Result<Arc<dyn MatrixSource>, ServeError> {
    let lead = body
        .iter()
        .position(|b| !b.is_ascii_whitespace())
        .unwrap_or(body.len());
    if body[lead..].starts_with(b"%%MatrixMarket") {
        let path = std::env::temp_dir().join(format!(
            "meliso-upload-{}-{}.mtx",
            std::process::id(),
            UPLOAD_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&path, body)
            .map_err(|e| ServeError::Internal(format!("spill upload: {e}")))?;
        let built = registry::build(&format!("mtx:{}", path.display()));
        let _ = std::fs::remove_file(&path);
        return built.map_err(ServeError::BadRequest);
    }
    let doc = parse_json(body)?;
    let name = doc.get("name").and_then(Json::as_str).ok_or_else(|| {
        ServeError::BadRequest(
            "body must be a Matrix-Market upload or {\"name\": \"<registry operand>\"}".into(),
        )
    })?;
    registry::build(name).map_err(ServeError::BadRequest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SolveOptions, SystemConfig};
    use crate::device::materials::Material;
    use crate::runtime::native::NativeBackend;

    fn state() -> ServeState {
        let solver = Meliso::with_backend(
            SystemConfig::single_mca(32),
            SolveOptions::default()
                .with_device(Material::EpiRam)
                .with_workers(2)
                .with_seed(11),
            Arc::new(NativeBackend::new()),
        );
        ServeState::new(solver, &ServeConfig::default())
    }

    fn request(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    fn body_json(resp: &ServeResponse) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn upload_solve_delete_round_trip() {
        let st = state();
        let up = st.handle(&request("POST", "/operands", "{\"name\": \"iperturb66\"}"), "t");
        assert_eq!(up.status, 200, "{}", String::from_utf8_lossy(&up.body));
        let doc = body_json(&up);
        let handle = doc.get("operand").unwrap().as_str().unwrap().to_string();
        assert_eq!(doc.get("m").unwrap().as_usize(), Some(66));
        assert_eq!(doc.get("cached").unwrap(), &Json::Bool(false));

        // Re-upload dedups onto the same residency.
        let again = st.handle(&request("POST", "/operands", "{\"name\": \"iperturb66\"}"), "t");
        assert_eq!(body_json(&again).get("cached").unwrap(), &Json::Bool(true));

        let x: Vec<String> = (0..66).map(|i| format!("{}", (i % 7) as f64 * 0.25)).collect();
        let solve = st.handle(
            &request(
                "POST",
                &format!("/operands/{handle}/solve"),
                &format!("{{\"x\": [{}]}}", x.join(",")),
            ),
            "t",
        );
        assert_eq!(solve.status, 200, "{}", String::from_utf8_lossy(&solve.body));
        let out = body_json(&solve);
        assert_eq!(out.get("y").unwrap().as_arr().unwrap().len(), 66);
        assert_eq!(out.get("solve_index").unwrap().as_usize(), Some(0));

        let del = st.handle(&request("DELETE", &format!("/operands/{handle}"), ""), "t");
        assert_eq!(del.status, 200);
        // The registry entry is gone: a further solve is 404.
        let gone = st.handle(
            &request(
                "POST",
                &format!("/operands/{handle}/solve"),
                &format!("{{\"x\": [{}]}}", x.join(",")),
            ),
            "t",
        );
        assert_eq!(gone.status, 404);
        st.drain();
    }

    #[test]
    fn malformed_requests_get_typed_400s() {
        let st = state();
        assert_eq!(st.handle(&request("POST", "/operands", "not json"), "t").status, 400);
        assert_eq!(
            st.handle(&request("POST", "/operands", "{\"name\": \"no-such\"}"), "t").status,
            400
        );
        assert_eq!(
            st.handle(&request("POST", "/operands/zzz/solve", "{\"x\": []}"), "t").status,
            400
        );
        assert_eq!(
            st.handle(&request("POST", "/operands/1234/solve", "{\"x\": [1]}"), "t").status,
            404
        );
        assert_eq!(st.handle(&request("GET", "/nope", ""), "t").status, 404);
        st.drain();
    }

    #[test]
    fn mtx_upload_and_solve_system() {
        let st = state();
        let mtx = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("data/arrow16.mtx"),
        )
        .unwrap();
        let up = st.handle(&request("POST", "/operands", &mtx), "t");
        assert_eq!(up.status, 200, "{}", String::from_utf8_lossy(&up.body));
        let doc = body_json(&up);
        let handle = doc.get("operand").unwrap().as_str().unwrap().to_string();
        assert_eq!(doc.get("m").unwrap().as_usize(), Some(16));

        let b: Vec<String> = (0..16).map(|_| "1".to_string()).collect();
        let solve = st.handle(
            &request(
                "POST",
                &format!("/operands/{handle}/solve-system"),
                &format!(
                    "{{\"b\": [{}], \"method\": \"gmres\", \"tol\": 1e-8}}",
                    b.join(",")
                ),
            ),
            "t",
        );
        assert_eq!(solve.status, 200, "{}", String::from_utf8_lossy(&solve.body));
        let out = body_json(&solve);
        assert_eq!(out.get("converged").unwrap(), &Json::Bool(true));
        assert!(out.get("rel_residual").unwrap().as_f64().unwrap() <= 1e-6);
        st.drain();
    }

    #[test]
    fn drain_mode_refuses_new_work_but_serves_reads() {
        let st = state();
        let resp = st.handle(&request("POST", "/shutdown", ""), "t");
        assert_eq!(resp.status, 200);
        assert!(st.shutting_down());
        let refused = st.handle(&request("POST", "/operands", "{\"name\": \"iperturb66\"}"), "t");
        assert_eq!(refused.status, 503);
        assert_eq!(
            body_json(&refused)
                .get("error")
                .unwrap()
                .get("code")
                .unwrap()
                .as_str(),
            Some("shutting_down")
        );
        assert_eq!(st.handle(&request("GET", "/metrics", ""), "t").status, 200);
        st.drain();
    }
}
