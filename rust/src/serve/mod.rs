//! The network serving front door: a std-only HTTP/1.1 + JSON server
//! over **one** shared [`PlaneHandle`](crate::plane::PlaneHandle).
//!
//! The paper's MELISO+ deployment is a *serving* substrate — distributed
//! RRAM planes answering matrix computations for many concurrent
//! clients.  This module is the process boundary: a dependency-free
//! `TcpListener` + thread-pool server (the repo's hermetic-build rule
//! forbids an HTTP crate, and the protocol needs none) exposing the
//! resident-session machinery over the wire:
//!
//! * [`router`] — the endpoint surface (`POST /operands`, `/solve`,
//!   `/solve-system`, `DELETE`, `GET /status|/metrics`,
//!   `POST /shutdown`) with residency handles keyed by operand content
//!   fingerprint, deduped through the
//!   [`OperandCache`](crate::server::OperandCache);
//! * [`coalesce`] — the headline win: a cross-client gather window
//!   folding concurrent solves against one resident operand into a
//!   single `execute_batch` chunk walk, demuxed per request — the
//!   write-once / read-many amortization the paper's energy model
//!   rewards, applied *across* clients;
//! * [`admission`] — bounded in-flight per client and global, typed
//!   429/503 JSON rejections;
//! * [`error`] — the [`PlaneError`](crate::plane::PlaneError) →
//!   HTTP taxonomy;
//! * [`http`] — minimal request parsing / response writing.
//!
//! Graceful shutdown (`POST /shutdown` or [`Server::shutdown`]) drains:
//! the accept loop stops, queued connections get typed 503s, in-flight
//! requests complete, the coalescer empties its buffer, then every
//! thread is joined.
//!
//! Start from the CLI with `meliso serve --addr 127.0.0.1:7737`, or
//! embed via [`Server::start`] (bind to port 0 for an ephemeral port —
//! what the end-to-end tests do).

pub mod admission;
pub mod coalesce;
pub mod error;
pub mod http;
pub mod router;

pub use error::ServeError;
pub use router::{ServeResponse, ServeState};

use crate::obs;
use crate::solver::Meliso;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// Front-door tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 for ephemeral).
    pub addr: String,
    /// Operands kept resident (LRU beyond this).
    pub cache_capacity: usize,
    /// How long the first solve of a window waits for company.
    pub window: Duration,
    /// Max solves folded into one coalesced window.
    pub max_batch: usize,
    /// Global in-flight request budget (excess → 503).
    pub max_inflight: usize,
    /// Per-client in-flight budget (excess → 429).
    pub max_inflight_per_client: usize,
    /// Connection-handler threads.
    pub http_threads: usize,
    /// Hard deadline for one request's execution.
    pub request_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7737".into(),
            cache_capacity: 8,
            window: Duration::from_millis(2),
            max_batch: 32,
            max_inflight: 64,
            max_inflight_per_client: 16,
            http_threads: 8,
            request_timeout: Duration::from_secs(120),
        }
    }
}

/// Idle poll period for the accept loop and connection-queue waits.
const POLL: Duration = Duration::from_millis(2);
/// Worker wait on the connection queue between liveness checks.
const QUEUE_TICK: Duration = Duration::from_millis(200);
/// Per-connection socket timeouts.
const SOCKET_TIMEOUT: Duration = Duration::from_secs(30);

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A running front door: accept loop + handler pool over one
/// [`ServeState`].
pub struct Server {
    state: Arc<ServeState>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving.  Metrics are armed if not already on —
    /// `/metrics` and `/status` are part of the serving contract.
    pub fn start(solver: Meliso, cfg: ServeConfig) -> Result<Server, String> {
        if !obs::metrics_on() {
            obs::set_level(obs::ObsLevel::Metrics);
        }
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("set_nonblocking: {e}"))?;
        let state = Arc::new(ServeState::new(solver, &cfg));
        let threads = cfg.http_threads.max(1);
        let (conn_tx, conn_rx) = mpsc::sync_channel::<(TcpStream, SocketAddr)>(threads * 2);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let state = state.clone();
            let conn_rx = conn_rx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("serve-http-{i}"))
                .spawn(move || worker_loop(&state, &conn_rx))
                .map_err(|e| format!("spawn worker: {e}"))?;
            workers.push(handle);
        }
        let accept = {
            let state = state.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(&listener, &state, &conn_tx))
                .map_err(|e| format!("spawn accept loop: {e}"))?
        };
        Ok(Server {
            state,
            addr,
            accept: Some(accept),
            workers,
        })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared handler state (fault tests watch
    /// [`ServeState::inflight`] through this).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Begin draining and block until fully stopped: accept loop down,
    /// queued connections answered with 503, in-flight requests
    /// completed, coalescer emptied, all threads joined.
    pub fn shutdown(mut self) {
        self.state.begin_shutdown();
        self.teardown();
    }

    /// Block until something (e.g. `POST /shutdown`) begins the drain,
    /// then tear down as [`shutdown`](Self::shutdown) does.  This is the
    /// CLI's main loop.
    pub fn wait(mut self) {
        while !self.state.shutting_down() {
            std::thread::sleep(QUEUE_TICK);
        }
        self.teardown();
    }

    fn teardown(&mut self) {
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        // The accept thread dropped its sender: workers drain the queue
        // (every queued connection gets a response — 503 on execution
        // routes once draining), then exit on Disconnected.
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
        self.state.drain();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.state.begin_shutdown();
        self.teardown();
    }
}

/// Accept until draining.  Overflow beyond the bounded connection queue
/// is answered inline with a typed 503 — the server never queues
/// unboundedly and never blocks the accept loop on a slow handler.
fn accept_loop(
    listener: &TcpListener,
    state: &ServeState,
    conn_tx: &mpsc::SyncSender<(TcpStream, SocketAddr)>,
) {
    while !state.shutting_down() {
        match listener.accept() {
            Ok((stream, peer)) => match conn_tx.try_send((stream, peer)) {
                Ok(()) => {}
                Err(TrySendError::Full((mut stream, _))) => {
                    let body = ServeError::Overloaded("connection queue is full".into())
                        .to_json()
                        .pretty();
                    let _ = stream.set_write_timeout(Some(SOCKET_TIMEOUT));
                    let _ = http::write_response(&mut stream, 503, "application/json", body.as_bytes());
                }
                Err(TrySendError::Disconnected(_)) => return,
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }
}

/// Handler-pool worker: take connections until the queue closes.
fn worker_loop(
    state: &ServeState,
    conn_rx: &Mutex<mpsc::Receiver<(TcpStream, SocketAddr)>>,
) {
    loop {
        let next = lock(conn_rx).recv_timeout(QUEUE_TICK);
        match next {
            Ok((stream, peer)) => handle_connection(state, stream, peer),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// One connection, one request, one response (`Connection: close`).
/// A client that hangs up mid-solve costs nothing: the response write
/// fails silently and every resource is permit/Drop-managed.
fn handle_connection(state: &ServeState, mut stream: TcpStream, peer: SocketAddr) {
    let _ = stream.set_read_timeout(Some(SOCKET_TIMEOUT));
    let _ = stream.set_write_timeout(Some(SOCKET_TIMEOUT));
    let req = match http::read_request(&mut stream, http::MAX_BODY) {
        Ok(req) => req,
        Err(e) => {
            let err = ServeError::BadRequest(e);
            let _ = http::write_response(
                &mut stream,
                err.status(),
                "application/json",
                err.to_json().pretty().as_bytes(),
            );
            return;
        }
    };
    let client = req
        .header("x-client-id")
        .map(str::to_string)
        .unwrap_or_else(|| peer.ip().to_string());
    let resp = state.handle(&req, &client);
    let _ = http::write_response(&mut stream, resp.status, resp.content_type, &resp.body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SolveOptions, SystemConfig};
    use crate::device::materials::Material;
    use crate::runtime::native::NativeBackend;
    use std::io::{Read, Write};

    fn solver() -> Meliso {
        Meliso::with_backend(
            SystemConfig::single_mca(32),
            SolveOptions::default()
                .with_device(Material::EpiRam)
                .with_workers(2)
                .with_seed(11),
            Arc::new(NativeBackend::new()),
        )
    }

    fn ephemeral_config() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            http_threads: 2,
            ..ServeConfig::default()
        }
    }

    fn roundtrip(addr: SocketAddr, raw: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(raw.as_bytes()).unwrap();
        conn.flush().unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn boots_serves_metrics_and_drains_on_shutdown_route() {
        let server = Server::start(solver(), ephemeral_config()).unwrap();
        let addr = server.addr();
        let metrics = roundtrip(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"), "{metrics}");
        assert!(metrics.contains("meliso_serve_requests_total"), "{metrics}");
        let bye = roundtrip(addr, "POST /shutdown HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(bye.contains("\"draining\": true"), "{bye}");
        // wait() returns because the shutdown route flipped the flag.
        server.wait();
    }

    #[test]
    fn explicit_shutdown_is_idempotent_with_drop() {
        let server = Server::start(solver(), ephemeral_config()).unwrap();
        let addr = server.addr();
        let resp = roundtrip(addr, "GET /status HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        server.shutdown();
    }
}
