//! Typed serving errors and their HTTP shape.
//!
//! Every failure on the request path becomes a [`ServeError`]: a stable
//! machine-readable `code`, an HTTP status, and a human message, rendered
//! as a JSON body.  [`crate::plane::PlaneError`] variants map onto the
//! client-facing taxonomy here (bad input → 400, stale residency → 404,
//! busy operand → 429, capacity/failed plane → 503) so embedded callers
//! and HTTP clients see the *same* cause for the same fault.

use crate::plane::PlaneError;
use crate::solver::MelisoError;
use crate::util::json::Json;
use std::fmt;

/// A request-path failure with an HTTP mapping.
///
/// `Clone` is deliberate: one failed coalesced window fans a single error
/// out to every waiter that was folded into it.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Malformed request (bad JSON, wrong vector length, unknown route
    /// payload) — HTTP 400.
    BadRequest(String),
    /// Unknown operand id / route — HTTP 404.
    NotFound(String),
    /// Per-client in-flight budget exhausted, or the operand is busy —
    /// HTTP 429.
    TooManyRequests(String),
    /// Global in-flight budget or plane capacity exhausted — HTTP 503.
    Overloaded(String),
    /// The server is draining; new work is refused — HTTP 503.
    ShuttingDown,
    /// The request did not complete within the serving deadline — HTTP 504.
    Timeout(String),
    /// Plane/shard failure or another internal fault — HTTP 500.
    Internal(String),
}

impl ServeError {
    /// The HTTP status this error renders as.
    pub fn status(&self) -> u16 {
        match self {
            ServeError::BadRequest(_) => 400,
            ServeError::NotFound(_) => 404,
            ServeError::TooManyRequests(_) => 429,
            ServeError::Overloaded(_) | ServeError::ShuttingDown => 503,
            ServeError::Timeout(_) => 504,
            ServeError::Internal(_) => 500,
        }
    }

    /// Stable machine-readable error code for clients to match on.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::BadRequest(_) => "bad_request",
            ServeError::NotFound(_) => "not_found",
            ServeError::TooManyRequests(_) => "too_many_requests",
            ServeError::Overloaded(_) => "overloaded",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::Timeout(_) => "timeout",
            ServeError::Internal(_) => "internal",
        }
    }

    /// The JSON error body (`{"error": {"code": ..., "message": ...}}`).
    pub fn to_json(&self) -> Json {
        let mut inner = Json::obj();
        inner
            .set("code", Json::Str(self.code().to_string()))
            .set("message", Json::Str(self.to_string()));
        let mut body = Json::obj();
        body.set("error", inner);
        body
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadRequest(m)
            | ServeError::NotFound(m)
            | ServeError::TooManyRequests(m)
            | ServeError::Overloaded(m)
            | ServeError::Timeout(m)
            | ServeError::Internal(m) => write!(f, "{m}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down; refusing new work"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<PlaneError> for ServeError {
    fn from(e: PlaneError) -> ServeError {
        let msg = e.to_string();
        match e {
            PlaneError::InvalidInput(_) | PlaneError::UnsupportedCell { .. } => {
                ServeError::BadRequest(msg)
            }
            PlaneError::StaleOperand { .. } => ServeError::NotFound(msg),
            PlaneError::OperandBusy { .. } => ServeError::TooManyRequests(msg),
            PlaneError::Capacity { .. } => ServeError::Overloaded(msg),
            PlaneError::Timeout(_) => ServeError::Timeout(msg),
            PlaneError::Build(_)
            | PlaneError::Chunk(_)
            | PlaneError::ShardDead(_)
            | PlaneError::Failed(_) => ServeError::Internal(msg),
        }
    }
}

impl From<MelisoError> for ServeError {
    fn from(e: MelisoError) -> ServeError {
        match e {
            MelisoError::Plane(p) => p.into(),
            MelisoError::InvalidInput(m) => ServeError::BadRequest(m),
            MelisoError::Backend(m) | MelisoError::Solver(m) => ServeError::Internal(m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plane::OperandId;

    #[test]
    fn plane_errors_map_to_client_statuses() {
        let cases: Vec<(PlaneError, u16, &str)> = vec![
            (
                PlaneError::InvalidInput("x len".into()),
                400,
                "bad_request",
            ),
            (
                PlaneError::StaleOperand {
                    id: OperandId(3),
                },
                404,
                "not_found",
            ),
            (
                PlaneError::OperandBusy {
                    id: OperandId(3),
                    inflight: 2,
                },
                429,
                "too_many_requests",
            ),
            (
                PlaneError::Capacity { mca: 0, slots: 4 },
                503,
                "overloaded",
            ),
            (PlaneError::Timeout("gather".into()), 504, "timeout"),
            (PlaneError::ShardDead("shard 1".into()), 500, "internal"),
            (PlaneError::Failed("poisoned".into()), 500, "internal"),
        ];
        for (plane, status, code) in cases {
            let e = ServeError::from(plane.clone());
            assert_eq!(e.status(), status, "{plane:?}");
            assert_eq!(e.code(), code, "{plane:?}");
        }
    }

    #[test]
    fn json_body_carries_code_and_message() {
        let e = ServeError::TooManyRequests("client budget".into());
        let body = e.to_json();
        let err = body.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("too_many_requests"));
        assert_eq!(err.get("message").unwrap().as_str(), Some("client budget"));
    }

    #[test]
    fn shutdown_renders_503() {
        assert_eq!(ServeError::ShuttingDown.status(), 503);
        assert!(ServeError::ShuttingDown.to_string().contains("shutting down"));
    }
}
