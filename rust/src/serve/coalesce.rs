//! Cross-client request coalescing: the gather window.
//!
//! Concurrent solve requests against the *same* resident operand are the
//! serving pattern the paper's energy model rewards: the conductance
//! write was paid once at program time, and one
//! [`crate::plane::PlaneHandle::execute_batch`] chunk walk can serve many
//! input vectors for nearly the cost of one.  The [`Coalescer`] exploits
//! that across clients: a single dispatcher thread gathers submitted
//! requests for a short window (or until `max_batch`), groups them by
//! operand fingerprint, runs **one** `solve_batch` per group, and demuxes
//! the per-request completions back over oneshot-style reply channels.
//!
//! Correctness contract (checked exhaustively by the interleaving model
//! in `rust/tests/loom_models.rs` and end-to-end by
//! `rust/tests/serve_end_to_end.rs`):
//!
//! * every submitted request is completed **exactly once** — with a
//!   result or with a typed [`ServeError`], never both, never zero;
//! * a failed window fans its error out to *every* waiter in the window
//!   (no waiter is left hanging on a reply that will never come);
//! * results are bit-identical to sequential solves: solve-index
//!   assignment follows arrival order within each operand group, and the
//!   plane's counter-based noise makes `y_k` a pure function of
//!   `(x_k, solve_index_k)`.
//!
//! Concurrency discipline: the dispatcher waits with `recv_timeout`
//! (C1 — a dead sender can never park it forever), reply sends ignore a
//! dropped receiver (a disconnected client leaks nothing), and deadlines
//! come from [`crate::plane::timing::monotonic_now`] (D2).

use super::error::ServeError;
use crate::obs;
use crate::plane::timing::monotonic_now;
use crate::server::{ServeSolve, Session};
use std::collections::BTreeMap;
use std::sync::mpsc::{self, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Idle poll period of the dispatcher (liveness check cadence) and of
/// reply waits.  Short enough that shutdown is prompt, long enough that
/// an idle server costs nothing measurable.
const TICK: Duration = Duration::from_millis(100);

/// One solve request submitted to the gather window.
pub struct SolveRequest {
    /// Operand fingerprint — requests with equal fingerprints fold into
    /// one `solve_batch` call.
    pub fp: u64,
    /// The resident session serving this operand.
    pub session: Arc<Session>,
    /// Input vector.
    pub x: crate::linalg::Vector,
    /// Oneshot-style completion channel (capacity 1; the send never
    /// blocks).  A dropped receiver means the client went away — the
    /// completion is discarded, nothing leaks.
    pub reply: SyncSender<Result<ServeSolve, ServeError>>,
}

/// The cross-client gather window (one dispatcher thread).
pub struct Coalescer {
    /// `None` after shutdown: submissions fail with
    /// [`ServeError::ShuttingDown`].
    tx: Mutex<Option<SyncSender<SolveRequest>>>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Coalescer {
    /// Start the dispatcher.  `window` is how long the first request of a
    /// window waits for company; `max_batch` caps one window; `queue`
    /// bounds the submission channel (admission control bounds the number
    /// of outstanding requests, so a queue of that size never blocks a
    /// submitter for long).
    pub fn start(window: Duration, max_batch: usize, queue: usize) -> Coalescer {
        let max_batch = max_batch.max(1);
        let (tx, rx) = mpsc::sync_channel::<SolveRequest>(queue.max(1));
        let dispatcher = std::thread::Builder::new()
            .name("serve-coalescer".into())
            .spawn(move || dispatch_loop(&rx, window, max_batch))
            .ok();
        Coalescer {
            tx: Mutex::new(Some(tx)),
            dispatcher: Mutex::new(dispatcher),
        }
    }

    /// Submit a request to the current gather window.  Fails only when
    /// the server is draining.
    pub fn submit(&self, req: SolveRequest) -> Result<(), ServeError> {
        // Clone the sender out of the lock so a briefly-full queue never
        // blocks shutdown (which needs this mutex to drop the sender).
        let tx = match lock_unpoisoned(&self.tx).as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(ServeError::ShuttingDown),
        };
        tx.send(req).map_err(|_| ServeError::ShuttingDown)
    }

    /// Drain and stop: no new submissions, buffered requests complete
    /// (with results or errors), then the dispatcher exits and is joined.
    pub fn shutdown(&self) {
        drop(lock_unpoisoned(&self.tx).take());
        if let Some(h) = lock_unpoisoned(&self.dispatcher).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coalescer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Wait for a coalesced completion with a hard deadline.  The poll loop
/// keeps the wait bounded (C1) even if the dispatcher dies, in which case
/// the dropped sender surfaces as a typed internal error.
pub fn await_reply(
    rx: &mpsc::Receiver<Result<ServeSolve, ServeError>>,
    timeout: Duration,
) -> Result<ServeSolve, ServeError> {
    let deadline = monotonic_now() + timeout;
    loop {
        match rx.recv_timeout(TICK.min(timeout)) {
            Ok(res) => return res,
            Err(RecvTimeoutError::Timeout) => {
                if monotonic_now() >= deadline {
                    return Err(ServeError::Timeout(format!(
                        "solve did not complete within {timeout:?}"
                    )));
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                return Err(ServeError::Internal(
                    "coalescer dropped the completion channel".into(),
                ))
            }
        }
    }
}

/// The dispatcher: gather a window, execute it, repeat.  Exits when every
/// sender is gone and the buffer is drained.
fn dispatch_loop(rx: &mpsc::Receiver<SolveRequest>, window: Duration, max_batch: usize) {
    loop {
        match rx.recv_timeout(TICK) {
            Ok(first) => {
                let batch = gather_window(rx, first, window, max_batch);
                execute_window(batch);
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Collect company for `first` until the window closes, `max_batch` is
/// reached, or every sender is gone (remaining buffered requests are
/// picked up by the next outer iteration).
fn gather_window(
    rx: &mpsc::Receiver<SolveRequest>,
    first: SolveRequest,
    window: Duration,
    max_batch: usize,
) -> Vec<SolveRequest> {
    let mut batch = vec![first];
    let deadline: Instant = monotonic_now() + window;
    while batch.len() < max_batch {
        let remaining = deadline.saturating_duration_since(monotonic_now());
        if remaining.is_zero() {
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(req) => batch.push(req),
            Err(_) => break, // window elapsed, or senders gone
        }
    }
    batch
}

/// Execute one gathered window: group by operand fingerprint (BTreeMap
/// for deterministic group order; arrival order is preserved within each
/// group), one `solve_batch` per group, demux completions.
fn execute_window(batch: Vec<SolveRequest>) {
    let mut groups: BTreeMap<u64, Vec<SolveRequest>> = BTreeMap::new();
    for req in batch {
        groups.entry(req.fp).or_default().push(req);
    }
    let metrics = obs::metrics_on();
    for (_, group) in groups {
        if metrics {
            obs::global()
                .counter(
                    obs::names::SERVE_COALESCED_BATCHES,
                    "Coalesced execute_batch windows dispatched",
                    &[],
                )
                .inc();
            obs::global()
                .counter(
                    obs::names::SERVE_COALESCED_SOLVES,
                    "Solve requests folded into coalesced windows",
                    &[],
                )
                .add(group.len() as f64);
        }
        let session = group[0].session.clone();
        let xs: Vec<crate::linalg::Vector> = group.iter().map(|r| r.x.clone()).collect();
        match session.solve_batch(&xs) {
            Ok(solves) => {
                // `solve_batch` returns exactly one ServeSolve per input,
                // in input order — zip demuxes each to its waiter.
                for (req, solve) in group.into_iter().zip(solves) {
                    let _ = req.reply.send(Ok(solve));
                }
            }
            Err(e) => {
                // One failure, every waiter in the window notified.
                let err: ServeError = e.into();
                for req in group {
                    let _ = req.reply.send(Err(err.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SolveOptions, SystemConfig};
    use crate::device::materials::Material;
    use crate::linalg::{Matrix, Vector};
    use crate::matrices::{DenseSource, MatrixSource};
    use crate::runtime::native::NativeBackend;
    use crate::server::fingerprint;
    use crate::solver::Meliso;

    fn solver() -> Meliso {
        Meliso::with_backend(
            SystemConfig::single_mca(32),
            SolveOptions::default()
                .with_device(Material::EpiRam)
                .with_workers(2)
                .with_seed(11),
            Arc::new(NativeBackend::new()),
        )
    }

    fn operand(seed: u64) -> Arc<dyn MatrixSource> {
        Arc::new(DenseSource::new(Matrix::standard_normal(16, 16, seed)))
    }

    fn submit_all(
        coalescer: &Coalescer,
        session: &Arc<Session>,
        fp: u64,
        xs: &[Vector],
    ) -> Vec<mpsc::Receiver<Result<ServeSolve, ServeError>>> {
        xs.iter()
            .map(|x| {
                let (tx, rx) = mpsc::sync_channel(1);
                coalescer
                    .submit(SolveRequest {
                        fp,
                        session: session.clone(),
                        x: x.clone(),
                        reply: tx,
                    })
                    .unwrap();
                rx
            })
            .collect()
    }

    #[test]
    fn coalesced_solves_bit_identical_to_sequential() {
        let solver = solver();
        let src = operand(1);
        let fp = fingerprint(src.as_ref());
        let xs: Vec<Vector> = (0..6).map(|s| Vector::standard_normal(16, 40 + s)).collect();

        // Reference: one fresh session, sequential solves 0..N.
        let reference: Vec<Vector> = {
            let session = solver.open_session(src.clone()).unwrap();
            xs.iter().map(|x| session.solve(x).unwrap().y).collect()
        };

        // Coalesced: submit all six before the window closes.
        let session = Arc::new(solver.open_session(src.clone()).unwrap());
        let coalescer = Coalescer::start(Duration::from_millis(50), 32, 64);
        let replies = submit_all(&coalescer, &session, fp, &xs);
        for (k, rx) in replies.iter().enumerate() {
            let out = await_reply(rx, Duration::from_secs(60)).unwrap();
            assert_eq!(out.solve_index, k as u64);
            assert_eq!(out.y.data(), reference[k].data(), "solve {k}");
        }
        coalescer.shutdown();
    }

    #[test]
    fn window_groups_by_fingerprint() {
        let solver = solver();
        let (src_a, src_b) = (operand(2), operand(3));
        let (fpa, fpb) = (fingerprint(src_a.as_ref()), fingerprint(src_b.as_ref()));
        assert_ne!(fpa, fpb);
        let plane = solver.build_plane(src_a.as_ref()).unwrap();
        let sa = Arc::new(solver.open_session_on(&plane, src_a).unwrap());
        let sb = Arc::new(solver.open_session_on(&plane, src_b).unwrap());
        let coalescer = Coalescer::start(Duration::from_millis(50), 32, 64);
        let xs: Vec<Vector> = (0..2).map(|s| Vector::standard_normal(16, 60 + s)).collect();
        let ra = submit_all(&coalescer, &sa, fpa, &xs);
        let rb = submit_all(&coalescer, &sb, fpb, &xs);
        // Both groups complete; each session saw exactly its own solves.
        for rx in ra.iter().chain(rb.iter()) {
            await_reply(rx, Duration::from_secs(60)).unwrap();
        }
        assert_eq!(sa.report().solves, 2);
        assert_eq!(sb.report().solves, 2);
        coalescer.shutdown();
    }

    #[test]
    fn dropped_reply_receiver_leaks_nothing() {
        let solver = solver();
        let src = operand(4);
        let fp = fingerprint(src.as_ref());
        let session = Arc::new(solver.open_session(src).unwrap());
        let coalescer = Coalescer::start(Duration::from_millis(5), 8, 8);
        let (tx, rx) = mpsc::sync_channel(1);
        drop(rx); // the client disconnected before completion
        coalescer
            .submit(SolveRequest {
                fp,
                session: session.clone(),
                x: Vector::standard_normal(16, 70),
                reply: tx,
            })
            .unwrap();
        // A live request behind it still completes normally.
        let (tx2, rx2) = mpsc::sync_channel(1);
        coalescer
            .submit(SolveRequest {
                fp,
                session: session.clone(),
                x: Vector::standard_normal(16, 71),
                reply: tx2,
            })
            .unwrap();
        await_reply(&rx2, Duration::from_secs(60)).unwrap();
        coalescer.shutdown();
        // Both solves executed; the orphaned completion was discarded.
        assert_eq!(session.report().solves, 2);
    }

    #[test]
    fn shutdown_drains_buffered_requests_then_refuses() {
        let solver = solver();
        let src = operand(5);
        let fp = fingerprint(src.as_ref());
        let session = Arc::new(solver.open_session(src).unwrap());
        let coalescer = Coalescer::start(Duration::from_millis(5), 8, 8);
        let xs: Vec<Vector> = (0..3).map(|s| Vector::standard_normal(16, 80 + s)).collect();
        let replies = submit_all(&coalescer, &session, fp, &xs);
        coalescer.shutdown(); // blocks until the buffer is drained
        for rx in &replies {
            await_reply(rx, Duration::from_secs(1)).unwrap();
        }
        let (tx, _rx) = mpsc::sync_channel(1);
        let err = coalescer
            .submit(SolveRequest {
                fp,
                session,
                x: Vector::standard_normal(16, 90),
                reply: tx,
            })
            .unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }
}
