//! Admission control: bounded in-flight work, per client and global.
//!
//! The front door refuses work it cannot serve promptly instead of
//! queueing unboundedly: each executing request holds a [`Permit`], and
//! [`Admission::try_acquire`] rejects — deterministically, with a typed
//! [`ServeError`] — when either the per-client or the global in-flight
//! budget is exhausted (HTTP 429 and 503 respectively).  Permits release
//! on `Drop`, so every exit path (success, typed error, client
//! disconnect, handler unwind) returns the budget; the fault-injection
//! battery asserts the in-flight gauge always drains back to zero.

use super::error::ServeError;
use crate::obs;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, PoisonError};

#[derive(Default)]
struct AdmissionState {
    total: usize,
    per_client: BTreeMap<String, usize>,
}

/// Shared admission budget for the serving front door.
pub struct Admission {
    global_limit: usize,
    per_client_limit: usize,
    state: Arc<Mutex<AdmissionState>>,
}

fn lock_state(state: &Mutex<AdmissionState>) -> std::sync::MutexGuard<'_, AdmissionState> {
    state.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Admission {
    /// Budgets of zero are clamped to one so the server can always make
    /// progress.
    pub fn new(global_limit: usize, per_client_limit: usize) -> Admission {
        Admission {
            global_limit: global_limit.max(1),
            per_client_limit: per_client_limit.max(1),
            state: Arc::new(Mutex::new(AdmissionState::default())),
        }
    }

    /// Requests currently holding permits.
    pub fn inflight(&self) -> usize {
        lock_state(&self.state).total
    }

    /// Admit one request for `client`, or reject with a typed error.
    /// Per-client exhaustion is checked first so a single greedy client
    /// sees 429 (back off) rather than 503 (server trouble).
    pub fn try_acquire(&self, client: &str) -> Result<Permit, ServeError> {
        let mut st = lock_state(&self.state);
        let held = st.per_client.get(client).copied().unwrap_or(0);
        if held >= self.per_client_limit {
            drop(st);
            note_rejected("client_budget");
            return Err(ServeError::TooManyRequests(format!(
                "client has {held} requests in flight (limit {})",
                self.per_client_limit
            )));
        }
        if st.total >= self.global_limit {
            let total = st.total;
            drop(st);
            note_rejected("global_budget");
            return Err(ServeError::Overloaded(format!(
                "server has {total} requests in flight (limit {})",
                self.global_limit
            )));
        }
        st.total += 1;
        *st.per_client.entry(client.to_string()).or_insert(0) += 1;
        let total = st.total;
        drop(st);
        set_inflight_gauge(total as f64);
        Ok(Permit {
            state: self.state.clone(),
            client: client.to_string(),
        })
    }
}

/// One admitted request; releases its budget on `Drop`.
pub struct Permit {
    state: Arc<Mutex<AdmissionState>>,
    client: String,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut st = lock_state(&self.state);
        st.total = st.total.saturating_sub(1);
        if let Some(held) = st.per_client.get_mut(&self.client) {
            *held = held.saturating_sub(1);
            if *held == 0 {
                st.per_client.remove(&self.client);
            }
        }
        let total = st.total;
        drop(st);
        set_inflight_gauge(total as f64);
    }
}

fn set_inflight_gauge(total: f64) {
    if obs::metrics_on() {
        obs::global()
            .gauge(
                obs::names::SERVE_INFLIGHT,
                "Requests currently admitted and executing on the front door",
                &[],
            )
            .set(total);
    }
}

fn note_rejected(reason: &'static str) {
    if obs::metrics_on() {
        obs::global()
            .counter(
                obs::names::SERVE_REJECTED,
                "Front-door requests rejected before execution",
                &[("reason", reason)],
            )
            .inc();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_client_budget_rejects_with_429_shape() {
        let adm = Admission::new(8, 2);
        let _a = adm.try_acquire("alice").unwrap();
        let _b = adm.try_acquire("alice").unwrap();
        let err = adm.try_acquire("alice").unwrap_err();
        assert_eq!(err.status(), 429);
        // A different client still gets in.
        let _c = adm.try_acquire("bob").unwrap();
        assert_eq!(adm.inflight(), 3);
    }

    #[test]
    fn global_budget_rejects_with_503_shape() {
        let adm = Admission::new(2, 2);
        let _a = adm.try_acquire("alice").unwrap();
        let _b = adm.try_acquire("bob").unwrap();
        let err = adm.try_acquire("carol").unwrap_err();
        assert_eq!(err.status(), 503);
        assert_eq!(err.code(), "overloaded");
    }

    #[test]
    fn dropping_permits_returns_budget() {
        let adm = Admission::new(2, 1);
        let a = adm.try_acquire("alice").unwrap();
        assert!(adm.try_acquire("alice").is_err());
        drop(a);
        assert_eq!(adm.inflight(), 0);
        let _again = adm.try_acquire("alice").unwrap();
        assert_eq!(adm.inflight(), 1);
    }

    #[test]
    fn budget_floor_is_one() {
        let adm = Admission::new(0, 0);
        let _a = adm.try_acquire("alice").unwrap();
        assert!(adm.try_acquire("alice").is_err());
    }
}
