//! A minimal std-only HTTP/1.1 layer: just enough protocol for the
//! front door.
//!
//! One request per connection (`Connection: close`), headers capped,
//! bodies bounded by `Content-Length`, everything read/written over a
//! plain [`std::net::TcpStream`] with caller-set timeouts.  Deliberately
//! not a general HTTP implementation — no chunked encoding, no
//! keep-alive, no TLS — because the repo's hermetic-build rule forbids
//! dependencies and the serving protocol needs none of it.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Maximum size of the request line + headers.
const MAX_HEAD: usize = 16 * 1024;
/// Maximum request body (a dense f64 `.mtx` upload of ~1M entries).
pub const MAX_BODY: usize = 64 * 1024 * 1024;

/// A parsed HTTP request.
pub struct Request {
    /// Uppercased method (`GET`, `POST`, `DELETE`, ...).
    pub method: String,
    /// Path with any query string stripped.
    pub path: String,
    /// Header name/value pairs; names lowercased.
    pub headers: Vec<(String, String)>,
    /// Request body (may be empty).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one request from `stream`.  The caller is responsible for having
/// set a read timeout; a timeout or short read surfaces as `Err`.
pub fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<Request, String> {
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 1024];
    let split = loop {
        if let Some(pos) = find_head_end(&head) {
            break pos;
        }
        if head.len() > MAX_HEAD {
            return Err("request head exceeds 16KiB".into());
        }
        let n = stream.read(&mut buf).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed before request head".into());
        }
        head.extend_from_slice(&buf[..n]);
    };
    let (head_bytes, mut body) = {
        let rest = head.split_off(split + 4);
        (head, rest)
    };
    let head_str = String::from_utf8_lossy(&head_bytes);
    let mut lines = head_str.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| "empty request line".to_string())?
        .to_ascii_uppercase();
    let target = parts
        .next()
        .ok_or_else(|| "request line missing target".to_string())?;
    let path = target.split('?').next().unwrap_or(target).to_string();
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let content_length = headers
        .iter()
        .find(|(n, _)| n == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .map_err(|_| "unparseable Content-Length".to_string())?
        .unwrap_or(0);
    if content_length > max_body {
        return Err(format!("body of {content_length} bytes exceeds limit"));
    }
    if body.len() > content_length {
        body.truncate(content_length);
    }
    while body.len() < content_length {
        let n = stream.read(&mut buf).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        let take = n.min(content_length - body.len());
        body.extend_from_slice(&buf[..take]);
    }
    Ok(Request {
        method,
        path,
        headers,
        body,
    })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write a complete response and flush.  Errors are returned but callers
/// typically ignore them — a client that hung up loses only its own
/// response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<(), String> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_text(status),
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body))
        .and_then(|()| stream.flush())
        .map_err(|e| format!("write: {e}"))
}

/// Reason phrase for the statuses the front door emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Round-trip a raw request through a real socket pair.
    fn parse_raw(raw: &[u8]) -> Result<Request, String> {
        let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        let mut client = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        client.write_all(raw).map_err(|e| e.to_string())?;
        client.flush().map_err(|e| e.to_string())?;
        let (mut server_side, _) = listener.accept().map_err(|e| e.to_string())?;
        server_side
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .map_err(|e| e.to_string())?;
        read_request(&mut server_side, MAX_BODY)
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let req = parse_raw(
            b"POST /operands/1a2b/solve?trace=1 HTTP/1.1\r\n\
              Content-Type: application/json\r\n\
              X-Client-Id: alice\r\n\
              Content-Length: 11\r\n\r\n{\"x\":[1,2]}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/operands/1a2b/solve");
        assert_eq!(req.header("x-client-id"), Some("alice"));
        assert_eq!(req.header("X-Client-Id"), Some("alice"));
        assert_eq!(req.body, b"{\"x\":[1,2]}");
    }

    #[test]
    fn missing_content_length_means_empty_body() {
        let req = parse_raw(b"GET /status HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn oversized_body_is_refused() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .write_all(b"POST /operands HTTP/1.1\r\nContent-Length: 999999\r\n\r\n")
            .unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        server_side
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        assert!(read_request(&mut server_side, 1024).is_err());
    }

    #[test]
    fn response_wire_format_is_parseable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        write_response(&mut server_side, 429, "application/json", b"{}").unwrap();
        drop(server_side);
        let mut raw = String::new();
        let mut client = client;
        client.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(raw.contains("Content-Length: 2\r\n"));
        assert!(raw.ends_with("\r\n\r\n{}"));
    }
}
