//! Message types exchanged between the leader and workers.

use crate::linalg::{Matrix, Vector};
use crate::virtualization::ChunkSpec;

/// One unit of work: an extracted, zero-padded chunk and its x slice.
pub struct Job {
    pub spec: ChunkSpec,
    pub a_tile: Matrix,
    pub x_chunk: Vector,
}

/// A worker's answer for one chunk.
pub struct JobResult {
    pub block_row: usize,
    pub block_col: usize,
    /// Partial product of length `cell_size` (padded rows included).
    pub partial: Vector,
    /// Write–verify iterations the matrix encode used.
    pub encode_iters: usize,
}
