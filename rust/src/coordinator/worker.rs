//! Worker thread: owns the [`TileExecutor`]s of its assigned MCAs.
//!
//! Determinism contract: MCA `i` is always served by worker
//! `i % workers`, its simulator is seeded from `(master seed, i)`, and the
//! leader dispatches that MCA's chunks in a fixed order over a FIFO
//! channel — so every chunk sees the same RNG stream no matter how many
//! workers run or how threads are scheduled.

use super::messages::{Job, JobResult};
use crate::config::{SolveOptions, SystemConfig};
use crate::ec::TileExecutor;
use crate::mca::{EnergyLedger, Mca};
use crate::runtime::Backend;
use std::collections::HashMap;
use std::sync::mpsc;

pub struct WorkerContext {
    pub worker_id: usize,
    pub workers: usize,
    pub config: SystemConfig,
    pub opts: SolveOptions,
    pub backend: Backend,
    pub jobs: mpsc::Receiver<Job>,
    pub results: mpsc::Sender<Result<JobResult, String>>,
    pub ledgers: mpsc::Sender<Vec<(usize, EnergyLedger)>>,
}

/// Deterministic per-MCA seed derivation: MCA `i`'s simulator stream is a
/// pure function of the master seed, independent of worker count.
pub fn mca_seed(master: u64, mca_index: usize) -> u64 {
    master
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(mca_index as u64)
}

/// Build the persistent executor for one MCA.  Shared by the one-shot
/// worker pool and the resident serving sessions (`crate::server`), so
/// both paths see identical device state for a given seed.
pub fn new_executor(
    opts: &SolveOptions,
    cell: usize,
    backend: &Backend,
    mca_index: usize,
) -> TileExecutor {
    let mca = Mca::new(opts.material, cell, cell, mca_seed(opts.seed, mca_index));
    TileExecutor::new(mca, backend.clone())
}

/// Worker main loop: execute jobs until the leader closes the channel,
/// then report per-MCA ledgers.
pub fn run(ctx: WorkerContext) {
    let mut executors: HashMap<usize, TileExecutor> = HashMap::new();
    let cell = ctx.config.geometry().cell_size;
    while let Ok(job) = ctx.jobs.recv() {
        let mca_index = job.spec.mca_index;
        debug_assert_eq!(mca_index % ctx.workers, ctx.worker_id);
        let exec = executors
            .entry(mca_index)
            .or_insert_with(|| new_executor(&ctx.opts, cell, &ctx.backend, mca_index));
        let outcome = exec
            .run_tile(&job.a_tile, &job.x_chunk, &ctx.opts.ec_options())
            .map(|r| JobResult {
                block_row: job.spec.block_row,
                block_col: job.spec.block_col,
                partial: r.y,
                encode_iters: r.encode.iters,
            });
        if ctx.results.send(outcome).is_err() {
            break; // leader gone
        }
    }
    let batch: Vec<(usize, EnergyLedger)> = executors
        .into_iter()
        .map(|(idx, exec)| (idx, exec.mca.ledger))
        .collect();
    let _ = ctx.ledgers.send(batch);
}
