//! Distributed coordinator: the leader/worker runtime that stands in for
//! the paper's OpenMPI + mpi4py deployment (DESIGN.md S10).
//!
//! * The **leader** walks the [`ChunkPlan`] in deterministic row-major
//!   order, extracts each chunk (zero-padded, per `zeroPadding`) from the
//!   [`MatrixSource`], skips certainly-zero chunks (sparsity-aware
//!   scheduling — an optimization the banded operands benefit from
//!   enormously), and dispatches jobs over bounded channels
//!   (backpressure).
//! * Each **worker** thread owns the [`crate::ec::TileExecutor`]s of the MCAs
//!   assigned to it (an MCA never migrates, so its RNG stream, its
//!   fixed-pattern noise and its ledger stay consistent) and runs the
//!   paper's `correctedMatVecMul` per chunk.
//! * The leader gathers partial products and reduces them **in
//!   deterministic chunk order**, so a solve is bit-reproducible for a
//!   given seed regardless of thread scheduling.

pub mod messages;
pub mod worker;

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::mca::EnergyLedger;
use crate::metrics::SolveReport;
use crate::runtime::Backend;
use crate::virtualization::ChunkPlan;
use messages::{Job, JobResult};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Instant;

/// Bound on in-flight jobs per worker (backpressure).
pub(crate) const JOB_QUEUE_DEPTH: usize = 4;

/// Reduce gathered per-chunk partial products into the output vector in
/// deterministic `(block_row, block_col)` order, so the sum is
/// bit-reproducible regardless of worker scheduling.  Shared with the
/// resident serving sessions (`crate::server`).
pub fn reduce_partials(
    m: usize,
    tile: usize,
    partials: &BTreeMap<(usize, usize), Vector>,
) -> Vector {
    let mut y = Vector::zeros(m);
    for ((bi, _bj), part) in partials {
        let row0 = bi * tile;
        for (k, v) in part.data().iter().enumerate() {
            let idx = row0 + k;
            if idx < m {
                y.set(idx, y.get(idx) + v);
            }
        }
    }
    y
}

/// Run one distributed MVM and return the full report.
///
/// `b_truth` is computed internally (exact f64 streaming matvec).
pub fn solve_distributed(
    source: &dyn MatrixSource,
    x: &Vector,
    config: &SystemConfig,
    opts: &SolveOptions,
    backend: Backend,
) -> Result<SolveReport, String> {
    let start = Instant::now();
    let (m, n) = (source.nrows(), source.ncols());
    if x.len() != n {
        return Err(format!("x has length {} but A has {n} columns", x.len()));
    }
    let plan = ChunkPlan::new(config.geometry(), m, n);
    let tile = config.geometry().cell_size;
    if !backend.tile_sizes().contains(&tile) {
        return Err(format!(
            "cell size {tile} has no compiled artifact (available: {:?})",
            backend.tile_sizes()
        ));
    }

    // Spawn workers; MCAs are distributed round-robin over worker threads.
    let workers = opts.workers.max(1).min(plan.geometry.mcas());
    let mut senders: Vec<mpsc::SyncSender<Job>> = Vec::with_capacity(workers);
    let (result_tx, result_rx) = mpsc::channel::<Result<JobResult, String>>();
    let (ledger_tx, ledger_rx) = mpsc::channel::<Vec<(usize, EnergyLedger)>>();
    let mut handles = Vec::with_capacity(workers);
    for w in 0..workers {
        let (tx, rx) = mpsc::sync_channel::<Job>(JOB_QUEUE_DEPTH);
        senders.push(tx);
        let ctx = worker::WorkerContext {
            worker_id: w,
            workers,
            config: *config,
            opts: opts.clone(),
            backend: backend.clone(),
            jobs: rx,
            results: result_tx.clone(),
            ledgers: ledger_tx.clone(),
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("meliso-worker-{w}"))
                .spawn(move || worker::run(ctx))
                .map_err(|e| format!("spawn worker {w}: {e}"))?,
        );
    }
    drop(result_tx);
    drop(ledger_tx);

    // Leader scatter: walk chunks, extract, dispatch.
    let mut dispatched = 0usize;
    let mut skipped = 0usize;
    for spec in plan.chunks() {
        if source.block_is_zero(spec.row0, spec.col0, tile, tile) {
            skipped += 1;
            continue;
        }
        let a_tile = source.block(spec.row0, spec.col0, tile, tile);
        let x_chunk = x.slice_padded(spec.col0, tile);
        let job = Job {
            spec,
            a_tile,
            x_chunk,
        };
        let target = spec.mca_index % workers;
        senders[target]
            .send(job)
            .map_err(|_| format!("worker {target} died"))?;
        dispatched += 1;
    }
    // Close job channels so workers drain and report ledgers.
    drop(senders);

    // Gather: collect partials keyed by chunk coordinates, then reduce in
    // deterministic order.
    let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
    let mut wv_iters_sum = 0.0f64;
    for _ in 0..dispatched {
        let jr = result_rx
            .recv()
            .map_err(|_| "workers exited before delivering all results".to_string())??;
        wv_iters_sum += jr.encode_iters as f64;
        partials.insert((jr.block_row, jr.block_col), jr.partial);
    }
    let y = reduce_partials(m, tile, &partials);

    // Collect per-MCA ledgers.
    let mut ledgers = vec![EnergyLedger::default(); plan.geometry.mcas()];
    while let Ok(batch) = ledger_rx.recv() {
        for (idx, ledger) in batch {
            ledgers[idx].merge(&ledger);
        }
    }
    for h in handles {
        h.join().map_err(|_| "worker panicked".to_string())?;
    }

    // Ground truth + report.
    let b = source.matvec(x);
    let mut report = SolveReport::empty(m);
    report.rel_err_l2 = crate::metrics::rel_err_l2(&y, &b);
    report.rel_err_inf = crate::metrics::rel_err_inf(&y, &b);
    report.y = y;
    report.chunks_total = plan.total_chunks();
    report.chunks_skipped = skipped;
    report.normalization_factor = plan.normalization_factor();
    report.row_reassignments = plan.row_reassignments();
    report.mean_wv_iters = if dispatched > 0 {
        wv_iters_sum / dispatched as f64
    } else {
        0.0
    };
    report.fill_from_ledgers(&ledgers);
    report.wall_seconds = start.elapsed().as_secs_f64();
    crate::log_info!(
        "coordinator",
        "solve {}x{n}: {} chunks ({} skipped), eps_l2={:.4e}, wall={:.2}s",
        m,
        dispatched,
        skipped,
        report.rel_err_l2,
        report.wall_seconds
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::matrices::DenseSource;
    use crate::linalg::Matrix;
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    #[test]
    fn single_mca_solve_works() {
        let a = Matrix::standard_normal(66, 66, 3);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(66, 4);
        let config = SystemConfig::single_mca(128);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
        assert_eq!(report.chunks_total, 1);
        assert_eq!(report.mcas_used, 1);
    }

    #[test]
    fn multi_mca_partition_correctness() {
        // 100x100 operand on a 2x2 grid of 32² MCAs: 4x4 chunk grid.
        let a = Matrix::standard_normal(100, 100, 5);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(100, 6);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_workers(3);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.chunks_total, 16);
        assert!(report.rel_err_l2 < 0.12, "{}", report.rel_err_l2);
        assert!(report.normalization_factor >= 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Matrix::standard_normal(64, 64, 7);
        let x = Vector::standard_normal(64, 8);
        let run = |workers: usize| {
            let src = DenseSource::new(a.clone());
            let config = SystemConfig::new(2, 2, 32);
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_workers(workers)
                .with_seed(99);
            solve_distributed(&src, &x, &config, &opts, native()).unwrap()
        };
        let r1 = run(1);
        let r2 = run(4); // different parallelism, same result
        assert_eq!(r1.y, r2.y);
        assert_eq!(r1.rel_err_l2, r2.rel_err_l2);
    }

    #[test]
    fn non_square_operand_solve() {
        // 48x80 on a 2x2 grid of 32² MCAs: 2x3 chunk grid, y of length 48.
        let a = Matrix::standard_normal(48, 80, 13);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(80, 14);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.y.len(), 48);
        assert_eq!(report.chunks_total, 6);
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
    }

    #[test]
    fn sparsity_skipping_counts() {
        use crate::matrices::BandedSource;
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let x = Vector::standard_normal(256, 9);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.chunks_total, 64);
        assert!(report.chunks_skipped > 30, "{}", report.chunks_skipped);
        assert!(report.rel_err_l2 < 0.1);
    }

    #[test]
    fn dimension_mismatch_is_error() {
        let a = Matrix::standard_normal(16, 16, 1);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(8, 2);
        let config = SystemConfig::single_mca(32);
        let opts = SolveOptions::default();
        assert!(solve_distributed(&src, &x, &config, &opts, native()).is_err());
    }

    #[test]
    fn unsupported_cell_size_is_error() {
        let a = Matrix::standard_normal(16, 16, 1);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(16, 2);
        let config = SystemConfig::single_mca(48); // not an artifact size
        let opts = SolveOptions::default();
        let err = solve_distributed(&src, &x, &config, &opts, native()).unwrap_err();
        assert!(err.contains("cell size 48"), "{err}");
    }

    #[test]
    fn no_ec_is_less_accurate() {
        let a = Matrix::standard_normal(128, 128, 11);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(128, 12);
        let config = SystemConfig::single_mca(128);
        let base = SolveOptions::default().with_device(Material::TaOxHfOx);
        let with_ec =
            solve_distributed(&src, &x, &config, &base.clone().with_ec(true), native()).unwrap();
        let src = DenseSource::new(Matrix::standard_normal(128, 128, 11));
        let no_ec =
            solve_distributed(&src, &x, &config, &base.with_ec(false), native()).unwrap();
        assert!(
            with_ec.rel_err_l2 < no_ec.rel_err_l2 * 0.5,
            "ec {} vs raw {}",
            with_ec.rel_err_l2,
            no_ec.rel_err_l2
        );
    }
}
