//! One-shot distributed solves: the leader/worker runtime that stands in
//! for the paper's OpenMPI + mpi4py deployment (DESIGN.md S10).
//!
//! Since the execution-plane refactor this module is a thin façade: all
//! scatter/gather machinery (shard pool, streaming sparsity-aware chunk
//! dispatch, deterministic reduction, ledger collection) lives in
//! [`crate::plane::ExecutionPlane`] and is shared with the resident
//! serving sessions ([`crate::server::Session`]).  [`solve_distributed`]
//! builds a plane for the operand, runs one fused program+execute pass and
//! tears it down.
//!
//! Re-exported here for continuity: [`reduce_partials`] (the deterministic
//! partial-product reduction both execution paths use) and the per-MCA
//! stream derivations [`mca_seed`] / [`new_executor`].

pub use crate::plane::{mca_seed, new_executor, reduce_partials};

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::metrics::SolveReport;
use crate::plane::{ExecutionPlane, PlaneError};
use crate::runtime::Backend;

/// Run one distributed MVM and return the full report.
///
/// With `opts.ground_truth` set (the default) the exact f64 reference
/// `b = Ax` is computed on the host and `rel_err_*` reported; switch it
/// off for at-scale operands where that O(m·n) pass would dominate
/// (`rel_err_*` are then NaN, serialized as JSON `null`).
pub fn solve_distributed(
    source: &dyn MatrixSource,
    x: &Vector,
    config: &SystemConfig,
    opts: &SolveOptions,
    backend: Backend,
) -> Result<SolveReport, PlaneError> {
    ExecutionPlane::build(source, config, opts, backend)?.execute_once(source, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::linalg::Matrix;
    use crate::matrices::DenseSource;
    use crate::plane::Placement;
    use crate::runtime::native::NativeBackend;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    #[test]
    fn single_mca_solve_works() {
        let a = Matrix::standard_normal(66, 66, 3);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(66, 4);
        let config = SystemConfig::single_mca(128);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
        assert_eq!(report.chunks_total, 1);
        assert_eq!(report.mcas_used, 1);
    }

    #[test]
    fn multi_mca_partition_correctness() {
        // 100x100 operand on a 2x2 grid of 32² MCAs: 4x4 chunk grid.
        let a = Matrix::standard_normal(100, 100, 5);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(100, 6);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_workers(3);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.chunks_total, 16);
        assert!(report.rel_err_l2 < 0.12, "{}", report.rel_err_l2);
        assert!(report.normalization_factor >= 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Matrix::standard_normal(64, 64, 7);
        let x = Vector::standard_normal(64, 8);
        let run = |workers: usize, placement: Placement| {
            let src = DenseSource::new(a.clone());
            let config = SystemConfig::new(2, 2, 32);
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_workers(workers)
                .with_placement(placement)
                .with_seed(99);
            solve_distributed(&src, &x, &config, &opts, native()).unwrap()
        };
        let r1 = run(1, Placement::RoundRobin);
        // Different parallelism and placement policy: same result.
        let r2 = run(4, Placement::RoundRobin);
        let r3 = run(3, Placement::LoadBalanced);
        assert_eq!(r1.y, r2.y);
        assert_eq!(r1.rel_err_l2, r2.rel_err_l2);
        assert_eq!(r1.y, r3.y);
    }

    #[test]
    fn non_square_operand_solve() {
        // 48x80 on a 2x2 grid of 32² MCAs: 2x3 chunk grid, y of length 48.
        let a = Matrix::standard_normal(48, 80, 13);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(80, 14);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.y.len(), 48);
        assert_eq!(report.chunks_total, 6);
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
    }

    #[test]
    fn tail_tile_operand_solve() {
        // m % tile != 0 on a multi-MCA grid: the last block row is
        // zero-padded on the crossbar and its padded rows must be dropped
        // from y (not summed into neighbours).
        let a = Matrix::standard_normal(40, 40, 17);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(40, 18);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.y.len(), 40);
        assert_eq!(report.chunks_total, 4);
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
    }

    #[test]
    fn sparsity_skipping_counts() {
        use crate::matrices::BandedSource;
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let x = Vector::standard_normal(256, 9);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        assert_eq!(report.chunks_total, 64);
        assert!(report.chunks_skipped > 30, "{}", report.chunks_skipped);
        assert!(report.rel_err_l2 < 0.1);
    }

    #[test]
    fn ground_truth_opt_out_skips_reference() {
        let a = Matrix::standard_normal(64, 64, 19);
        let src = DenseSource::new(a.clone());
        let x = Vector::standard_normal(64, 20);
        let config = SystemConfig::single_mca(64);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_ground_truth(false);
        let report = solve_distributed(&src, &x, &config, &opts, native()).unwrap();
        // rel_err_* are NaN-flagged when the reference is skipped …
        assert!(report.rel_err_l2.is_nan());
        assert!(report.rel_err_inf.is_nan());
        // … but y itself is unchanged: the in-memory result does not
        // depend on whether the host computed a reference.
        let with_truth = solve_distributed(
            &src,
            &x,
            &config,
            &SolveOptions::default().with_device(Material::EpiRam),
            native(),
        )
        .unwrap();
        assert_eq!(report.y, with_truth.y);
        let b = a.matvec(&x);
        let err = report.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn dimension_mismatch_is_error() {
        let a = Matrix::standard_normal(16, 16, 1);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(8, 2);
        let config = SystemConfig::single_mca(32);
        let opts = SolveOptions::default();
        assert!(solve_distributed(&src, &x, &config, &opts, native()).is_err());
    }

    #[test]
    fn unsupported_cell_size_is_error() {
        let a = Matrix::standard_normal(16, 16, 1);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(16, 2);
        let config = SystemConfig::single_mca(48); // not an artifact size
        let opts = SolveOptions::default();
        let err = solve_distributed(&src, &x, &config, &opts, native()).unwrap_err();
        assert!(
            matches!(err, PlaneError::UnsupportedCell { cell: 48, .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("cell size 48"), "{err}");
    }

    #[test]
    fn no_ec_is_less_accurate() {
        let a = Matrix::standard_normal(128, 128, 11);
        let src = DenseSource::new(a);
        let x = Vector::standard_normal(128, 12);
        let config = SystemConfig::single_mca(128);
        let base = SolveOptions::default().with_device(Material::TaOxHfOx);
        let with_ec =
            solve_distributed(&src, &x, &config, &base.clone().with_ec(true), native()).unwrap();
        let src = DenseSource::new(Matrix::standard_normal(128, 128, 11));
        let no_ec =
            solve_distributed(&src, &x, &config, &base.with_ec(false), native()).unwrap();
        assert!(
            with_ec.rel_err_l2 < no_ec.rel_err_l2 * 0.5,
            "ec {} vs raw {}",
            with_ec.rel_err_l2,
            no_ec.rel_err_l2
        );
    }

    // ---- reduce_partials unit coverage (shared by one-shot and resident
    // paths; exercised here through the coordinator-facing re-export) ----

    #[test]
    fn reduce_partials_tail_block_row() {
        // m = 40, tile = 32: the last block row owns rows 32..40; entries
        // 8..32 of its partial are crossbar padding and must be dropped.
        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        partials.insert((0, 0), Vector::from_vec((0..32).map(|i| i as f64).collect()));
        partials.insert(
            (1, 0),
            Vector::from_vec((0..32).map(|i| 1000.0 + i as f64).collect()),
        );
        let y = reduce_partials(40, 32, &partials);
        assert_eq!(y.len(), 40);
        assert_eq!(y.get(0), 0.0);
        assert_eq!(y.get(31), 31.0);
        assert_eq!(y.get(32), 1000.0);
        assert_eq!(y.get(39), 1007.0);
    }

    #[test]
    fn reduce_partials_non_square_grid_sums_block_cols() {
        // A 2x3 chunk grid: partials in the same block row (different
        // block cols) sum; different block rows land in disjoint spans.
        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        for bj in 0..3usize {
            partials.insert((0, bj), Vector::from_vec(vec![1.0; 4]));
            partials.insert((1, bj), Vector::from_vec(vec![10.0; 4]));
        }
        let y = reduce_partials(8, 4, &partials);
        for i in 0..4 {
            assert_eq!(y.get(i), 3.0, "row {i}");
        }
        for i in 4..8 {
            assert_eq!(y.get(i), 30.0, "row {i}");
        }
    }

    #[test]
    fn reduce_partials_non_square_tail() {
        // Non-square grid AND a ragged tail: m = 6 with tile 4 drops the
        // final two padded rows of block row 1.
        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        partials.insert((0, 0), Vector::from_vec(vec![1.0; 4]));
        partials.insert((0, 1), Vector::from_vec(vec![2.0; 4]));
        partials.insert((1, 0), Vector::from_vec(vec![5.0, 6.0, 99.0, 99.0]));
        partials.insert((1, 1), Vector::from_vec(vec![7.0, 8.0, 99.0, 99.0]));
        let y = reduce_partials(6, 4, &partials);
        assert_eq!(y.len(), 6);
        for i in 0..4 {
            assert_eq!(y.get(i), 3.0, "row {i}");
        }
        assert_eq!(y.get(4), 12.0);
        assert_eq!(y.get(5), 14.0);
    }
}
