//! Metrics and reporting (the paper's "backward pass" — DESIGN.md S12).
//!
//! Relative ℓ2/ℓ∞ error norms (paper Eq. in §2.1), energy/latency
//! aggregation across MCAs (figures report the *mean across all MCAs*),
//! table/CSV/JSON emitters for the benches, [`serving`] statistics
//! (throughput, latency percentiles, write-vs-read energy split) for the
//! resident-session serving layer, and [`convergence`] reports (residual
//! trajectory + whole-solve energy split) for the iterative solvers.

pub mod convergence;
pub mod serving;
pub mod table;

pub use convergence::ConvergenceReport;

use crate::linalg::Vector;
use crate::mca::EnergyLedger;
use crate::util::json::Json;

/// Relative error `‖y − b‖_p / ‖b‖_p` for p ∈ {2, ∞}.
pub fn rel_err_l2(y: &Vector, b: &Vector) -> f64 {
    y.sub(b).norm_l2() / b.norm_l2().max(f64::MIN_POSITIVE)
}

pub fn rel_err_inf(y: &Vector, b: &Vector) -> f64 {
    y.sub(b).norm_inf() / b.norm_inf().max(f64::MIN_POSITIVE)
}

/// Full report of one distributed solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// The in-memory result `y`.
    pub y: Vector,
    /// Relative error norms vs the exact f64 ground truth.
    pub rel_err_l2: f64,
    pub rel_err_inf: f64,
    /// Write energy / latency: mean across MCAs (paper Figs 4/5 caption).
    pub ew_mean: f64,
    pub lw_mean: f64,
    /// Totals / maxima across MCAs (wall-clock latency follows the max).
    pub ew_total: f64,
    pub lw_max: f64,
    pub read_energy_total: f64,
    /// Virtualization accounting.
    pub chunks_total: usize,
    pub chunks_skipped: usize,
    pub mcas_used: usize,
    pub normalization_factor: usize,
    pub row_reassignments: usize,
    /// Encode statistics (averaged over chunks).
    pub mean_wv_iters: f64,
    /// Wall-clock of the whole solve (simulation time, not device time).
    pub wall_seconds: f64,
}

impl SolveReport {
    /// Aggregate per-MCA ledgers into the report's energy/latency fields.
    pub fn fill_from_ledgers(&mut self, ledgers: &[EnergyLedger]) {
        let used: Vec<&EnergyLedger> = ledgers.iter().filter(|l| l.write_passes > 0).collect();
        let n = used.len().max(1) as f64;
        self.mcas_used = used.len();
        self.ew_total = used.iter().map(|l| l.write_energy_j).sum();
        self.ew_mean = self.ew_total / n;
        self.lw_max = used.iter().map(|l| l.write_latency_s).fold(0.0, f64::max);
        self.lw_mean = used.iter().map(|l| l.write_latency_s).sum::<f64>() / n;
        self.read_energy_total = used.iter().map(|l| l.read_energy_j).sum();
    }

    /// Machine-readable JSON (for EXPERIMENTS.md tooling and the CLI).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("rel_err_l2", Json::Num(self.rel_err_l2))
            .set("rel_err_inf", Json::Num(self.rel_err_inf))
            .set("ew_mean_j", Json::Num(self.ew_mean))
            .set("lw_mean_s", Json::Num(self.lw_mean))
            .set("ew_total_j", Json::Num(self.ew_total))
            .set("lw_max_s", Json::Num(self.lw_max))
            .set("read_energy_total_j", Json::Num(self.read_energy_total))
            .set("chunks_total", Json::Num(self.chunks_total as f64))
            .set("chunks_skipped", Json::Num(self.chunks_skipped as f64))
            .set("mcas_used", Json::Num(self.mcas_used as f64))
            .set(
                "normalization_factor",
                Json::Num(self.normalization_factor as f64),
            )
            .set(
                "row_reassignments",
                Json::Num(self.row_reassignments as f64),
            )
            .set("mean_wv_iters", Json::Num(self.mean_wv_iters))
            .set("wall_seconds", Json::Num(self.wall_seconds));
        j
    }

    pub fn empty(y_len: usize) -> SolveReport {
        SolveReport {
            y: Vector::zeros(y_len),
            rel_err_l2: 0.0,
            rel_err_inf: 0.0,
            ew_mean: 0.0,
            lw_mean: 0.0,
            ew_total: 0.0,
            lw_max: 0.0,
            read_energy_total: 0.0,
            chunks_total: 0,
            chunks_skipped: 0,
            mcas_used: 0,
            normalization_factor: 1,
            row_reassignments: 1,
            mean_wv_iters: 0.0,
            wall_seconds: 0.0,
        }
    }
}

/// Mean and sample standard deviation of a series (bench statistics).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::pulse::PassCost;

    #[test]
    fn rel_errors() {
        let b = Vector::from_vec(vec![3.0, 4.0]);
        let y = Vector::from_vec(vec![3.0, 5.0]);
        assert!((rel_err_l2(&y, &b) - 1.0 / 5.0).abs() < 1e-12);
        assert!((rel_err_inf(&y, &b) - 1.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn zero_truth_does_not_divide_by_zero() {
        let b = Vector::zeros(3);
        let y = Vector::from_vec(vec![1.0, 0.0, 0.0]);
        assert!(rel_err_l2(&y, &b).is_finite());
    }

    #[test]
    fn ledger_aggregation_means_over_used_mcas() {
        let mut report = SolveReport::empty(4);
        let mut l1 = EnergyLedger::default();
        l1.record_write(PassCost {
            energy_j: 2.0,
            latency_s: 1.0,
            cells: 1,
            pulses: 1.0,
        });
        let mut l2 = EnergyLedger::default();
        l2.record_write(PassCost {
            energy_j: 4.0,
            latency_s: 3.0,
            cells: 1,
            pulses: 1.0,
        });
        let idle = EnergyLedger::default(); // unused MCA is excluded
        report.fill_from_ledgers(&[l1, l2, idle]);
        assert_eq!(report.mcas_used, 2);
        assert!((report.ew_mean - 3.0).abs() < 1e-12);
        assert!((report.ew_total - 6.0).abs() < 1e-12);
        assert!((report.lw_mean - 2.0).abs() < 1e-12);
        assert!((report.lw_max - 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_contains_fields() {
        let mut report = SolveReport::empty(2);
        report.rel_err_l2 = 0.0123;
        let j = report.to_json();
        assert_eq!(j.get("rel_err_l2").unwrap().as_f64(), Some(0.0123));
        assert!(j.get("normalization_factor").is_some());
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(mean_std(&[5.0]).1, 0.0);
    }
}
