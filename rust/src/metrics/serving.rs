//! Serving-path metrics: throughput, per-solve latency percentiles, and
//! the write-once / read-per-solve energy split for resident crossbar
//! sessions (`crate::server`).
//!
//! The whole point of program-once / solve-many serving is that the
//! conductance write is paid once while reads are nearly free — so the
//! report keeps programming energy and per-solve energy in separate
//! columns and exposes their ratio (`write_amortization`) directly.

use crate::util::json::Json;
use std::time::Instant;

/// Bound on retained per-solve latency samples (ring buffer beyond this).
const MAX_LATENCY_SAMPLES: usize = 65_536;

/// Mutable per-session counters, owned by the session behind its lock.
#[derive(Clone, Debug)]
pub struct ServingStats {
    started: Instant,
    solves: u64,
    batches: u64,
    errors: u64,
    latencies_s: Vec<f64>,
    sample_cursor: usize,
    samples_dropped: u64,
    program_energy_j: f64,
    program_latency_s: f64,
    solve_write_energy_j: f64,
    solve_read_energy_j: f64,
}

impl ServingStats {
    pub fn new() -> ServingStats {
        ServingStats {
            // meliso-lint: allow(clock) -- serving-uptime bookkeeping, reporting only
            started: Instant::now(),
            solves: 0,
            batches: 0,
            errors: 0,
            latencies_s: Vec::new(),
            sample_cursor: 0,
            samples_dropped: 0,
            program_energy_j: 0.0,
            program_latency_s: 0.0,
            solve_write_energy_j: 0.0,
            solve_read_energy_j: 0.0,
        }
    }

    /// Record the one-time programming cost (write–verify of the operand).
    pub fn record_program(&mut self, energy_j: f64, latency_s: f64) {
        self.program_energy_j += energy_j;
        self.program_latency_s += latency_s;
    }

    /// Record one served batch: `vectors` solves in `wall_s` seconds, with
    /// the given energy deltas accumulated across all MCAs.
    pub fn record_batch(&mut self, vectors: usize, wall_s: f64, write_j: f64, read_j: f64) {
        let vectors = vectors.max(1);
        self.batches += 1;
        self.solves += vectors as u64;
        self.solve_write_energy_j += write_j;
        self.solve_read_energy_j += read_j;
        let per_vector = wall_s / vectors as f64;
        let mut dropped = 0u64;
        for _ in 0..vectors {
            if self.latencies_s.len() < MAX_LATENCY_SAMPLES {
                self.latencies_s.push(per_vector);
            } else {
                // The ring is full: overwriting evicts the oldest retained
                // sample, so percentiles describe the most recent window.
                self.latencies_s[self.sample_cursor] = per_vector;
                self.sample_cursor = (self.sample_cursor + 1) % MAX_LATENCY_SAMPLES;
                dropped += 1;
            }
        }
        if dropped > 0 {
            self.samples_dropped += dropped;
            if crate::obs::metrics_on() {
                crate::obs::global()
                    .counter(
                        crate::obs::names::SAMPLES_DROPPED,
                        "Per-solve latency samples evicted from the serving ring",
                        &[],
                    )
                    .add(dropped as f64);
            }
        }
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Snapshot the counters into an immutable report.
    pub fn report(&self) -> ServingReport {
        let mut sorted = self.latencies_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len().max(1) as f64;
        let mean_s = sorted.iter().sum::<f64>() / n;
        let uptime_s = self.started.elapsed().as_secs_f64();
        let per_solve = |total: f64| total / self.solves.max(1) as f64;
        let write_per_solve = per_solve(self.solve_write_energy_j);
        ServingReport {
            solves: self.solves,
            batches: self.batches,
            errors: self.errors,
            uptime_s,
            throughput_sps: self.solves as f64 / uptime_s.max(1e-9),
            latency_mean_ms: mean_s * 1e3,
            latency_p50_ms: percentile(&sorted, 0.50) * 1e3,
            latency_p99_ms: percentile(&sorted, 0.99) * 1e3,
            latency_samples: self.latencies_s.len() as u64,
            latency_samples_dropped: self.samples_dropped,
            program_energy_j: self.program_energy_j,
            program_latency_s: self.program_latency_s,
            solve_write_energy_j: self.solve_write_energy_j,
            solve_read_energy_j: self.solve_read_energy_j,
            write_energy_per_solve_j: write_per_solve,
            read_energy_per_solve_j: per_solve(self.solve_read_energy_j),
            write_amortization: self.program_energy_j / write_per_solve.max(f64::MIN_POSITIVE),
        }
    }
}

impl Default for ServingStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Nearest-rank percentile of a sorted series, `q` in `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Immutable snapshot of a session's serving statistics.
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub solves: u64,
    pub batches: u64,
    pub errors: u64,
    pub uptime_s: f64,
    /// Served vectors per second over the session lifetime.
    pub throughput_sps: f64,
    pub latency_mean_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    /// Samples currently retained in the latency ring.
    pub latency_samples: u64,
    /// Samples evicted once the ring filled; when non-zero the percentiles
    /// describe the most recent `latency_samples` solves, not the lifetime.
    pub latency_samples_dropped: u64,
    /// One-time programming (write) cost of the resident operand.
    pub program_energy_j: f64,
    pub program_latency_s: f64,
    /// Cumulative per-solve costs (input-vector encodes + reads).
    pub solve_write_energy_j: f64,
    pub solve_read_energy_j: f64,
    pub write_energy_per_solve_j: f64,
    pub read_energy_per_solve_j: f64,
    /// Programming energy over per-solve write energy: how many solves the
    /// resident write amortizes across.
    pub write_amortization: f64,
}

impl ServingReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("solves", Json::Num(self.solves as f64))
            .set("batches", Json::Num(self.batches as f64))
            .set("errors", Json::Num(self.errors as f64))
            .set("uptime_s", Json::Num(self.uptime_s))
            .set("throughput_sps", Json::Num(self.throughput_sps))
            .set("latency_mean_ms", Json::Num(self.latency_mean_ms))
            .set("latency_p50_ms", Json::Num(self.latency_p50_ms))
            .set("latency_p99_ms", Json::Num(self.latency_p99_ms))
            .set("latency_samples", Json::Num(self.latency_samples as f64))
            .set(
                "latency_samples_dropped",
                Json::Num(self.latency_samples_dropped as f64),
            )
            .set("program_energy_j", Json::Num(self.program_energy_j))
            .set("program_latency_s", Json::Num(self.program_latency_s))
            .set(
                "solve_write_energy_j",
                Json::Num(self.solve_write_energy_j),
            )
            .set("solve_read_energy_j", Json::Num(self.solve_read_energy_j))
            .set(
                "write_energy_per_solve_j",
                Json::Num(self.write_energy_per_solve_j),
            )
            .set(
                "read_energy_per_solve_j",
                Json::Num(self.read_energy_per_solve_j),
            )
            .set("write_amortization", Json::Num(self.write_amortization));
        j
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let window = if self.latency_samples_dropped > 0 {
            format!(
                " (last {} samples; {} dropped)",
                self.latency_samples, self.latency_samples_dropped
            )
        } else {
            String::new()
        };
        format!(
            "solves {} (batches {}, errors {}) over {:.2}s -> {:.1} solves/s\n\
             latency ms: mean {:.3}, p50 {:.3}, p99 {:.3}{}\n\
             energy J: program {:.3e} (once), write/solve {:.3e}, read/solve {:.3e}\n\
             write amortization: {:.1}x",
            self.solves,
            self.batches,
            self.errors,
            self.uptime_s,
            self.throughput_sps,
            self.latency_mean_ms,
            self.latency_p50_ms,
            self.latency_p99_ms,
            window,
            self.program_energy_j,
            self.write_energy_per_solve_j,
            self.read_energy_per_solve_j,
            self.write_amortization,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 51.0); // round(99 * 0.5) = 50
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn batches_accumulate() {
        let mut s = ServingStats::new();
        s.record_program(10.0, 0.5);
        s.record_batch(4, 0.08, 1.0, 2.0);
        s.record_batch(1, 0.01, 0.25, 0.5);
        let r = s.report();
        assert_eq!(r.solves, 5);
        assert_eq!(r.batches, 2);
        assert!((r.solve_write_energy_j - 1.25).abs() < 1e-12);
        assert!((r.solve_read_energy_j - 2.5).abs() < 1e-12);
        assert!((r.write_energy_per_solve_j - 0.25).abs() < 1e-12);
        assert!((r.program_energy_j - 10.0).abs() < 1e-12);
        assert!((r.write_amortization - 40.0).abs() < 1e-9);
        assert!(r.throughput_sps > 0.0);
        // 4 samples at 20ms, 1 at 10ms.
        assert!((r.latency_p50_ms - 20.0).abs() < 1e-9, "{}", r.latency_p50_ms);
    }

    #[test]
    fn latency_samples_are_bounded() {
        let mut s = ServingStats::new();
        for _ in 0..3 {
            s.record_batch(40_000, 1.0, 0.0, 0.0);
        }
        let r = s.report();
        assert_eq!(r.solves, 120_000);
        assert!(s.latencies_s.len() <= 65_536);
        assert_eq!(r.latency_samples, 65_536);
        assert_eq!(r.latency_samples_dropped, 120_000 - 65_536);
    }

    #[test]
    fn no_samples_dropped_below_capacity() {
        let mut s = ServingStats::new();
        s.record_batch(100, 1.0, 0.0, 0.0);
        let r = s.report();
        assert_eq!(r.latency_samples, 100);
        assert_eq!(r.latency_samples_dropped, 0);
        assert!(!r.render().contains("dropped"));
    }

    #[test]
    fn percentiles_follow_the_window_after_wraparound() {
        let mut s = ServingStats::new();
        // Fill the ring with slow 1s solves, then push exactly one full
        // window of fast 1ms solves: every retained sample must be fast.
        s.record_batch(MAX_LATENCY_SAMPLES, MAX_LATENCY_SAMPLES as f64, 0.0, 0.0);
        s.record_batch(MAX_LATENCY_SAMPLES, MAX_LATENCY_SAMPLES as f64 * 1e-3, 0.0, 0.0);
        let r = s.report();
        assert_eq!(r.latency_samples, MAX_LATENCY_SAMPLES as u64);
        assert_eq!(r.latency_samples_dropped, MAX_LATENCY_SAMPLES as u64);
        assert!((r.latency_p50_ms - 1.0).abs() < 1e-9, "{}", r.latency_p50_ms);
        assert!((r.latency_p99_ms - 1.0).abs() < 1e-9, "{}", r.latency_p99_ms);
        assert!(r.render().contains("dropped"));
    }

    #[test]
    fn partial_wraparound_keeps_a_mixed_window() {
        let mut s = ServingStats::new();
        s.record_batch(MAX_LATENCY_SAMPLES, MAX_LATENCY_SAMPLES as f64 * 2.0, 0.0, 0.0);
        // Overwrite just over half the ring with 1ms samples: p50 lands in
        // the fast half while p99 still sees the surviving slow samples.
        let fast = MAX_LATENCY_SAMPLES / 2 + 1;
        s.record_batch(fast, fast as f64 * 1e-3, 0.0, 0.0);
        let r = s.report();
        assert_eq!(r.latency_samples_dropped, fast as u64);
        assert!((r.latency_p50_ms - 1.0).abs() < 1e-9, "{}", r.latency_p50_ms);
        assert!(
            (r.latency_p99_ms - 2000.0).abs() < 1e-6,
            "{}",
            r.latency_p99_ms
        );
        let j = r.to_json();
        assert_eq!(
            j.get("latency_samples_dropped").unwrap().as_f64(),
            Some(fast as f64)
        );
    }

    #[test]
    fn errors_counted() {
        let mut s = ServingStats::new();
        s.record_error();
        s.record_error();
        assert_eq!(s.report().errors, 2);
    }

    #[test]
    fn json_has_serving_fields() {
        let mut s = ServingStats::new();
        s.record_batch(2, 0.02, 0.5, 1.0);
        let j = s.report().to_json();
        assert_eq!(j.get("solves").unwrap().as_f64(), Some(2.0));
        assert!(j.get("latency_p99_ms").is_some());
        assert!(j.get("write_amortization").is_some());
    }
}
