//! Plain-text table and CSV emitters for the bench harnesses
//! (paper-table-shaped output).

/// A simple left-header table: rows of labelled numeric cells.
pub struct TableBuilder {
    title: String,
    headers: Vec<String>,
    rows: Vec<(String, Vec<String>)>,
}

impl TableBuilder {
    pub fn new(title: &str, headers: &[&str]) -> TableBuilder {
        TableBuilder {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row {label:?} has wrong arity"
        );
        self.rows.push((label.to_string(), cells));
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths = vec![0usize; self.headers.len() + 1];
        widths[0] = self
            .rows
            .iter()
            .map(|(l, _)| l.chars().count())
            .max()
            .unwrap_or(0);
        for (k, h) in self.headers.iter().enumerate() {
            widths[k + 1] = h.chars().count();
        }
        for (_, cells) in &self.rows {
            for (k, c) in cells.iter().enumerate() {
                widths[k + 1] = widths[k + 1].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let pad = |s: &str, w: usize| {
            let extra = w.saturating_sub(s.chars().count());
            format!("{s}{}", " ".repeat(extra))
        };
        out.push_str(&pad("", widths[0]));
        for (k, h) in self.headers.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&pad(h, widths[k + 1]));
        }
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(&pad(label, widths[0]));
            for (k, c) in cells.iter().enumerate() {
                out.push_str("  ");
                out.push_str(&pad(c, widths[k + 1]));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (label column first).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str("label");
        for h in &self.headers {
            out.push(',');
            out.push_str(&esc(h));
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(&esc(label));
            for c in cells {
                out.push(',');
                out.push_str(&esc(c));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableBuilder::new("Demo", &["a", "long-header"]);
        t.row("first", vec!["1".into(), "2".into()]);
        t.row("second-longer", vec!["3.25".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("first"));
        assert!(s.contains("long-header"));
        // Every data line has the same width.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = TableBuilder::new("x", &["v"]);
        t.row("with,comma", vec!["say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = TableBuilder::new("x", &["a", "b"]);
        t.row("r", vec!["1".into()]);
    }
}
