//! Convergence reporting for the iterative `Ax = b` solvers
//! (`crate::iterative`): the per-iteration residual trajectory plus the
//! write-once / read-per-iteration energy split across the whole solve.
//!
//! The report makes the serving-layer economics of an iterative solve
//! legible at a glance: one programming pass (`program_energy_j`, paid at
//! session open) against the cumulative per-iteration read/encode costs —
//! the amortization that makes in-memory Krylov methods worthwhile.

use crate::linalg::Vector;
use crate::util::json::Json;

/// Full report of one iterative system solve.
#[derive(Clone, Debug)]
pub struct ConvergenceReport {
    /// Method name (`cg`, `gmres`, `jacobi`, `richardson`).
    pub method: String,
    /// The solution iterate.
    pub x: Vector,
    pub converged: bool,
    /// Target relative residual.
    pub tol: f64,
    /// Final relative residual `‖b − Ax‖₂ / ‖b‖₂` (exact f64 host-side).
    pub rel_residual: f64,
    /// MVM-bearing inner iterations.
    pub iterations: usize,
    /// Outer iterative-refinement corrections applied.
    pub refinements: usize,
    /// MVMs served by the operator over the solve.
    pub mvms: u64,
    /// Per-iteration relative residual trajectory.
    pub residual_history: Vec<f64>,
    /// Write–verify programming passes paid (1 for a resident session,
    /// however many iterations the solve took).
    pub programming_passes: u64,
    /// One-time operand programming energy (write–verify at session open).
    pub program_energy_j: f64,
    /// Cumulative per-iteration write energy (input-vector encodes).
    pub solve_write_energy_j: f64,
    /// Cumulative per-iteration read energy (crossbar activations).
    pub read_energy_j: f64,
    pub wall_seconds: f64,
}

impl ConvergenceReport {
    /// Programming energy over mean per-MVM write energy: how many solver
    /// iterations the one-time operand write amortizes across.
    pub fn write_amortization(&self) -> f64 {
        if self.mvms == 0 {
            return 0.0;
        }
        let per_mvm = self.solve_write_energy_j / self.mvms as f64;
        self.program_energy_j / per_mvm.max(f64::MIN_POSITIVE)
    }

    /// Machine-readable JSON (CLI `--json`, bench artifacts).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("method", Json::Str(self.method.clone()))
            .set("converged", Json::Bool(self.converged))
            .set("tol", Json::Num(self.tol))
            .set("rel_residual", Json::Num(self.rel_residual))
            .set("iterations", Json::Num(self.iterations as f64))
            .set("refinements", Json::Num(self.refinements as f64))
            .set("mvms", Json::Num(self.mvms as f64))
            .set(
                "residual_history",
                Json::Arr(self.residual_history.iter().map(|&v| Json::Num(v)).collect()),
            )
            .set(
                "programming_passes",
                Json::Num(self.programming_passes as f64),
            )
            .set("program_energy_j", Json::Num(self.program_energy_j))
            .set(
                "solve_write_energy_j",
                Json::Num(self.solve_write_energy_j),
            )
            .set("read_energy_j", Json::Num(self.read_energy_j))
            .set("write_amortization", Json::Num(self.write_amortization()))
            .set("wall_seconds", Json::Num(self.wall_seconds));
        j
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        format!(
            "{}: {} at rel residual {:.3e} (tol {:.1e}) — {} iterations, \
             {} refinements, {} MVMs in {:.2}s\n\
             energy J: program {:.3e} ({} pass{}), encode/solve {:.3e}, \
             read {:.3e} — write amortization {:.1}x",
            self.method,
            if self.converged {
                "converged"
            } else {
                "NOT converged"
            },
            self.rel_residual,
            self.tol,
            self.iterations,
            self.refinements,
            self.mvms,
            self.wall_seconds,
            self.program_energy_j,
            self.programming_passes,
            if self.programming_passes == 1 { "" } else { "es" },
            self.solve_write_energy_j,
            self.read_energy_j,
            self.write_amortization(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ConvergenceReport {
        ConvergenceReport {
            method: "cg".to_string(),
            x: Vector::zeros(4),
            converged: true,
            tol: 1e-6,
            rel_residual: 4.2e-7,
            iterations: 30,
            refinements: 5,
            mvms: 30,
            residual_history: vec![1.0, 1e-2, 4.2e-7],
            programming_passes: 1,
            program_energy_j: 3.0,
            solve_write_energy_j: 0.3,
            read_energy_j: 0.06,
            wall_seconds: 0.5,
        }
    }

    #[test]
    fn amortization_is_program_over_per_mvm_write() {
        let r = sample();
        // 3.0 / (0.3 / 30) = 300.
        assert!((r.write_amortization() - 300.0).abs() < 1e-9);
        let mut idle = sample();
        idle.mvms = 0;
        assert_eq!(idle.write_amortization(), 0.0);
    }

    #[test]
    fn json_has_convergence_fields() {
        let j = sample().to_json();
        assert_eq!(j.get("method").and_then(|v| v.as_str()), Some("cg"));
        assert_eq!(j.get("iterations").unwrap().as_f64(), Some(30.0));
        assert_eq!(j.get("programming_passes").unwrap().as_f64(), Some(1.0));
        assert!(j.get("residual_history").is_some());
        assert!(j.get("write_amortization").is_some());
    }

    #[test]
    fn render_mentions_method_and_verdict() {
        let text = sample().render();
        assert!(text.contains("cg"));
        assert!(text.contains("converged"));
        assert!(text.contains("1 pass"));
    }
}
