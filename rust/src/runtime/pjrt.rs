//! PJRT engine: loads the AOT HLO-text artifacts and executes them on the
//! `xla` crate's CPU client.
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1's proto path rejects; the text parser
//! reassigns ids — see python/compile/aot.py and /opt/xla-example).
//!
//! PJRT handles are not `Send`/`Sync`, so [`PjrtEngine`] must stay on one
//! thread; [`super::service`] wraps it in the runtime-service thread that
//! the rest of the system talks to.

use super::{EcMvmRequest, EcMvmResponse};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// Without the vendored crate, `xla::*` resolves to the API-compatible
// stub whose client constructor fails cleanly (see `super::xla_stub`):
// the whole engine stays typechecked under `--features pjrt`, and
// `PjrtBackend::start` reports the missing vendor exactly like a missing
// artifact directory.
#[cfg(not(feature = "xla-vendored"))]
use super::xla_stub as xla;

/// Artifact kinds produced by `make artifacts`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    Mvm,
    EcMvm,
}

impl ArtifactKind {
    fn prefix(self) -> &'static str {
        match self {
            ArtifactKind::Mvm => "mvm",
            ArtifactKind::EcMvm => "ec_mvm",
        }
    }
}

/// Single-threaded PJRT execution engine.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    exes: BTreeMap<(ArtifactKind, usize), xla::PjRtLoadedExecutable>,
    sizes: Vec<usize>,
}

impl PjrtEngine {
    /// Load every artifact listed in `<dir>/manifest.json`, compiling each
    /// on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<PjrtEngine, String> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| format!("cannot read {}: {e}", manifest_path.display()))?;
        let manifest = Json::parse(&text).map_err(|e| format!("bad manifest: {e}"))?;
        let sizes: Vec<usize> = manifest
            .get("tile_sizes")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing tile_sizes")?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        if sizes.is_empty() {
            return Err("manifest lists no tile sizes".into());
        }

        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        let mut exes = BTreeMap::new();
        let artifacts = manifest
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .ok_or("manifest missing artifacts")?;
        for (key, meta) in artifacts {
            let file = meta
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("artifact {key} missing file"))?;
            let tile = meta
                .get("tile")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("artifact {key} missing tile"))?;
            let kind = if key.starts_with("ec_mvm") {
                ArtifactKind::EcMvm
            } else if key.starts_with("mvm") {
                ArtifactKind::Mvm
            } else {
                continue; // unknown artifact kinds are ignored
            };
            let path: PathBuf = dir.join(file);
            let exe = compile_hlo_text(&client, &path)?;
            exes.insert((kind, tile), exe);
        }
        crate::log_info!(
            "runtime",
            "loaded {} artifacts from {} (tiles {:?})",
            exes.len(),
            dir.display(),
            sizes
        );
        Ok(PjrtEngine {
            client,
            exes,
            sizes,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn tile_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }

    fn exe(&self, kind: ArtifactKind, n: usize) -> Result<&xla::PjRtLoadedExecutable, String> {
        self.exes.get(&(kind, n)).ok_or_else(|| {
            format!(
                "no {}_{n} artifact loaded (available tiles: {:?})",
                kind.prefix(),
                self.sizes
            )
        })
    }

    /// Execute the plain `mvm_{n}` artifact.
    pub fn mvm(&self, n: usize, at: &[f32], xt: &[f32]) -> Result<Vec<f32>, String> {
        if at.len() != n * n || xt.len() != n {
            return Err(format!("mvm shape mismatch at n={n}"));
        }
        let exe = self.exe(ArtifactKind::Mvm, n)?;
        let a_lit = mat_literal(at, n, n)?;
        let x_lit = mat_literal(xt, n, 1)?;
        let result = exe
            .execute::<xla::Literal>(&[a_lit, x_lit])
            .map_err(|e| format!("mvm_{n} execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("mvm_{n} fetch: {e}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format!("mvm_{n} untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| format!("mvm_{n} read: {e}"))
    }

    /// Execute the fused `ec_mvm_{n}` artifact.
    pub fn ec_mvm(&self, req: &EcMvmRequest) -> Result<EcMvmResponse, String> {
        let n = req.n;
        let exe = self.exe(ArtifactKind::EcMvm, n)?;
        let args = [
            mat_literal(&req.a, n, n)?,
            mat_literal(&req.at, n, n)?,
            mat_literal(&req.x, n, 1)?,
            mat_literal(&req.xt, n, 1)?,
            mat_literal(&req.minv, n, n)?,
            mat_literal(&req.nv, n, 1)?,
            mat_literal(&req.nu, n, 1)?,
            mat_literal(&req.ny, n, 1)?,
        ];
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| format!("ec_mvm_{n} execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("ec_mvm_{n} fetch: {e}"))?;
        let (y_raw, p, y_corr) = result
            .to_tuple3()
            .map_err(|e| format!("ec_mvm_{n} untuple: {e}"))?;
        Ok(EcMvmResponse {
            y_raw: y_raw
                .to_vec::<f32>()
                .map_err(|e| format!("ec_mvm_{n} read y_raw: {e}"))?,
            p: p.to_vec::<f32>()
                .map_err(|e| format!("ec_mvm_{n} read p: {e}"))?,
            y_corr: y_corr
                .to_vec::<f32>()
                .map_err(|e| format!("ec_mvm_{n} read y_corr: {e}"))?,
        })
    }
}

fn compile_hlo_text(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable, String> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| format!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| format!("compile {}: {e}", path.display()))
}

fn mat_literal(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal, String> {
    if data.len() != rows * cols {
        return Err(format!(
            "literal shape mismatch: {} elements for {rows}x{cols}",
            data.len()
        ));
    }
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| format!("reshape literal: {e}"))
}

pub use super::default_artifact_dir;
