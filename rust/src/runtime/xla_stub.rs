//! API-compatible stand-in for the vendored `xla` crate.
//!
//! The `pjrt` feature compiles the full runtime-service plumbing
//! ([`super::pjrt`], [`super::service`]) so the PJRT path stays
//! typechecked in every build — but the real `xla` crate (PJRT CPU
//! client + HLO compilation) is a vendored native dependency that not
//! every environment carries.  When the `xla-vendored` feature is off,
//! [`super::pjrt`] resolves `xla::*` to this module instead: the same
//! surface, with [`PjRtClient::cpu`] failing cleanly at construction so
//! callers fall back to the native backend exactly as they would on a
//! missing artifact directory.  Nothing past construction is reachable —
//! the unconstructible client makes that a type-level guarantee.

use std::path::Path;

/// Error type mirroring the vendored crate's (stringly, `Display`-able).
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "the vendored `xla` crate is not linked (enable the `xla-vendored` feature after \
         vendoring third_party/xla-rs)"
            .to_string(),
    )
}

/// Unconstructible PJRT client: [`PjRtClient::cpu`] always fails.
pub struct PjRtClient {
    unconstructible: std::convert::Infallible,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.unconstructible {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match self.unconstructible {}
    }
}

/// Unreachable executable handle (only a client can produce one).
pub struct PjRtLoadedExecutable {
    unconstructible: std::convert::Infallible,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.unconstructible {}
    }
}

/// Unreachable device buffer (only an executable can produce one).
pub struct PjRtBuffer {
    unconstructible: std::convert::Infallible,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.unconstructible {}
    }
}

/// Host literal.  Constructible (the engine builds literals before any
/// client call), but every device-facing operation fails.
pub struct Literal {}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Compilable computation.
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => unreachable!("stub client must not construct"),
        };
        assert!(err.to_string().contains("xla"), "{err}");
    }

    #[test]
    fn literals_construct_but_do_not_execute() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope")).is_err());
    }
}
