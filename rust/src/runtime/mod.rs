//! Execution runtime: the bridge between the Rust coordinator and the
//! AOT-compiled L2/L1 artifacts (DESIGN.md S7).
//!
//! * [`ExecBackend`] — the per-tile compute contract (plain MVM and the
//!   fused two-tier-EC MVM).
//! * [`native`] — pure-Rust f32 implementation mirroring the Pallas/jnp
//!   oracle semantics exactly; used as the digital baseline, as a fallback
//!   when artifacts are absent, and to cross-check PJRT numerics.
//! * [`pjrt`] — loads `artifacts/*.hlo.txt` through the `xla` crate's PJRT
//!   CPU client and executes them.  PJRT handles are not `Send`, so a
//!   dedicated **runtime-service thread** owns the client and executables
//!   and serves requests over a channel ([`service`]).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod service;
#[cfg(all(feature = "pjrt", not(feature = "xla-vendored")))]
mod xla_stub;

/// Stub `pjrt` module when the feature (and its vendored `xla` crate) is
/// absent; keeps the `runtime::pjrt::default_artifact_dir` path alive for
/// the CLI `artifacts` command and the solver's error path.
#[cfg(not(feature = "pjrt"))]
pub mod pjrt {
    pub use super::default_artifact_dir;
}

/// Default artifact directory: `$MELISO_ARTIFACTS` or `./artifacts`.
/// Feature-independent — both the PJRT engine and its stub re-export it.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("MELISO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

use std::sync::Arc;

/// Inputs to a fused EC MVM over one tile (all row-major f32, square n).
pub struct EcMvmRequest {
    pub n: usize,
    /// True operand `A` (n*n).
    pub a: Vec<f32>,
    /// Encoded `Ã` (n*n).
    pub at: Vec<f32>,
    /// True input `x` (n).
    pub x: Vec<f32>,
    /// Encoded `x̃` (n).
    pub xt: Vec<f32>,
    /// Encoded denoiser `M̃inv` (n*n).
    pub minv: Vec<f32>,
    /// Read-noise multipliers for the three measured products (n each).
    pub nv: Vec<f32>,
    pub nu: Vec<f32>,
    pub ny: Vec<f32>,
}

/// Outputs of a fused EC MVM.
#[derive(Clone, Debug)]
pub struct EcMvmResponse {
    /// Uncorrected measured product `Ãx̃ ∘ ny`.
    pub y_raw: Vec<f32>,
    /// First-order corrected `p`.
    pub p: Vec<f32>,
    /// Second-order denoised `y_corr`.
    pub y_corr: Vec<f32>,
}

/// Per-tile compute backend.  `n` is always one of the artifact tile sizes;
/// the virtualization layer pads to guarantee it.
pub trait ExecBackend: Send + Sync {
    /// Plain (no-EC) tile MVM: `y = Ã x̃`.  Operands are taken by value —
    /// the hot path hands buffers straight to the runtime service with no
    /// intermediate clone (EXPERIMENTS.md §Perf).
    fn mvm(&self, n: usize, at: Vec<f32>, xt: Vec<f32>) -> Result<Vec<f32>, String>;

    /// Fused two-tier EC MVM (see [`EcMvmRequest`]); request by value.
    fn ec_mvm(&self, req: EcMvmRequest) -> Result<EcMvmResponse, String>;

    /// Tile sizes this backend can execute.
    fn tile_sizes(&self) -> Vec<usize>;

    fn name(&self) -> &'static str;
}

/// Pick the smallest supported tile size that fits `n` (or the largest
/// available if `n` exceeds them all — the virtualization layer will then
/// block-partition down to it).
pub fn fit_tile(sizes: &[usize], n: usize) -> usize {
    let mut sorted = sizes.to_vec();
    sorted.sort_unstable();
    for &s in &sorted {
        if s >= n {
            return s;
        }
    }
    *sorted.last().expect("backend advertises no tile sizes")
}

/// Shared handle type used across the coordinator.
pub type Backend = Arc<dyn ExecBackend>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_tile_picks_smallest_fitting() {
        let sizes = vec![512, 32, 128, 64, 256, 1024];
        assert_eq!(fit_tile(&sizes, 1), 32);
        assert_eq!(fit_tile(&sizes, 32), 32);
        assert_eq!(fit_tile(&sizes, 33), 64);
        assert_eq!(fit_tile(&sizes, 66), 128);
        assert_eq!(fit_tile(&sizes, 1024), 1024);
        assert_eq!(fit_tile(&sizes, 5000), 1024);
    }
}
