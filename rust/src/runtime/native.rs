//! Pure-Rust f32 backend mirroring the L1/L2 artifact semantics.
//!
//! This is the *specification twin* of `python/compile/kernels/ref.py`: the
//! same products, the same combine, the same f32 arithmetic.  It serves as
//! the digital baseline in ablations, the fallback when `artifacts/` is
//! absent, and the oracle the PJRT path is cross-checked against in
//! integration tests.

use super::{EcMvmRequest, EcMvmResponse, ExecBackend};

/// Pure-Rust backend; supports any tile size.
pub struct NativeBackend {
    sizes: Vec<usize>,
}

impl NativeBackend {
    pub fn new() -> Self {
        // Advertise the standard artifact ladder so scheduling decisions are
        // identical whichever backend runs.
        NativeBackend {
            sizes: vec![32, 64, 128, 256, 512, 1024],
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Row-major f32 matvec: `y[m] = a[m,n] @ x[n]`.
///
/// The inner loop is written over 4-wide accumulators so the compiler can
/// keep independent dependency chains in registers (see EXPERIMENTS.md
/// §Perf — this is the hot path for every tile MVM on the native backend).
pub fn matvec_f32(m: usize, n: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = [0.0f32; 4];
        let chunks = n / 4;
        for k in 0..chunks {
            let b = k * 4;
            acc[0] += row[b] * x[b];
            acc[1] += row[b + 1] * x[b + 1];
            acc[2] += row[b + 2] * x[b + 2];
            acc[3] += row[b + 3] * x[b + 3];
        }
        let mut tail = 0.0f32;
        for k in chunks * 4..n {
            tail += row[k] * x[k];
        }
        *yi = acc[0] + acc[1] + acc[2] + acc[3] + tail;
    }
}

impl ExecBackend for NativeBackend {
    fn mvm(&self, n: usize, at: Vec<f32>, xt: Vec<f32>) -> Result<Vec<f32>, String> {
        if at.len() != n * n || xt.len() != n {
            return Err(format!(
                "mvm shape mismatch: n={n}, |A|={}, |x|={}",
                at.len(),
                xt.len()
            ));
        }
        let mut y = vec![0.0f32; n];
        matvec_f32(n, n, &at, &xt, &mut y);
        Ok(y)
    }

    fn ec_mvm(&self, req: EcMvmRequest) -> Result<EcMvmResponse, String> {
        let n = req.n;
        if req.a.len() != n * n
            || req.at.len() != n * n
            || req.minv.len() != n * n
            || req.x.len() != n
            || req.xt.len() != n
            || req.nv.len() != n
            || req.nu.len() != n
            || req.ny.len() != n
        {
            return Err(format!("ec_mvm shape mismatch at n={n}"));
        }
        let mut v = vec![0.0f32; n]; // Ãx
        let mut u = vec![0.0f32; n]; // Ax̃
        let mut y = vec![0.0f32; n]; // Ãx̃
        matvec_f32(n, n, &req.at, &req.x, &mut v);
        matvec_f32(n, n, &req.a, &req.xt, &mut u);
        matvec_f32(n, n, &req.at, &req.xt, &mut y);

        // First-order combine with read noise (ec_combine kernel semantics).
        let mut p = vec![0.0f32; n];
        for i in 0..n {
            p[i] = v[i] * req.nv[i] + u[i] * req.nu[i] - y[i] * req.ny[i];
        }
        // Second-order denoise: y_corr = M̃inv p.
        let mut y_corr = vec![0.0f32; n];
        matvec_f32(n, n, &req.minv, &p, &mut y_corr);
        // Measured raw output.
        let y_raw: Vec<f32> = y.iter().zip(&req.ny).map(|(a, b)| a * b).collect();
        Ok(EcMvmResponse { y_raw, p, y_corr })
    }

    fn tile_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn matvec_identity() {
        let n = 8;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let x = rand_vec(n, 1);
        let mut y = vec![0.0f32; n];
        matvec_f32(n, n, &a, &x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn matvec_matches_naive() {
        let (m, n) = (13, 29); // deliberately not multiples of 4
        let a = rand_vec(m * n, 2);
        let x = rand_vec(n, 3);
        let mut y = vec![0.0f32; m];
        matvec_f32(m, n, &a, &x, &mut y);
        for i in 0..m {
            let want: f32 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-4, "row {i}: {} vs {want}", y[i]);
        }
    }

    #[test]
    fn ec_mvm_zero_noise_reduces_to_exact() {
        let n = 16;
        let backend = NativeBackend::new();
        let a = rand_vec(n * n, 4);
        let x = rand_vec(n, 5);
        let mut minv = vec![0.0f32; n * n];
        for i in 0..n {
            minv[i * n + i] = 1.0;
        }
        let ones = vec![1.0f32; n];
        let req = EcMvmRequest {
            n,
            a: a.clone(),
            at: a.clone(),
            x: x.clone(),
            xt: x.clone(),
            minv,
            nv: ones.clone(),
            nu: ones.clone(),
            ny: ones,
        };
        let resp = backend.ec_mvm(req).unwrap();
        let want = backend.mvm(n, a.clone(), x.clone()).unwrap();
        for i in 0..n {
            assert!((resp.y_raw[i] - want[i]).abs() < 1e-5);
            assert!((resp.p[i] - want[i]).abs() < 1e-4);
            assert!((resp.y_corr[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn ec_mvm_cancels_first_order() {
        let n = 64;
        let backend = NativeBackend::new();
        let a = rand_vec(n * n, 6);
        let x = rand_vec(n, 7);
        let eps = 0.01f32;
        // Distinct error magnitudes so the first-order terms do not cancel
        // by construction (eps_a + eps_x != 0).
        let at: Vec<f32> = a.iter().map(|v| v * (1.0 + eps)).collect();
        let xt: Vec<f32> = x.iter().map(|v| v * (1.0 + 2.0 * eps)).collect();
        let mut minv = vec![0.0f32; n * n];
        for i in 0..n {
            minv[i * n + i] = 1.0;
        }
        let ones = vec![1.0f32; n];
        let req = EcMvmRequest {
            n,
            a: a.clone(),
            at,
            x: x.clone(),
            xt,
            minv,
            nv: ones.clone(),
            nu: ones.clone(),
            ny: ones,
        };
        let resp = backend.ec_mvm(req).unwrap();
        let b = backend.mvm(n, a.clone(), x.clone()).unwrap();
        let rel = |got: &[f32]| {
            let num: f32 = got
                .iter()
                .zip(&b)
                .map(|(g, w)| (g - w) * (g - w))
                .sum::<f32>()
                .sqrt();
            let den: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
            num / den
        };
        let raw_err = rel(&resp.y_raw);
        let p_err = rel(&resp.p);
        // p = Ax(1 - eps^2): error ~1e-4 vs raw ~eps.
        assert!(raw_err > 5e-3, "raw {raw_err}");
        assert!(p_err < raw_err * 0.1, "p {p_err} raw {raw_err}");
    }

    #[test]
    fn shape_errors_reported() {
        let backend = NativeBackend::new();
        assert!(backend.mvm(4, vec![0.0; 7], vec![0.0; 4]).is_err());
        let req = EcMvmRequest {
            n: 4,
            a: vec![0.0; 16],
            at: vec![0.0; 16],
            x: vec![0.0; 3], // wrong
            xt: vec![0.0; 4],
            minv: vec![0.0; 16],
            nv: vec![0.0; 4],
            nu: vec![0.0; 4],
            ny: vec![0.0; 4],
        };
        assert!(backend.ec_mvm(req).is_err());
    }
}
