//! Runtime-service thread: makes the single-threaded `PjrtEngine`
//! (`super::pjrt`, behind the `pjrt` feature) available behind the
//! `Send + Sync` [`ExecBackend`] interface.
//!
//! PJRT client/executable handles are `!Send`, so a dedicated thread owns
//! the engine and serves requests over an mpsc channel; callers block on a
//! per-request reply channel.  On a multi-core host this serializes tile
//! executions per service — matching the paper's model of an MCA executing
//! one analog MVM at a time — while the coordinator's worker pool still
//! overlaps encode (Rust) with execute (PJRT).

#[cfg(feature = "pjrt")]
use super::pjrt::PjrtEngine;
use super::{EcMvmRequest, EcMvmResponse, ExecBackend};
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::sync::mpsc;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;
#[cfg(feature = "pjrt")]
use std::thread::JoinHandle;
#[cfg(feature = "pjrt")]
use std::time::Duration;

/// Service-loop wake-up tick (lint rule C1: no unbounded receives): the
/// engine thread re-checks channel liveness at this cadence while idle.
#[cfg(feature = "pjrt")]
const SERVICE_TICK: Duration = Duration::from_millis(200);

/// Hard bound on one caller's wait for a reply.  The engine executes one
/// tile MVM at a time, far below this; if the service thread wedges (a
/// hung PJRT call), callers get a typed error instead of blocking forever.
#[cfg(feature = "pjrt")]
const REPLY_DEADLINE: Duration = Duration::from_secs(600);

/// Bounded reply wait shared by init and per-request paths.
#[cfg(feature = "pjrt")]
fn recv_reply<T>(rx: &mpsc::Receiver<T>, what: &str) -> Result<T, String> {
    match rx.recv_timeout(REPLY_DEADLINE) {
        Ok(v) => Ok(v),
        Err(mpsc::RecvTimeoutError::Timeout) => Err(format!(
            "runtime service unresponsive for {}s awaiting {what}",
            REPLY_DEADLINE.as_secs()
        )),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err(format!("runtime service dropped {what}"))
        }
    }
}

/// Placeholder backend when the `pjrt` feature (and its vendored `xla`
/// dependency) is absent: [`PjrtBackend::start`] always fails with a clear
/// message, so callers fall back to the native twin.  The type cannot be
/// constructed, making the trait methods unreachable by construction.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtBackend {
    unconstructible: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtBackend {
    pub fn start(dir: &Path) -> Result<PjrtBackend, String> {
        Err(format!(
            "PJRT runtime support is not compiled in (build with `--features pjrt` and the \
             vendored `xla` crate); artifact dir {}",
            dir.display()
        ))
    }
}

#[cfg(not(feature = "pjrt"))]
impl ExecBackend for PjrtBackend {
    fn mvm(&self, _n: usize, _at: Vec<f32>, _xt: Vec<f32>) -> Result<Vec<f32>, String> {
        match self.unconstructible {}
    }

    fn ec_mvm(&self, _req: EcMvmRequest) -> Result<EcMvmResponse, String> {
        match self.unconstructible {}
    }

    fn tile_sizes(&self) -> Vec<usize> {
        match self.unconstructible {}
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(feature = "pjrt")]
enum Request {
    Mvm {
        n: usize,
        at: Vec<f32>,
        xt: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>, String>>,
    },
    EcMvm {
        req: Box<EcMvmRequest>,
        reply: mpsc::Sender<Result<EcMvmResponse, String>>,
    },
    Shutdown,
}

/// `ExecBackend` implementation backed by the runtime-service thread.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    tx: Mutex<mpsc::Sender<Request>>,
    sizes: Vec<usize>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Start the service thread and load artifacts from `dir`.
    pub fn start(dir: &Path) -> Result<PjrtBackend, String> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<Vec<usize>, String>>();
        let dir = dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("meliso-runtime".into())
            .spawn(move || {
                let engine = match PjrtEngine::load(&dir) {
                    Ok(engine) => {
                        let _ = init_tx.send(Ok(engine.tile_sizes()));
                        engine
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                loop {
                    let req = match rx.recv_timeout(SERVICE_TICK) {
                        Ok(req) => req,
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    };
                    match req {
                        Request::Mvm { n, at, xt, reply } => {
                            let _ = reply.send(engine.mvm(n, &at, &xt));
                        }
                        Request::EcMvm { req, reply } => {
                            let _ = reply.send(engine.ec_mvm(&req));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| format!("spawn runtime service: {e}"))?;
        let sizes = recv_reply(&init_rx, "artifact init")??;
        Ok(PjrtBackend {
            tx: Mutex::new(tx),
            sizes,
            handle: Mutex::new(Some(handle)),
        })
    }

    fn send(&self, req: Request) -> Result<(), String> {
        self.tx
            .lock()
            .map_err(|_| "runtime service mutex poisoned".to_string())?
            .send(req)
            .map_err(|_| "runtime service gone".to_string())
    }
}

#[cfg(feature = "pjrt")]
impl ExecBackend for PjrtBackend {
    fn mvm(&self, n: usize, at: Vec<f32>, xt: Vec<f32>) -> Result<Vec<f32>, String> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Mvm { n, at, xt, reply })?;
        recv_reply(&rx, "mvm reply")?
    }

    fn ec_mvm(&self, req: EcMvmRequest) -> Result<EcMvmResponse, String> {
        // Zero-copy handoff: the request buffers move straight into the
        // service thread (boxed so the channel payload stays small).
        let (reply, rx) = mpsc::channel();
        self.send(Request::EcMvm {
            req: Box::new(req),
            reply,
        })?;
        recv_reply(&rx, "ec_mvm reply")?
    }

    fn tile_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(feature = "pjrt")]
impl Drop for PjrtBackend {
    fn drop(&mut self) {
        let _ = self.send(Request::Shutdown);
        if let Ok(mut h) = self.handle.lock() {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}
