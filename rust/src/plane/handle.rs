//! The clone-able plane handle: concurrent admission onto one shard pool.
//!
//! [`PlaneHandle`] is the multi-tenant surface of the execution plane.
//! Every method takes `&self` and the handle is `Clone`, so any number of
//! threads can `program` / `execute_batch` / `evict` against the same
//! shard pool without an external mutex — batches against *different*
//! resident operands admit and run concurrently, and leader-side work
//! (tile extraction, partial reduction) of one walk overlaps shard-side
//! execution of another.
//!
//! ## Lock map
//!
//! * **`structural` (plane-wide `Mutex`)** — held only across structural
//!   bookkeeping: operand-id allocation, tile-slot alloc/free, residency
//!   registration/eviction, energy fold-in.  Never held across a shard
//!   round-trip, so it is contended for microseconds, not walks.
//! * **Per-`(operand, MCA)` `Mutex<McaSlot>`** — owns that MCA's
//!   [`TileExecutor`] and programmed tiles for one operand.  Programming
//!   locks it from the one shard the placement assigned; batch execution
//!   locks it from whichever worker claimed the MCA (work-stealing).
//! * **Per-walk reply channels** — each walk (program / batch / one-shot)
//!   gathers on its own `mpsc` channel, so concurrent gathers never
//!   interleave messages.
//!
//! ## Why determinism survives concurrency
//!
//! * Chunk→MCA binding and per-MCA seeds ([`mca_seed`](super::mca_seed))
//!   are pure functions of the plan and master seed.
//! * Programming order per MCA is FIFO: each MCA's `Program` jobs go to
//!   its one owning shard over a FIFO queue, so the executor's persistent
//!   write–verify RNG always draws in chunk order regardless of what
//!   other walks interleave on the same shard.
//! * Batch execution noise is *counter-based*
//!   ([`exec_stream_seed`](super::exec_stream_seed)): a pure function of
//!   `(seed, mca, solve index, chunk)`.  Batch work is claimed at
//!   **sub-MCA granularity** — one chunk × the whole batch, off a
//!   per-MCA atomic grid cursor — and every claimant, owner or thief,
//!   executes through the owner slot's executor under its lock.  So
//!   stealing can reorder which worker runs which chunk, but never what
//!   noise a given solve draws.  The one thing chunk-level interleaving
//!   relaxes is the order one MCA's `f64` energy ledger accumulates its
//!   chunks: ulp-level wobble in energy *reporting*, never in results.
//! * Solve indices are allocated atomically per operand at admission, so
//!   concurrent batches on one operand serialize only that counter.
//!
//! ## Tile materialization: two walk modes
//!
//! `scatter_walk` streams the occupied chunks of a plan to the shards in
//! one of two modes, selected by its `WalkSource`:
//!
//! * **Borrowed** ([`program`](PlaneHandle::program) /
//!   [`execute_once`](PlaneHandle::execute_once)): the leader extracts
//!   each dense tile itself, double-buffered over a bounded channel —
//!   a producer thread extracts tile `N + 1` while the consumer
//!   dispatches tile `N` to the shards (which encode `N - 1`…).
//! * **Shared** ([`program_shared`](PlaneHandle::program_shared) /
//!   [`execute_once_shared`](PlaneHandle::execute_once_shared)): each job
//!   carries a compact chunk descriptor (an `Arc` of the source plus the
//!   chunk's coordinates) and the *shard* extracts the tile, fused
//!   directly into conductance encoding.  The leader's per-chunk work
//!   drops to enumerate + dispatch, so materialization scales with the
//!   pool instead of one producer thread (`benches/tile_pipeline.rs`
//!   measures both paths and hard-asserts they are bit-identical).
//!
//! Either way dispatch order per MCA — and therefore every programming
//! RNG draw — is exactly the serial walk's.

use super::error::PlaneError;
use super::placement::{self, Placement};
use super::shard::{self, ShardContext, ShardJob, ShardMsg, TilePayload};
use super::timing::{self, McaTiming};
use super::{reduce_partials, BatchOutcome, OperandId, ProgramReport, ServeSolve, TileAllocator};
use crate::config::{SolveOptions, SystemConfig};
use crate::ec::{ProgrammedTile, TileExecutor};
use crate::linalg::{Matrix, Vector};
use crate::matrices::MatrixSource;
use crate::mca::EnergyLedger;
use crate::metrics::SolveReport;
use crate::obs::{self, Lane, Stage};
use crate::runtime::Backend;
use crate::virtualization::{ChunkPlan, ChunkSpec};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Bound on in-flight jobs per shard (backpressure: caps leader-side tile
/// memory at `depth × shards` tiles per walk).
pub(crate) const JOB_QUEUE_DEPTH: usize = 4;

/// Depth of the extraction double-buffer: how many extracted tiles may sit
/// between the producer (extract) and consumer (dispatch) halves of a
/// scatter walk.  `2` = classic double buffering — extract chunk `N + 1`
/// while chunk `N` dispatches.
pub(crate) const EXTRACT_QUEUE_DEPTH: usize = 2;

/// Supervision interval of the gather loops: how often a blocked receive
/// wakes up to check shard liveness.
const SUPERVISE_INTERVAL: Duration = Duration::from_millis(200);

/// Default hard deadline of one supervised gather.  Override with
/// `MELISO_WALK_TIMEOUT_SECS` (`0` disables).
const DEFAULT_WALK_TIMEOUT: Duration = Duration::from_secs(600);

fn walk_timeout() -> Option<Duration> {
    match std::env::var("MELISO_WALK_TIMEOUT_SECS") {
        Ok(s) => match s.trim().parse::<u64>() {
            Ok(0) => None,
            Ok(n) => Some(Duration::from_secs(n)),
            Err(_) => Some(DEFAULT_WALK_TIMEOUT),
        },
        Err(_) => Some(DEFAULT_WALK_TIMEOUT),
    }
}

/// Lock a mutex, treating poisoning (a shard panicked while holding it)
/// as benign: the plane is already marked failed by supervision, and the
/// guarded state is only read for best-effort accounting afterwards.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One MCA's share of one operand: the persistent executor (device
/// simulator + energy ledger) and the tiles programmed onto it.
#[derive(Default)]
pub(crate) struct McaSlot {
    pub(crate) exec: Option<TileExecutor>,
    pub(crate) chunks: Vec<(ChunkSpec, ProgrammedTile)>,
}

/// Shared per-operand state: the plan plus one [`McaSlot`] per MCA.
/// Leader and shards both hold `Arc`s; the fine-grained slot locks are
/// what lets batches on different operands run concurrently.
pub(crate) struct OperandEntry {
    pub(crate) op: u64,
    pub(crate) plan: ChunkPlan,
    pub(crate) mcas: Vec<Mutex<McaSlot>>,
    /// Occupied-chunk count per MCA (leader-side, set while programming).
    pub(crate) chunks_per_mca: Vec<AtomicUsize>,
    /// Monotonic solve counter (drives the counter-based noise streams);
    /// advances even for failed batches so retries never reuse noise.
    next_solve: Mutex<u64>,
    /// Batches currently admitted but not yet returned; guards eviction.
    inflight: AtomicUsize,
}

impl OperandEntry {
    fn new(op: u64, plan: ChunkPlan) -> OperandEntry {
        let mcas = plan.geometry.mcas();
        OperandEntry {
            op,
            plan,
            mcas: (0..mcas).map(|_| Mutex::new(McaSlot::default())).collect(),
            chunks_per_mca: (0..mcas).map(|_| AtomicUsize::new(0)).collect(),
            next_solve: Mutex::new(0),
            inflight: AtomicUsize::new(0),
        }
    }

    /// `(write, read)` energy accumulated by this operand's executors.
    fn energy_totals(&self) -> (f64, f64) {
        let (mut w, mut r) = (0.0, 0.0);
        for m in &self.mcas {
            let slot = lock_unpoisoned(m);
            if let Some(e) = slot.exec.as_ref() {
                w += e.mca.ledger.write_energy_j;
                r += e.mca.ledger.read_energy_j;
            }
        }
        (w, r)
    }

    /// Per-MCA ledger snapshot (default for MCAs this operand never
    /// touched).
    fn ledgers(&self) -> Vec<EnergyLedger> {
        self.mcas
            .iter()
            .map(|m| {
                lock_unpoisoned(m)
                    .exec
                    .as_ref()
                    .map(|e| e.mca.ledger)
                    .unwrap_or_default()
            })
            .collect()
    }
}

/// Decrement the operand's in-flight count when a batch leaves
/// `execute_batch` on any path.
struct InflightGuard<'a>(&'a OperandEntry);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Per-walk executor set of the fused one-shot path: fresh per walk (the
/// historical consumed-plane semantics), shared with the shards by `Arc`.
pub(crate) struct OnceWalk {
    pub(crate) executors: Vec<Mutex<Option<TileExecutor>>>,
}

/// One batch's shared work description: the operand, the input vectors,
/// the per-shard MCA queues workers claim from (and steal between), and
/// one chunk-grid cursor per MCA for sub-MCA claims.
pub(crate) struct BatchWalk {
    pub(crate) entry: Arc<OperandEntry>,
    pub(crate) xs: Arc<Vec<Vector>>,
    pub(crate) first_solve: u64,
    /// Per-shard claim queues of MCA indices (only MCAs with resident
    /// chunks of this operand appear, each in exactly one queue).
    queues: Vec<Vec<usize>>,
    cursors: Vec<AtomicUsize>,
    /// Per-MCA chunk-grid cursors: index of the next unclaimed resident
    /// chunk in that MCA's slot.  *All* execution — by the queue-assigned
    /// worker or a thief — claims chunks through these, which is what
    /// makes sub-MCA stealing a pure scheduling change.
    pub(crate) grid: Vec<AtomicUsize>,
}

impl BatchWalk {
    /// Claim a starting MCA for `shard`: its own queue first, then steal
    /// from the other workers' queues (round-robin from the next shard).
    /// The per-queue atomic cursor hands each index out exactly once;
    /// the MCA's *chunks* are then claimed one by one off its grid
    /// cursor, so a thief arriving later still splits the remainder.
    pub(crate) fn claim(&self, shard: usize) -> Option<(usize, bool)> {
        let shards = self.queues.len();
        for off in 0..shards {
            let v = (shard + off) % shards;
            let q = &self.queues[v];
            let i = self.cursors[v].fetch_add(1, Ordering::Relaxed);
            if i < q.len() {
                return Some((q[i], off != 0));
            }
        }
        None
    }

    /// Pick a sub-MCA steal target: the MCA with the most unclaimed
    /// chunks left on its grid, or `None` when every grid is exhausted.
    /// Grid cursors only move forward, so repeated calls terminate; a
    /// cursor read racing a concurrent claim at worst sends the thief to
    /// a grid that drains on arrival (it executes nothing and rescans).
    pub(crate) fn steal_target(&self) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (mca, count) in self.entry.chunks_per_mca.iter().enumerate() {
            let total = count.load(Ordering::Relaxed);
            let claimed = self.grid[mca].load(Ordering::Relaxed);
            let remaining = total.saturating_sub(claimed);
            if remaining > 0 && best.map_or(true, |(_, r)| remaining > r) {
                best = Some((mca, remaining));
            }
        }
        best.map(|(mca, _)| mca)
    }
}

/// Leader-side bookkeeping of one residency (kept out of the shared
/// [`OperandEntry`] so shards never see allocator state).
struct Residency {
    entry: Arc<OperandEntry>,
    chunks_resident: usize,
    slots: Vec<(usize, usize)>,
}

/// Plane-wide structural state, guarded by one mutex held only across
/// bookkeeping (never across a shard round-trip).
struct Structural {
    residencies: BTreeMap<u64, Residency>,
    alloc: TileAllocator,
    next_operand: u64,
    /// `(write, read)` energy of completed one-shot walks.
    oneshot_energy: (f64, f64),
    /// `(write, read)` energy of evicted residencies, so plane-wide totals
    /// stay monotone across evictions.
    retired_energy: (f64, f64),
    /// Set when a shard died or a gather timed out: the pool can no
    /// longer complete walks consistently, so every later admission fails
    /// fast instead of desynchronizing.
    failed: Option<String>,
}

impl Structural {
    fn ensure_live(&self) -> Result<(), PlaneError> {
        match &self.failed {
            Some(e) => Err(PlaneError::Failed(e.clone())),
            None => Ok(()),
        }
    }
}

/// The shared pool behind every clone of one [`PlaneHandle`].
pub(crate) struct PlaneShared {
    config: SystemConfig,
    opts: SolveOptions,
    senders: Vec<mpsc::SyncSender<ShardJob>>,
    handles: Vec<JoinHandle<()>>,
    /// MCA index → shard index (stable for the plane's lifetime).
    assignment: Vec<usize>,
    /// Measured per-MCA execution time (feeds timing-aware distribution).
    timings: Arc<Vec<McaTiming>>,
    structural: Mutex<Structural>,
}

impl Drop for PlaneShared {
    fn drop(&mut self) {
        // Closing the job channels ends the shard loops.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A clone-able, thread-safe handle to one sharded execution plane.
///
/// All methods take `&self`: clone the handle freely across threads and
/// sessions.  Batches against different resident operands run
/// concurrently; structural changes (`program` / `evict`) serialize only
/// on brief bookkeeping locks.  The shard pool shuts down when the last
/// clone drops.
///
/// ```
/// use meliso::plane::PlaneHandle;
/// use meliso::prelude::*;
/// use meliso::runtime::native::NativeBackend;
/// use std::sync::Arc;
///
/// let src = meliso::matrices::registry::build("spd64").unwrap();
/// let cfg = SystemConfig::new(2, 2, 32);
/// let opts = SolveOptions::default().with_workers(2);
/// let plane =
///     PlaneHandle::build(src.as_ref(), &cfg, &opts, Arc::new(NativeBackend::new())).unwrap();
/// let (id, report) = plane.program(src.as_ref()).unwrap();
/// assert_eq!(report.chunks_resident, 4);
/// let x = Vector::standard_normal(64, 1);
/// let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
/// assert_eq!(batch.solves.len(), 1);
/// plane.evict(id).unwrap();
/// ```
#[derive(Clone)]
pub struct PlaneHandle {
    shared: Arc<PlaneShared>,
}

impl PlaneHandle {
    /// Spawn the shard pool sized for `source`'s chunk plan.  `source` is
    /// only used for placement statistics and geometry validation here;
    /// tiles are extracted lazily by the execution calls, and operands of
    /// *other* dimensions may be programmed later — the pool is shared.
    pub fn build(
        source: &dyn MatrixSource,
        config: &SystemConfig,
        opts: &SolveOptions,
        backend: Backend,
    ) -> Result<PlaneHandle, PlaneError> {
        let (m, n) = (source.nrows(), source.ncols());
        let plan = ChunkPlan::new(config.geometry(), m, n);
        let tile = config.geometry().cell_size;
        if !backend.tile_sizes().contains(&tile) {
            return Err(PlaneError::UnsupportedCell {
                cell: tile,
                available: backend.tile_sizes(),
            });
        }
        let mcas = plan.geometry.mcas();
        let shards = opts.workers.max(1).min(mcas);
        let policy = opts.placement.policy();
        let mut assignment = policy.assign(&plan, source, shards);
        if assignment.len() != mcas || assignment.iter().any(|&s| s >= shards) {
            return Err(PlaneError::Build(format!(
                "placement {} produced a malformed assignment ({} entries for {mcas} MCAs, \
                 {shards} shards)",
                policy.name(),
                assignment.len()
            )));
        }

        // Timings are shared per (seed, geometry) domain across plane
        // builds, so measurements taken while one plane served batches
        // feed the *build-time* assignment of the next.  Placement never
        // affects numerics, only scheduling.
        let timings = timing::domain(
            timing::DomainKey {
                seed: opts.seed,
                tile_rows: config.geometry().tile_rows,
                tile_cols: config.geometry().tile_cols,
                cell_size: tile,
            },
            mcas,
        );
        if opts.placement == Placement::TimingAware
            && timings.iter().any(|t| t.mean_nanos().is_some())
        {
            let measured: u64 = timings.iter().map(|t| t.samples()).sum();
            assignment = timed_split(&plan.assignments_per_mca(), &timings, shards);
            crate::log_info!(
                "plane",
                "timing-aware build: warm-started the MCA assignment from {measured} measured \
                 chunk executions"
            );
        }
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(JOB_QUEUE_DEPTH);
            senders.push(tx);
            let ctx = ShardContext {
                shard: s,
                cell: tile,
                opts: opts.clone(),
                backend: backend.clone(),
                jobs: rx,
                timings: timings.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("meliso-shard-{s}"))
                    .spawn(move || shard::run(ctx))
                    .map_err(|e| PlaneError::Build(format!("spawn shard {s}: {e}")))?,
            );
        }

        Ok(PlaneHandle {
            shared: Arc::new(PlaneShared {
                config: *config,
                opts: opts.clone(),
                senders,
                handles,
                assignment,
                timings,
                structural: Mutex::new(Structural {
                    residencies: BTreeMap::new(),
                    alloc: TileAllocator::new(mcas, config.tile_slots),
                    next_operand: 0,
                    oneshot_energy: (0.0, 0.0),
                    retired_energy: (0.0, 0.0),
                    failed: None,
                }),
            }),
        })
    }

    /// Whether two handles refer to the same underlying shard pool.
    pub fn ptr_eq(a: &PlaneHandle, b: &PlaneHandle) -> bool {
        Arc::ptr_eq(&a.shared, &b.shared)
    }

    /// Number of shard worker threads.
    pub fn shards(&self) -> usize {
        self.shared.senders.len()
    }

    /// MCA index → shard index, as decided by the placement policy.
    pub fn assignment(&self) -> &[usize] {
        &self.shared.assignment
    }

    /// The physical system configuration the pool was built for.
    pub fn system_config(&self) -> SystemConfig {
        self.shared.config
    }

    /// The solve options every residency on this plane shares.
    pub fn options(&self) -> &SolveOptions {
        &self.shared.opts
    }

    /// Operands currently resident.
    pub fn resident_operands(&self) -> usize {
        lock_unpoisoned(&self.shared.structural).residencies.len()
    }

    /// Chunks currently resident across all operands.
    pub fn resident_chunks(&self) -> usize {
        lock_unpoisoned(&self.shared.structural)
            .residencies
            .values()
            .map(|r| r.chunks_resident)
            .sum()
    }

    /// Tile slots currently held across all MCAs.
    pub fn slots_in_use(&self) -> usize {
        lock_unpoisoned(&self.shared.structural).alloc.in_use()
    }

    /// Highest tile-slot count any MCA has ever needed (eviction makes
    /// slots reusable, so reprogramming does not grow this).
    pub fn slot_high_water(&self) -> usize {
        lock_unpoisoned(&self.shared.structural).alloc.high_water()
    }

    /// The failure that poisoned this plane, if any (a shard panicked,
    /// exited mid-walk, or a gather timed out).
    pub fn failure(&self) -> Option<String> {
        lock_unpoisoned(&self.shared.structural).failed.clone()
    }

    /// Total `(write, read)` energy across the plane so far: one-shot
    /// walks, live residencies, and evicted (retired) residencies.
    pub fn energy_totals(&self) -> (f64, f64) {
        let st = lock_unpoisoned(&self.shared.structural);
        let (mut w, mut r) = st.oneshot_energy;
        w += st.retired_energy.0;
        r += st.retired_energy.1;
        for res in st.residencies.values() {
            let (rw, rr) = res.entry.energy_totals();
            w += rw;
            r += rr;
        }
        (w, r)
    }

    /// `(write, read)` energy attributable to one resident operand, or
    /// `None` when `id` is not resident.
    pub fn operand_energy_totals(&self, id: OperandId) -> Option<(f64, f64)> {
        let entry = {
            let st = lock_unpoisoned(&self.shared.structural);
            st.residencies.get(&id.0).map(|r| r.entry.clone())
        };
        entry.map(|e| e.energy_totals())
    }

    fn poison(&self, fatal: &PlaneError) {
        lock_unpoisoned(&self.shared.structural)
            .failed
            .get_or_insert(fatal.to_string());
    }

    /// Publish the plane's residency gauges to the global registry (the
    /// allocator publishes the slot-occupancy gauges itself).
    fn publish_occupancy(st: &Structural) {
        if !obs::metrics_on() {
            return;
        }
        let g = obs::global();
        g.gauge(
            obs::names::PLANE_RESIDENT_OPERANDS,
            "Operands currently resident on the plane",
            &[],
        )
        .set(st.residencies.len() as f64);
        g.gauge(
            obs::names::PLANE_RESIDENT_CHUNKS,
            "Chunks currently resident on the plane",
            &[],
        )
        .set(
            st.residencies
                .values()
                .map(|r| r.chunks_resident)
                .sum::<usize>() as f64,
        );
    }

    /// Program `source` resident: scatter and write–verify every non-zero
    /// chunk (per-shard programming runs in parallel, with tile extraction
    /// double-buffered ahead of dispatch) and return the operand's handle
    /// with its one-time programming report.  Afterwards
    /// [`execute_batch`](Self::execute_batch) serves unlimited solves
    /// against it, interleaved freely with other residencies — including
    /// from other threads holding clones of this handle.
    ///
    /// On failure the partial residency is retired (tile slots and
    /// executor state reclaimed), so the plane stays serviceable and a
    /// retry programs a fresh, bit-reproducible residency.
    pub fn program(
        &self,
        source: &dyn MatrixSource,
    ) -> Result<(OperandId, ProgramReport), PlaneError> {
        self.program_walk(WalkSource::Borrowed(source))
    }

    /// [`program`](Self::program) over a shared (`Arc`'d) source: jobs
    /// carry a compact chunk descriptor instead of a leader-extracted
    /// dense tile, and each *shard* materializes its tiles fused into
    /// conductance encoding.  Bit-identical to [`program`](Self::program)
    /// — extraction is a pure read and per-MCA dispatch order is
    /// unchanged — but the leader's serial per-chunk stage shrinks to
    /// enumerate + dispatch, so programming throughput scales with the
    /// shard pool.  Prefer this whenever the source is already shared
    /// (the serving sessions do).
    pub fn program_shared(
        &self,
        source: Arc<dyn MatrixSource>,
    ) -> Result<(OperandId, ProgramReport), PlaneError> {
        self.program_walk(WalkSource::Shared(source))
    }

    fn program_walk(
        &self,
        source: WalkSource<'_>,
    ) -> Result<(OperandId, ProgramReport), PlaneError> {
        let sh = &*self.shared;
        let start = timing::monotonic_now();
        let plan_span = obs::span_start();
        let plan = {
            let src = source.as_dyn();
            ChunkPlan::new(sh.config.geometry(), src.nrows(), src.ncols())
        };
        let (m, n) = (plan.m, plan.n);
        note_plan(plan_span, "program", plan.total_chunks(), m, n);
        let op = {
            let mut st = lock_unpoisoned(&sh.structural);
            st.ensure_live()?;
            let op = st.next_operand;
            st.next_operand += 1;
            op
        };
        let id = OperandId(op);
        let entry = Arc::new(OperandEntry::new(op, plan.clone()));

        let (reply_tx, reply_rx) = mpsc::channel::<ShardMsg>();
        let mut slots: Vec<(usize, usize)> = Vec::new();
        let (dispatched, walk_err) = {
            let slots = &mut slots;
            let entry = &entry;
            scatter_walk(sh, &plan, &source, &reply_tx, |spec, payload| {
                let slot = lock_unpoisoned(&sh.structural).alloc.alloc(spec.mca_index)?;
                slots.push((spec.mca_index, slot));
                entry.chunks_per_mca[spec.mca_index].fetch_add(1, Ordering::Relaxed);
                Ok(ShardJob::Program {
                    spec,
                    payload,
                    entry: entry.clone(),
                    reply: reply_tx.clone(),
                })
            })
        };
        drop(reply_tx);

        let shards = sh.senders.len();
        let mut iters_sum = 0.0f64;
        let mut acks = 0usize;
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = drain_walk(&reply_rx, &sh.handles, shards, |msg| match msg {
            ShardMsg::Programmed {
                block_row,
                block_col,
                outcome,
            } => {
                acks += 1;
                match outcome {
                    Ok(iters) => {
                        iters_sum += iters as f64;
                        None
                    }
                    Err(e) => Some(format!("programming chunk ({block_row},{block_col}): {e}")),
                }
            }
            _ => None,
        });
        note_gather(gather_clock, gather_span, "program");
        if let Some(fatal) = outcome.fatal {
            self.poison(&fatal);
            self.retire(&entry, &slots);
            return Err(fatal);
        }
        let mut err = walk_err.or(outcome.chunk_err.map(PlaneError::Chunk));
        if err.is_none() && acks < dispatched {
            err = Some(PlaneError::Chunk(
                "shards exited before acknowledging every chunk".to_string(),
            ));
        }
        if let Some(e) = err {
            // Reclaim the partial residency so the plane stays clean.
            self.retire(&entry, &slots);
            return Err(e);
        }

        let ledgers = entry.ledgers();
        let used: Vec<&EnergyLedger> = ledgers.iter().filter(|l| l.write_passes > 0).collect();
        let write_energy_j: f64 = used.iter().map(|l| l.write_energy_j).sum();
        let write_latency_s = used.iter().map(|l| l.write_latency_s).fold(0.0, f64::max);
        let report = ProgramReport {
            m,
            n,
            chunks_total: plan.total_chunks(),
            chunks_resident: dispatched,
            chunks_skipped: plan.total_chunks() - dispatched,
            mcas_used: used.len(),
            normalization_factor: plan.normalization_factor(),
            mean_wv_iters: if dispatched > 0 {
                iters_sum / dispatched as f64
            } else {
                0.0
            },
            write_energy_j,
            write_latency_s,
            wall_seconds: start.elapsed().as_secs_f64(),
        };
        let resident_now = {
            let mut st = lock_unpoisoned(&sh.structural);
            st.residencies.insert(
                op,
                Residency {
                    entry,
                    chunks_resident: dispatched,
                    slots,
                },
            );
            Self::publish_occupancy(&st);
            st.residencies.len()
        };
        crate::log_info!(
            "plane",
            "programmed {id} ({m}x{n}): {} resident chunks ({} skipped) on {} MCAs / {} \
             shards, E_w {:.3e} J, wall {:.2}s ({} operands resident)",
            report.chunks_resident,
            report.chunks_skipped,
            report.mcas_used,
            shards,
            write_energy_j,
            report.wall_seconds,
            resident_now
        );
        Ok((id, report))
    }

    /// Serve a batch of solves against resident operand `id` in one chunk
    /// walk: every resident tile is visited once and all input vectors run
    /// against it.  Bit-identical to the same vectors solved sequentially,
    /// to the same operand served from a dedicated plane, and to any
    /// degree of cross-operand concurrency (counter-based execution noise
    /// streams — see [`exec_stream_seed`](super::exec_stream_seed)).
    ///
    /// Work distribution: each worker starts from the MCAs the placement
    /// (or, under [`Placement::TimingAware`], a measured-wall-time LPT
    /// split) handed it, steals whole MCAs from slower workers' queues,
    /// and — once every queue is empty — steals at **sub-MCA
    /// granularity**, joining the chunk grid of whichever MCA has the
    /// most unclaimed chunks.  A single dominating MCA (an arrowhead's
    /// spike column) therefore spreads over the whole pool instead of
    /// serializing on one worker.
    ///
    /// A failed batch (chunk-level shard error) leaves the residency
    /// consistent: ledgers are fully synced and the solve counter has
    /// advanced past the failed batch, so a subsequent batch draws exactly
    /// the noise it would have in an error-free run.
    pub fn execute_batch(
        &self,
        id: OperandId,
        xs: &[Vector],
    ) -> Result<BatchOutcome, PlaneError> {
        let sh = &*self.shared;
        // Admission: look up the entry and mark the batch in-flight under
        // the structural lock, so `evict` can never race the walk.
        let entry = {
            let st = lock_unpoisoned(&sh.structural);
            st.ensure_live()?;
            let res = st
                .residencies
                .get(&id.0)
                .ok_or(PlaneError::StaleOperand { id })?;
            res.entry.inflight.fetch_add(1, Ordering::SeqCst);
            res.entry.clone()
        };
        let _inflight = InflightGuard(&entry);
        let n = entry.plan.n;
        for (k, x) in xs.iter().enumerate() {
            if x.len() != n {
                return Err(PlaneError::InvalidInput(format!(
                    "batch vector {k} has length {} but A has {n} columns",
                    x.len()
                )));
            }
        }
        if xs.is_empty() {
            return Ok(BatchOutcome {
                solves: Vec::new(),
                wall_seconds: 0.0,
            });
        }
        let start = timing::monotonic_now();
        let plan_span = obs::span_start();
        let (m, tile) = (entry.plan.m, entry.plan.geometry.cell_size);
        let first_solve = {
            let mut next = lock_unpoisoned(&entry.next_solve);
            let first = *next;
            *next += xs.len() as u64;
            first
        };
        let walk = Arc::new(BatchWalk {
            entry: entry.clone(),
            xs: Arc::new(xs.to_vec()),
            first_solve,
            queues: self.distribute(&entry),
            cursors: (0..sh.senders.len()).map(|_| AtomicUsize::new(0)).collect(),
            grid: (0..entry.plan.geometry.mcas())
                .map(|_| AtomicUsize::new(0))
                .collect(),
        });
        let (reply_tx, reply_rx) = mpsc::channel::<ShardMsg>();
        // Best-effort broadcast: a dead shard (its receiver dropped after
        // a panic) is skipped — the liveness sweep below catches it —
        // while every live shard still gets the job, so the supervised
        // drain terminates.
        let mut dead: Option<usize> = None;
        for (s, tx) in sh.senders.iter().enumerate() {
            let job = ShardJob::Execute {
                walk: walk.clone(),
                reply: reply_tx.clone(),
            };
            if tx.send(job).is_err() && dead.is_none() {
                dead = Some(s);
            }
        }
        drop(reply_tx);
        if let Some(sp) = plan_span {
            sp.finish(
                Stage::Plan,
                Lane::Leader,
                vec![
                    ("path", "batch".to_string()),
                    ("operand", id.0.to_string()),
                    ("batch", xs.len().to_string()),
                ],
            );
        }

        // Gather: partials per (resident chunk, vector), then one seal per
        // shard.  Drained fully even on error, so when this returns no
        // shard is still touching the batch (the in-flight guard may then
        // release eviction safely).
        let shards = sh.senders.len();
        let mut per_solve: Vec<BTreeMap<(usize, usize), Vector>> =
            (0..xs.len()).map(|_| BTreeMap::new()).collect();
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = drain_walk(&reply_rx, &sh.handles, shards, |msg| match msg {
            ShardMsg::Partial {
                solve,
                block_row,
                block_col,
                outcome,
            } => match outcome {
                Ok(v) => {
                    let k = solve.wrapping_sub(first_solve) as usize;
                    match per_solve.get_mut(k) {
                        Some(slot) => {
                            slot.insert((block_row, block_col), v);
                            None
                        }
                        None => Some(format!(
                            "chunk ({block_row},{block_col}): stray partial for solve \
                             {solve} (batch starts at {first_solve})"
                        )),
                    }
                }
                Err(e) => Some(format!("chunk ({block_row},{block_col}) solve {solve}: {e}")),
            },
            _ => None,
        });
        note_gather(gather_clock, gather_span, "batch");
        if let Some(fatal) = outcome.fatal {
            self.poison(&fatal);
            return Err(fatal);
        }
        if let Some(s) = dead {
            let fatal = PlaneError::ShardDead(format!("shard {s} died mid-batch"));
            self.poison(&fatal);
            return Err(fatal);
        }
        if let Some(e) = outcome.chunk_err {
            return Err(PlaneError::Chunk(e));
        }
        let wall = start.elapsed().as_secs_f64();
        let reduce_span = obs::span_start();
        let solves: Vec<ServeSolve> = per_solve
            .into_iter()
            .enumerate()
            .map(|(k, partials)| ServeSolve {
                y: reduce_partials(m, tile, &partials),
                solve_index: first_solve + k as u64,
                wall_seconds: wall / xs.len() as f64,
            })
            .collect();
        if let Some(sp) = reduce_span {
            sp.finish(
                Stage::Reduce,
                Lane::Leader,
                vec![
                    ("operand", id.0.to_string()),
                    ("batch", xs.len().to_string()),
                ],
            );
        }
        Ok(BatchOutcome {
            solves,
            wall_seconds: wall,
        })
    }

    /// Per-shard claim queues for one batch: under
    /// [`Placement::TimingAware`], MCAs are re-split by *measured* mean
    /// execution wall time (LPT), so the initial distribution already
    /// reflects how expensive each MCA's chunks really are; otherwise the
    /// build-time placement assignment is used.  Work-stealing then
    /// corrects whatever imbalance remains.
    fn distribute(&self, entry: &OperandEntry) -> Vec<Vec<usize>> {
        let sh = &*self.shared;
        let shards = sh.senders.len();
        let mcas = entry.plan.geometry.mcas();
        let counts: Vec<usize> = entry
            .chunks_per_mca
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let owner: Vec<usize> = if sh.opts.placement == Placement::TimingAware {
            timed_split(&counts, &sh.timings, shards)
        } else {
            sh.assignment.clone()
        };
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for (mca, &count) in counts.iter().enumerate() {
            if count > 0 {
                queues[owner[mca]].push(mca);
            }
        }
        queues
    }

    /// Evict resident operand `id`: drop its tiles and executors, fold
    /// its energy into the plane's retired totals, and return its tile
    /// slots to the allocator for reuse.  The id becomes stale — later
    /// calls with it are clean errors.
    ///
    /// An operand with an in-flight batch is **not** evicted:
    /// [`PlaneError::OperandBusy`] is returned instead of racing the
    /// executing shards for the allocator.  Eviction works on a *failed*
    /// plane too (leader-side bookkeeping is still reclaimed) — the pool
    /// failure stays observable through [`failure`](Self::failure).
    pub fn evict(&self, id: OperandId) -> Result<(), PlaneError> {
        let mut st = lock_unpoisoned(&self.shared.structural);
        let res = st
            .residencies
            .get(&id.0)
            .ok_or(PlaneError::StaleOperand { id })?;
        let inflight = res.entry.inflight.load(Ordering::SeqCst);
        if inflight > 0 {
            return Err(PlaneError::OperandBusy { id, inflight });
        }
        let Some(res) = st.residencies.remove(&id.0) else {
            // Unreachable (checked under the same lock above), but the
            // plane/server contract is typed errors, never panics.
            return Err(PlaneError::StaleOperand { id });
        };
        for (mca, slot) in &res.slots {
            st.alloc.free(*mca, *slot);
        }
        let (w, r) = res.entry.energy_totals();
        st.retired_energy.0 += w;
        st.retired_energy.1 += r;
        if obs::metrics_on() {
            obs::global()
                .counter(
                    obs::names::PLANE_EVICTIONS,
                    "Operand evictions/retirements from the plane",
                    &[],
                )
                .inc();
        }
        Self::publish_occupancy(&st);
        Ok(())
    }

    /// Reclaim a residency that failed to program: free its slots and
    /// fold whatever energy the partial write charged into the retired
    /// totals.  The scatter walk was sealed and drained before this, so
    /// no shard still holds the entry's slots.
    fn retire(&self, entry: &Arc<OperandEntry>, slots: &[(usize, usize)]) {
        let mut st = lock_unpoisoned(&self.shared.structural);
        for (mca, slot) in slots {
            st.alloc.free(*mca, *slot);
        }
        let (w, r) = entry.energy_totals();
        st.retired_energy.0 += w;
        st.retired_energy.1 += r;
        if obs::metrics_on() {
            obs::global()
                .counter(
                    obs::names::PLANE_EVICTIONS,
                    "Operand evictions/retirements from the plane",
                    &[],
                )
                .inc();
        }
        Self::publish_occupancy(&st);
    }

    /// Run one distributed MVM end-to-end (the one-shot path): program +
    /// execute fused per chunk, exact ground-truth comparison when
    /// `opts.ground_truth` is set, full [`SolveReport`].  The walk owns a
    /// fresh executor set, so every call is bit-identical to the
    /// historical consumed-plane semantics (and to every other call with
    /// the same inputs).  Refused while operands are resident — the
    /// one-shot path models a dedicated, throwaway grid.
    pub fn execute_once(
        &self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, PlaneError> {
        self.execute_once_walk(WalkSource::Borrowed(source), x)
    }

    /// [`execute_once`](Self::execute_once) over a shared (`Arc`'d)
    /// source: the shards materialize their own tiles from chunk
    /// descriptors, fused into the encode.  Bit-identical to
    /// [`execute_once`](Self::execute_once) for the same source and `x`.
    pub fn execute_once_shared(
        &self,
        source: Arc<dyn MatrixSource>,
        x: &Vector,
    ) -> Result<SolveReport, PlaneError> {
        self.execute_once_walk(WalkSource::Shared(source), x)
    }

    fn execute_once_walk(
        &self,
        source: WalkSource<'_>,
        x: &Vector,
    ) -> Result<SolveReport, PlaneError> {
        let sh = &*self.shared;
        {
            let st = lock_unpoisoned(&sh.structural);
            st.ensure_live()?;
            if !st.residencies.is_empty() {
                // The one-shot path models a dedicated, throwaway grid;
                // fusing it onto a serving plane is always a caller bug.
                return Err(PlaneError::InvalidInput(
                    "this plane holds resident operands; build a fresh plane for one-shot solves"
                        .to_string(),
                ));
            }
        }
        let start = timing::monotonic_now();
        let plan_span = obs::span_start();
        let plan = {
            let src = source.as_dyn();
            ChunkPlan::new(sh.config.geometry(), src.nrows(), src.ncols())
        };
        let (m, n) = (plan.m, plan.n);
        note_plan(plan_span, "one-shot", plan.total_chunks(), m, n);
        if x.len() != n {
            return Err(PlaneError::InvalidInput(format!(
                "x has length {} but A has {n} columns",
                x.len()
            )));
        }
        let tile = plan.geometry.cell_size;
        let mcas = plan.geometry.mcas();
        let walk = Arc::new(OnceWalk {
            executors: (0..mcas).map(|_| Mutex::new(None)).collect(),
        });
        let (reply_tx, reply_rx) = mpsc::channel::<ShardMsg>();
        let (dispatched, walk_err) = {
            let walk = &walk;
            scatter_walk(sh, &plan, &source, &reply_tx, |spec, payload| {
                Ok(ShardJob::RunOnce {
                    spec,
                    x_chunk: x.slice_padded(spec.col0, tile),
                    payload,
                    walk: walk.clone(),
                    reply: reply_tx.clone(),
                })
            })
        };
        drop(reply_tx);

        let shards = sh.senders.len();
        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        let mut wv_sum = 0.0f64;
        let mut got = 0usize;
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = drain_walk(&reply_rx, &sh.handles, shards, |msg| match msg {
            ShardMsg::Once {
                block_row,
                block_col,
                outcome,
            } => {
                got += 1;
                match outcome {
                    Ok((partial, iters)) => {
                        wv_sum += iters as f64;
                        partials.insert((block_row, block_col), partial);
                        None
                    }
                    Err(e) => Some(format!("chunk ({block_row},{block_col}): {e}")),
                }
            }
            _ => None,
        });
        note_gather(gather_clock, gather_span, "one-shot");
        if let Some(fatal) = outcome.fatal {
            self.poison(&fatal);
            return Err(fatal);
        }
        if let Some(e) = walk_err.or(outcome.chunk_err.map(PlaneError::Chunk)) {
            return Err(e);
        }
        if got < dispatched {
            return Err(PlaneError::Chunk(
                "shards exited before delivering all results".to_string(),
            ));
        }
        let skipped = plan.total_chunks() - dispatched;
        let reduce_span = obs::span_start();
        let y = reduce_partials(m, tile, &partials);
        if let Some(sp) = reduce_span {
            sp.finish(
                Stage::Reduce,
                Lane::Leader,
                vec![("chunks", partials.len().to_string())],
            );
        }

        // Fold the walk's ledgers into the report and the plane totals.
        let ledgers: Vec<EnergyLedger> = walk
            .executors
            .iter()
            .map(|m| {
                lock_unpoisoned(m)
                    .as_ref()
                    .map(|e| e.mca.ledger)
                    .unwrap_or_default()
            })
            .collect();
        {
            let mut st = lock_unpoisoned(&sh.structural);
            st.oneshot_energy.0 += ledgers.iter().map(|l| l.write_energy_j).sum::<f64>();
            st.oneshot_energy.1 += ledgers.iter().map(|l| l.read_energy_j).sum::<f64>();
        }

        // Ground truth (opt-out: O(m·n) host work, infeasible at 65k²).
        let mut report = SolveReport::empty(m);
        if sh.opts.ground_truth {
            let b = source.as_dyn().matvec(x);
            report.rel_err_l2 = crate::metrics::rel_err_l2(&y, &b);
            report.rel_err_inf = crate::metrics::rel_err_inf(&y, &b);
        } else {
            report.rel_err_l2 = f64::NAN;
            report.rel_err_inf = f64::NAN;
        }
        report.y = y;
        report.chunks_total = plan.total_chunks();
        report.chunks_skipped = skipped;
        report.normalization_factor = plan.normalization_factor();
        report.row_reassignments = plan.row_reassignments();
        report.mean_wv_iters = if dispatched > 0 {
            wv_sum / dispatched as f64
        } else {
            0.0
        };
        report.fill_from_ledgers(&ledgers);
        report.wall_seconds = start.elapsed().as_secs_f64();
        crate::log_info!(
            "plane",
            "solve {}x{n}: {} chunks ({} skipped) on {} shards, eps_l2={:.4e}, wall={:.2}s",
            m,
            dispatched,
            skipped,
            shards,
            report.rel_err_l2,
            report.wall_seconds
        );
        Ok(report)
    }
}

/// Outcome of one supervised gather: chunk-level errors are recoverable
/// (the plane stays serviceable), fatal errors (a shard panicked or
/// exited mid-walk, or the deadline passed) poison the plane.
struct WalkOutcome {
    chunk_err: Option<String>,
    fatal: Option<PlaneError>,
}

/// Mutable bookkeeping of one supervised gather.
struct GatherState {
    done: Vec<bool>,
    pending: usize,
    chunk_err: Option<String>,
    fatal: Option<PlaneError>,
}

/// Route one shard reply: seals and failures update the per-shard done
/// tracking; everything else goes to the walk-specific `on_msg` handler.
fn dispatch_msg<F: FnMut(ShardMsg) -> Option<String>>(
    st: &mut GatherState,
    on_msg: &mut F,
    msg: ShardMsg,
) {
    match msg {
        ShardMsg::Sealed { shard } => {
            if let Some(d) = st.done.get_mut(shard) {
                if !*d {
                    *d = true;
                    st.pending -= 1;
                }
            }
        }
        ShardMsg::Failed { shard, error } => {
            if let Some(d) = st.done.get_mut(shard) {
                if !*d {
                    *d = true;
                    st.pending -= 1;
                }
            }
            st.fatal
                .get_or_insert(PlaneError::ShardDead(format!("shard {shard} panicked: {error}")));
        }
        msg => {
            if let Some(e) = on_msg(msg) {
                st.chunk_err.get_or_insert(e);
            }
        }
    }
}

/// Supervised gather: drain one walk's replies until every shard has
/// sealed, with a periodic liveness check against the worker handles so a
/// shard that dies without sealing (panic, abort) surfaces as an error
/// instead of blocking the receive forever, and a hard deadline
/// (`MELISO_WALK_TIMEOUT_SECS`) so even a livelocked pool cannot hang the
/// caller.
///
/// `on_msg` handles the walk-specific messages (`Once` / `Programmed` /
/// `Partial`); it returns a chunk-level error to record (first one wins).
fn drain_walk(
    results: &mpsc::Receiver<ShardMsg>,
    handles: &[JoinHandle<()>],
    shards: usize,
    mut on_msg: impl FnMut(ShardMsg) -> Option<String>,
) -> WalkOutcome {
    let mut st = GatherState {
        done: vec![false; shards],
        pending: shards,
        chunk_err: None,
        fatal: None,
    };
    let deadline = walk_timeout().map(|d| timing::monotonic_now() + d);
    while st.pending > 0 {
        match results.recv_timeout(SUPERVISE_INTERVAL) {
            Ok(msg) => dispatch_msg(&mut st, &mut on_msg, msg),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Liveness sweep, race-free against a shard sealing right
                // at the deadline: snapshot liveness FIRST, then drain the
                // queue.  A shard sends its seal strictly before moving to
                // the next job, so if the snapshot saw it finished, its
                // seal (if any) is consumed by the drain below before the
                // verdict.
                let finished: Vec<bool> = (0..shards)
                    .map(|s| handles.get(s).map(|h| h.is_finished()).unwrap_or(true))
                    .collect();
                while let Ok(msg) = results.try_recv() {
                    dispatch_msg(&mut st, &mut on_msg, msg);
                }
                for (s, &gone) in finished.iter().enumerate() {
                    if gone && !st.done[s] {
                        st.done[s] = true;
                        st.pending -= 1;
                        st.fatal.get_or_insert(PlaneError::ShardDead(format!(
                            "shard {s} exited without sealing its walk"
                        )));
                    }
                }
                if let Some(dl) = deadline {
                    if st.pending > 0 && st.fatal.is_none() && timing::monotonic_now() >= dl {
                        st.fatal = Some(PlaneError::Timeout(format!(
                            "supervised gather timed out with {} shard(s) unsealed \
                             (MELISO_WALK_TIMEOUT_SECS to adjust)",
                            st.pending
                        )));
                        break;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if st.pending > 0 {
                    st.fatal.get_or_insert(PlaneError::ShardDead(
                        "a shard dropped its walk replies before sealing".to_string(),
                    ));
                }
                break;
            }
        }
    }
    WalkOutcome {
        chunk_err: st.chunk_err,
        fatal: st.fatal,
    }
}

/// Close a leader-side `Plan` span (shared by the one-shot, program and
/// batch paths; a no-op `None` when tracing is off).
fn note_plan(span: Option<obs::SpanTimer>, path: &'static str, chunks: usize, m: usize, n: usize) {
    if let Some(sp) = span {
        sp.finish(
            Stage::Plan,
            Lane::Leader,
            vec![
                ("path", path.to_string()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("chunks", chunks.to_string()),
            ],
        );
    }
}

/// Account one supervised gather: fold the blocked-wait seconds into the
/// leader's gather-wait counter and close the `Gather` span.  Both handles
/// are `None` when the corresponding level is off.
fn note_gather(clock: Option<Instant>, span: Option<obs::SpanTimer>, path: &'static str) {
    if let Some(t0) = clock {
        obs::global()
            .counter(
                obs::names::PLANE_GATHER_WAIT,
                "Seconds the leader spent in supervised gathers",
                &[],
            )
            .add(t0.elapsed().as_secs_f64());
    }
    if let Some(sp) = span {
        sp.finish(Stage::Gather, Lane::Leader, vec![("path", path.to_string())]);
    }
}

/// How a scatter walk reaches its operand: borrowed (the leader extracts
/// dense tiles itself, double-buffered) or shared (jobs carry an `Arc`'d
/// chunk descriptor and the shards extract, fused into the encode).
pub(crate) enum WalkSource<'a> {
    Borrowed(&'a dyn MatrixSource),
    Shared(Arc<dyn MatrixSource>),
}

impl WalkSource<'_> {
    fn as_dyn(&self) -> &dyn MatrixSource {
        match self {
            WalkSource::Borrowed(s) => *s,
            WalkSource::Shared(s) => s.as_ref(),
        }
    }
}

/// LPT split of MCAs over shards weighted by *measured* mean execution
/// time per chunk (`mean_nanos × chunks`); MCAs without measurements get
/// the mean of the observed means.  Used by the timing-aware batch
/// distribution and, once any history exists, by the timing-aware
/// build-time assignment.
fn timed_split(counts: &[usize], timings: &[McaTiming], shards: usize) -> Vec<usize> {
    let means: Vec<Option<f64>> = timings.iter().map(|t| t.mean_nanos()).collect();
    let observed: Vec<f64> = means.iter().filter_map(|m| *m).collect();
    let fallback = if observed.is_empty() {
        1.0
    } else {
        observed.iter().sum::<f64>() / observed.len() as f64
    };
    let weights: Vec<usize> = counts
        .iter()
        .zip(&means)
        .map(|(&c, mean)| {
            if c == 0 {
                0
            } else {
                (mean.unwrap_or(fallback).max(1.0) * c as f64).round() as usize + 1
            }
        })
        .collect();
    placement::balance(&weights, shards)
}

/// Stream the occupied chunks of `plan` to the shards.  The calling
/// thread builds each job via `make_job` (which may refuse — e.g.
/// tile-slot exhaustion) and dispatches it to the owning shard; per-MCA
/// dispatch order is exactly the serial walk's either way, so
/// determinism is untouched.  Returns `(dispatched, walk_err)`.
///
/// * [`WalkSource::Borrowed`]: a producer thread enumerates
///   [`ChunkPlan::nonzero_chunks`] and extracts one zero-padded tile at
///   a time (unwind-caught) into a bounded channel — tile `N + 1` is
///   extracted while tile `N` dispatches.
/// * [`WalkSource::Shared`]: no leader-side extraction at all — jobs
///   carry [`TilePayload::Descriptor`]s and the shards extract, so the
///   leader's extraction counters stay untouched and the per-chunk cost
///   moves into the shards' fused encode stage
///   (`meliso_shard_encode_seconds_total`).
///
/// The walk is **always closed**: every shard gets a best-effort
/// [`ShardJob::Seal`] even after an error, so the matching supervised
/// gather terminates on a partial walk.
fn scatter_walk<F>(
    sh: &PlaneShared,
    plan: &ChunkPlan,
    source: &WalkSource<'_>,
    reply: &mpsc::Sender<ShardMsg>,
    mut make_job: F,
) -> (usize, Option<PlaneError>)
where
    F: FnMut(ChunkSpec, TilePayload) -> Result<ShardJob, PlaneError>,
{
    let mut dispatched = 0usize;
    let mut walk_err: Option<PlaneError> = None;
    // Dispatch one job, shared by both modes.  Returns `false` when the
    // walk must stop (job refused or the shard is gone).
    let mut dispatch = |spec: ChunkSpec,
                        payload: TilePayload,
                        dispatched: &mut usize,
                        walk_err: &mut Option<PlaneError>| {
        let job = match make_job(spec, payload) {
            Ok(job) => job,
            Err(e) => {
                *walk_err = Some(e);
                return false;
            }
        };
        let s = sh.assignment[spec.mca_index];
        if sh.senders[s].send(job).is_err() {
            *walk_err = Some(PlaneError::ShardDead(format!("shard {s} died mid-walk")));
            return false;
        }
        *dispatched += 1;
        true
    };
    match source {
        WalkSource::Shared(src) => {
            let mut iter = plan.nonzero_chunks(src.as_ref());
            loop {
                match next_chunk(&mut iter) {
                    Ok(Some(spec)) => {
                        let payload = TilePayload::Descriptor(src.clone());
                        if !dispatch(spec, payload, &mut dispatched, &mut walk_err) {
                            break;
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        walk_err = Some(PlaneError::Chunk(e));
                        break;
                    }
                }
            }
        }
        WalkSource::Borrowed(source) => {
            let source: &dyn MatrixSource = *source;
            let tile = plan.geometry.cell_size;
            let (tile_tx, tile_rx) =
                mpsc::sync_channel::<Result<(ChunkSpec, Matrix), String>>(EXTRACT_QUEUE_DEPTH);
            std::thread::scope(|scope| {
                let producer = scope.spawn(move || {
                    let extract_metrics = if obs::metrics_on() {
                        let g = obs::global();
                        Some((
                            g.counter(
                                obs::names::PLANE_TILES_EXTRACTED,
                                "Tiles extracted and dispatched by the leader",
                                &[],
                            ),
                            g.counter(
                                obs::names::PLANE_EXTRACT_SECONDS,
                                "Seconds the leader spent extracting tiles",
                                &[],
                            ),
                        ))
                    } else {
                        None
                    };
                    let mut iter = plan.nonzero_chunks(source);
                    loop {
                        let spec = match next_chunk(&mut iter) {
                            Ok(Some(spec)) => spec,
                            Ok(None) => break,
                            Err(e) => {
                                let _ = tile_tx.send(Err(e));
                                break;
                            }
                        };
                        let span = obs::span_start();
                        let t0 = extract_metrics.as_ref().map(|_| timing::monotonic_now());
                        let extracted = extract_tile(source, &spec, tile);
                        if let (Some((tiles, secs)), Some(t0)) = (&extract_metrics, t0) {
                            tiles.inc();
                            secs.add(t0.elapsed().as_secs_f64());
                        }
                        if let Some(sp) = span {
                            sp.finish(
                                Stage::Extract,
                                Lane::Leader,
                                vec![
                                    (
                                        "chunk",
                                        format!("({},{})", spec.block_row, spec.block_col),
                                    ),
                                    ("mca", spec.mca_index.to_string()),
                                ],
                            );
                        }
                        match extracted {
                            Ok(a_tile) => {
                                // A closed buffer means the consumer bailed.
                                if tile_tx.send(Ok((spec, a_tile))).is_err() {
                                    break;
                                }
                            }
                            Err(e) => {
                                let _ = tile_tx.send(Err(e));
                                break;
                            }
                        }
                    }
                });
                for item in tile_rx {
                    match item {
                        Ok((spec, a_tile)) => {
                            let payload = TilePayload::Dense(a_tile);
                            if !dispatch(spec, payload, &mut dispatched, &mut walk_err) {
                                break;
                            }
                        }
                        Err(e) => {
                            walk_err = Some(PlaneError::Chunk(e));
                            break;
                        }
                    }
                }
                // Dropping the receiver (the for-loop consumed it) unblocks
                // a producer mid-send; join so the borrowed source outlives
                // it.
                let _ = producer.join();
            });
        }
    }
    for tx in &sh.senders {
        let _ = tx.send(ShardJob::Seal {
            reply: reply.clone(),
        });
    }
    (dispatched, walk_err)
}

/// Advance the chunk walk one step, converting a panic inside the
/// source's sparsity probes into an error.
fn next_chunk(iter: &mut dyn Iterator<Item = ChunkSpec>) -> Result<Option<ChunkSpec>, String> {
    catch_unwind(AssertUnwindSafe(|| iter.next()))
        .map_err(|p| format!("operand chunk walk panicked: {}", shard::panic_text(p)))
}

/// Extract one zero-padded tile, converting a panic inside the source's
/// `block` into an error.
fn extract_tile(
    source: &dyn MatrixSource,
    spec: &ChunkSpec,
    tile: usize,
) -> Result<Matrix, String> {
    catch_unwind(AssertUnwindSafe(|| {
        source.block(spec.row0, spec.col0, tile, tile)
    }))
    .map_err(|p| {
        format!(
            "extracting chunk ({},{}) panicked: {}",
            spec.block_row,
            spec.block_col,
            shard::panic_text(p)
        )
    })
}
