//! Placement policies: how MCAs are grouped into shards (worker threads).
//!
//! The chunk→MCA binding is fixed by the virtualization plan (paper
//! Algorithm 8 — chunk `(i, j)` always lands on MCA `(i mod R, j mod C)`),
//! and every MCA owns its own deterministic RNG/noise streams.  Placement
//! therefore only decides *which shard owns each MCA*, which changes
//! scheduling and load balance but can never change a result: any policy
//! preserves bit-reproducibility for a fixed seed.

use crate::matrices::MatrixSource;
use crate::virtualization::ChunkPlan;

/// Maps every MCA of a [`ChunkPlan`] to one of `shards` worker threads.
///
/// Implementations must return one shard index per MCA
/// (`plan.geometry.mcas()` entries, each `< shards`); the
/// [`ExecutionPlane`](crate::plane::ExecutionPlane) rejects malformed
/// assignments.  An MCA never migrates between shards afterwards, so its
/// RNG stream, fixed-pattern noise and energy ledger stay consistent.
pub trait PlacementPolicy: Send + Sync {
    /// Short name for logs and reports.
    fn name(&self) -> &'static str;

    /// Compute the MCA→shard assignment.
    fn assign(&self, plan: &ChunkPlan, source: &dyn MatrixSource, shards: usize) -> Vec<usize>;
}

/// The historical policy: MCA `i` is owned by shard `i % shards`.
pub struct RoundRobinPlacement;

impl PlacementPolicy for RoundRobinPlacement {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn assign(&self, plan: &ChunkPlan, _source: &dyn MatrixSource, shards: usize) -> Vec<usize> {
        let shards = shards.max(1);
        (0..plan.geometry.mcas()).map(|i| i % shards).collect()
    }
}

/// Balances the *planned* chunk count per shard (longest-processing-time
/// greedy over `assignments_per_mca`).  Helps when the chunk grid does not
/// divide evenly into the tile grid, where round-robin leaves some shards
/// with systematically more reassignments than others.
pub struct LoadBalancedPlacement;

impl PlacementPolicy for LoadBalancedPlacement {
    fn name(&self) -> &'static str {
        "load-balanced"
    }

    fn assign(&self, plan: &ChunkPlan, _source: &dyn MatrixSource, shards: usize) -> Vec<usize> {
        balance(&plan.assignments_per_mca(), shards)
    }
}

/// Balances the *occupied* chunk count per shard: zero blocks are never
/// dispatched, so on sparse (banded) operands the diagonal MCAs carry
/// nearly all the work and round-robin can idle whole shards.  Counting
/// through [`ChunkPlan::nonzero_chunks`] is O(occupied blocks) for sources
/// with a cheap column-range bound (e.g. `BandedSource`).
pub struct SparsityAwarePlacement;

impl PlacementPolicy for SparsityAwarePlacement {
    fn name(&self) -> &'static str {
        "sparsity-aware"
    }

    fn assign(&self, plan: &ChunkPlan, source: &dyn MatrixSource, shards: usize) -> Vec<usize> {
        let mut counts = vec![0usize; plan.geometry.mcas()];
        for spec in plan.nonzero_chunks(source) {
            counts[spec.mca_index] += 1;
        }
        balance(&counts, shards)
    }
}

/// Balances by *measured* per-chunk execution wall time, not by counts:
/// at batch-distribution time the plane re-splits MCAs over workers using
/// the EWMA of measured nanoseconds per chunk from the shared timing
/// domain (LPT over `mean_time × occupied_chunks`), so chunks that are
/// genuinely slower — denser tiles, more write–verify retries — weigh
/// more than their count suggests.
///
/// The pure [`assign`](PlacementPolicy::assign) below is what runs with
/// no history and is deliberately identical to [`LoadBalancedPlacement`]
/// (policies stay side-effect-free).  The plane itself goes further:
/// timing domains are keyed by `(seed, geometry)` and shared process-wide
/// (see [`timing`](crate::plane::timing)), so when a *new* plane is built
/// for a domain that already has measurements, `PlaneHandle::build`
/// warm-starts the static MCA→shard assignment from the measured means
/// instead of this cold fallback.  The measured re-split per batch (plus
/// two-tier work-stealing) takes over from the first batch onwards
/// either way.
pub struct TimingAwarePlacement;

impl PlacementPolicy for TimingAwarePlacement {
    fn name(&self) -> &'static str {
        "timing-aware"
    }

    fn assign(&self, plan: &ChunkPlan, source: &dyn MatrixSource, shards: usize) -> Vec<usize> {
        LoadBalancedPlacement.assign(plan, source, shards)
    }
}

/// Greedy longest-processing-time assignment: visit MCAs by descending
/// weight (ties by index, so the result is deterministic) and hand each to
/// the least-loaded shard.  Also used by the plane's batch distribution,
/// with measured-time weights.
pub(crate) fn balance(counts: &[usize], shards: usize) -> Vec<usize> {
    let shards = shards.max(1);
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
    let mut load = vec![0usize; shards];
    let mut assign = vec![0usize; counts.len()];
    for mca in order {
        let mut best = 0usize;
        for (s, &l) in load.iter().enumerate().skip(1) {
            if l < load[best] {
                best = s;
            }
        }
        assign[mca] = best;
        // Even zero-weight MCAs count a little, so idle MCAs still spread
        // over shards instead of piling onto shard 0.
        load[best] += counts[mca].max(1);
    }
    assign
}

/// Named placement selection, carried by
/// [`SolveOptions`](crate::config::SolveOptions) so configs and the CLI can
/// pick a policy; embedders with custom policies pass a
/// [`PlacementPolicy`] object to the plane directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    RoundRobin,
    LoadBalanced,
    SparsityAware,
    TimingAware,
}

impl Placement {
    pub fn parse(s: &str) -> Option<Placement> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(Placement::RoundRobin),
            "load-balanced" | "loadbalanced" | "balanced" => Some(Placement::LoadBalanced),
            "sparsity-aware" | "sparsityaware" | "sparsity" => Some(Placement::SparsityAware),
            "timing-aware" | "timingaware" | "timing" => Some(Placement::TimingAware),
            _ => None,
        }
    }

    /// The policy implementation behind the name.
    pub fn policy(self) -> &'static dyn PlacementPolicy {
        match self {
            Placement::RoundRobin => &RoundRobinPlacement,
            Placement::LoadBalanced => &LoadBalancedPlacement,
            Placement::SparsityAware => &SparsityAwarePlacement,
            Placement::TimingAware => &TimingAwarePlacement,
        }
    }

    pub fn name(self) -> &'static str {
        self.policy().name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::virtualization::SystemGeometry;

    fn dense_plan(m: usize, n: usize) -> (ChunkPlan, DenseSource) {
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), m, n);
        (plan, DenseSource::new(Matrix::standard_normal(m, n, 1)))
    }

    #[test]
    fn round_robin_matches_modulo() {
        let (plan, src) = dense_plan(128, 128);
        assert_eq!(RoundRobinPlacement.assign(&plan, &src, 3), vec![0, 1, 2, 0]);
        assert_eq!(RoundRobinPlacement.assign(&plan, &src, 1), vec![0, 0, 0, 0]);
    }

    #[test]
    fn assignments_are_well_formed() {
        let (plan, src) = dense_plan(100, 40);
        for placement in [
            Placement::RoundRobin,
            Placement::LoadBalanced,
            Placement::SparsityAware,
            Placement::TimingAware,
        ] {
            let assign = placement.policy().assign(&plan, &src, 3);
            assert_eq!(assign.len(), plan.geometry.mcas(), "{}", placement.name());
            assert!(assign.iter().all(|&s| s < 3), "{}", placement.name());
        }
    }

    #[test]
    fn load_balanced_spreads_uneven_grids() {
        // 3x3 chunk grid on a 2x2 tile grid: MCA 0 carries 4 chunks, the
        // others 2/2/1.  Greedy LPT keeps the heaviest MCA alone.
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), 96, 96);
        let src = DenseSource::new(Matrix::standard_normal(96, 96, 2));
        let assign = LoadBalancedPlacement.assign(&plan, &src, 2);
        let counts = plan.assignments_per_mca();
        let mut load = [0usize; 2];
        for (mca, &shard) in assign.iter().enumerate() {
            load[shard] += counts[mca];
        }
        assert_eq!(load.iter().sum::<usize>(), plan.total_chunks());
        assert!(load[0].abs_diff(load[1]) <= 1, "{load:?}");
    }

    #[test]
    fn sparsity_aware_balances_occupied_chunks() {
        // Banded operand: only near-diagonal chunks are occupied, so the
        // occupied count per MCA is far from uniform.
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), 256, 256);
        let assign = SparsityAwarePlacement.assign(&plan, &src, 2);
        let mut occupied = vec![0usize; plan.geometry.mcas()];
        for spec in plan.nonzero_chunks(&src) {
            occupied[spec.mca_index] += 1;
        }
        let mut load = [0usize; 2];
        for (mca, &shard) in assign.iter().enumerate() {
            load[shard] += occupied[mca];
        }
        let total: usize = occupied.iter().sum();
        assert_eq!(load.iter().sum::<usize>(), total);
        let spread = load[0].abs_diff(load[1]);
        assert!(spread * 4 <= total, "load {load:?} of {total}");
    }

    #[test]
    fn placement_parse_and_names() {
        assert_eq!(Placement::parse("round-robin"), Some(Placement::RoundRobin));
        assert_eq!(Placement::parse("BALANCED"), Some(Placement::LoadBalanced));
        assert_eq!(Placement::parse("sparsity"), Some(Placement::SparsityAware));
        assert_eq!(Placement::parse("timing"), Some(Placement::TimingAware));
        assert_eq!(Placement::parse("TIMING-AWARE"), Some(Placement::TimingAware));
        assert_eq!(Placement::parse("nope"), None);
        assert_eq!(Placement::RoundRobin.name(), "round-robin");
        assert_eq!(Placement::SparsityAware.name(), "sparsity-aware");
        assert_eq!(Placement::TimingAware.name(), "timing-aware");
    }

    #[test]
    fn timing_aware_build_assignment_matches_load_balanced() {
        // With no measurements yet (build time), timing-aware must fall
        // back to the load-balanced static assignment.
        let (plan, src) = dense_plan(96, 96);
        assert_eq!(
            TimingAwarePlacement.assign(&plan, &src, 3),
            LoadBalancedPlacement.assign(&plan, &src, 3)
        );
    }
}
