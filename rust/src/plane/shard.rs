//! Shard worker: the execution loop behind both the one-shot coordinator
//! and the resident serving sessions.
//!
//! A shard is one long-lived thread pulling jobs off a FIFO queue.  Since
//! the plane grew a concurrent admission surface
//! ([`PlaneHandle`](super::PlaneHandle)), shards no longer *own* operand
//! state: executors and programmed tiles live in per-`(operand, MCA)`
//! slots ([`McaSlot`](super::handle::McaSlot)) shared through `Arc`s
//! carried by the jobs themselves, and each walk gathers on its own reply
//! channel.  That is what lets one shard interleave jobs of many
//! concurrent walks, and what lets batch workers **steal** work from each
//! other when irregular sparsity leaves some queues short.
//!
//! **Shard-side tile materialization.**  A programming job carries a
//! [`TilePayload`]: either a dense tile the leader already extracted
//! (the compatibility path), or a **chunk descriptor** — an `Arc`'d
//! [`MatrixSource`] plus the chunk's coordinates.  With a descriptor the
//! shard extracts the zero-padded block itself, fused directly into the
//! conductance encode, so extraction parallelizes across the whole pool
//! and sparse tiles never materialize on the leader (see
//! `PlaneHandle::program_shared`).
//!
//! **Determinism contract.**  Each resident operand owns its *own* set of
//! executors: MCA `i`'s simulator for operand `k` is seeded from
//! `(master seed, i)` ([`mca_seed`]) exactly as if the operand had a
//! dedicated plane.  Programming jobs for one MCA always flow through the
//! placement-assigned owner shard in plan order (FIFO queue), so the
//! executor's persistent write–verify RNG draws in chunk order no matter
//! what other walks interleave — and since extraction is a pure read of
//! the source, *where* a tile is materialized cannot change a bit of it.
//! Resident execution noise comes from a *counter-based* stream derived
//! from `(master seed, mca, solve index, chunk)` ([`exec_stream_seed`]),
//! swapped into the executor per chunk execution.  Batch work is claimed
//! at **sub-MCA granularity**: each MCA's resident chunks form a grid
//! with an atomic cursor, a claim is one chunk (all batch vectors), and
//! every claimant executes through the *owner's* executor under the slot
//! lock — so which worker runs which chunk can never change a single RNG
//! draw.  The one thing chunk-level interleaving does relax is the order
//! in which one MCA's `f64` energy ledger accumulates its chunks, which
//! is ulp-level only and never touches results (see `plane::handle`).
//!
//! **Fault containment.**  Every job is processed under
//! [`std::panic::catch_unwind`]: a panicking shard reports
//! [`ShardMsg::Failed`] on the walk's own reply channel and exits,
//! instead of silently dropping out of the reply protocol.  The leader's
//! supervised gather (see [`crate::plane`]) converts that into a clean
//! typed error — a shard panic cannot hang a `program` or
//! `execute_batch` gather, including walks *other* than the one that
//! panicked (their liveness sweep notices the dead thread).  A panic
//! inside a descriptor's `block()` is narrower: it is caught at the
//! extraction site and reported as that chunk's error, matching the
//! leader-extraction path's recoverable chunk failures.

use super::handle::{lock_unpoisoned, BatchWalk, OnceWalk, OperandEntry};
use super::timing::McaTiming;
use crate::config::SolveOptions;
use crate::ec::{EcOptions, TileExecutor};
use crate::linalg::{Matrix, Vector};
use crate::matrices::MatrixSource;
use crate::mca::Mca;
use crate::obs::{self, Counter, Lane, Stage};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::virtualization::ChunkSpec;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Deterministic per-MCA seed derivation: MCA `i`'s simulator stream is a
/// pure function of the master seed, independent of shard count and
/// placement.
pub fn mca_seed(master: u64, mca_index: usize) -> u64 {
    master
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(mca_index as u64)
}

/// Counter-based execution-stream derivation (Philox-style): the noise for
/// one `(solve, chunk)` pair is a pure function of the master seed and the
/// chunk's coordinates.  This is what makes resident-session results
/// independent of batching, shard count, work-stealing and scheduling
/// order.
pub fn exec_stream_seed(
    master: u64,
    mca_index: usize,
    solve: u64,
    block_row: usize,
    block_col: usize,
) -> u64 {
    let mut h = master ^ 0xA076_1D64_78BD_642F;
    for v in [
        mca_index as u64,
        solve,
        block_row as u64,
        block_col as u64,
    ] {
        h = (h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left(23);
        h = (h ^ (h >> 27)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h ^ (h >> 31)
}

/// Build the persistent executor for one MCA.  Both execution modes (fused
/// one-shot and program/execute residency) construct device state through
/// this single path, so they see identical simulators for a given seed.
pub fn new_executor(
    opts: &SolveOptions,
    cell: usize,
    backend: &Backend,
    mca_index: usize,
) -> TileExecutor {
    let mca = Mca::new(opts.material, cell, cell, mca_seed(opts.seed, mca_index));
    TileExecutor::new(mca, backend.clone())
}

/// What a programming-shaped job carries for its tile.
pub(crate) enum TilePayload {
    /// A dense tile the leader extracted (double-buffered compatibility
    /// path, and the baseline `benches/tile_pipeline.rs` measures against).
    Dense(Matrix),
    /// A chunk descriptor: the shard extracts the zero-padded block from
    /// the shared source itself, fused into the encode stage.  The job
    /// stays a few words instead of `cell²` floats.
    Descriptor(Arc<dyn MatrixSource>),
}

impl TilePayload {
    /// Materialize the dense tile for `spec`.  A panic inside a source's
    /// `block()` is contained here and surfaces as this chunk's error —
    /// the same recoverable semantics the leader-extraction path gives a
    /// panicking source.
    fn materialize(self, spec: &ChunkSpec, cell: usize) -> Result<Matrix, String> {
        match self {
            TilePayload::Dense(tile) => Ok(tile),
            TilePayload::Descriptor(src) => {
                catch_unwind(AssertUnwindSafe(|| {
                    src.block(spec.row0, spec.col0, cell, cell)
                }))
                .map_err(|payload| {
                    format!(
                        "extracting chunk ({}, {}) panicked: {}",
                        spec.block_row,
                        spec.block_col,
                        panic_text(payload)
                    )
                })
            }
        }
    }
}

/// One unit of work sent from the leader to a shard.  Shared state rides
/// along as `Arc`s and every job carries the reply sender of the walk it
/// belongs to, so replies of concurrent walks never interleave.
pub(crate) enum ShardJob {
    /// One-shot fused program + execute for a single chunk (the original
    /// `correctedMatVecMul` shape) against the walk's private executor
    /// set: answer with [`ShardMsg::Once`].
    RunOnce {
        spec: ChunkSpec,
        payload: TilePayload,
        x_chunk: Vector,
        walk: Arc<OnceWalk>,
        reply: mpsc::Sender<ShardMsg>,
    },
    /// Program one chunk of an operand into its MCA's slot: answer with
    /// [`ShardMsg::Programmed`]; the tile stays in the slot for later
    /// batches.
    Program {
        spec: ChunkSpec,
        payload: TilePayload,
        entry: Arc<OperandEntry>,
        reply: mpsc::Sender<ShardMsg>,
    },
    /// Join one batch walk: claim chunks from the walk's per-MCA grids
    /// (queue-assigned MCAs first, then sub-MCA stealing) and run every
    /// input vector against each claimed chunk.  Answer with one
    /// [`ShardMsg::Partial`] per (chunk, vector) executed here, then
    /// [`ShardMsg::Sealed`].
    Execute {
        walk: Arc<BatchWalk>,
        reply: mpsc::Sender<ShardMsg>,
    },
    /// Close a scatter walk: answer with [`ShardMsg::Sealed`].
    Seal { reply: mpsc::Sender<ShardMsg> },
}

impl ShardJob {
    /// The reply channel of the walk this job belongs to (where a caught
    /// panic must be reported).
    fn reply(&self) -> &mpsc::Sender<ShardMsg> {
        match self {
            ShardJob::RunOnce { reply, .. }
            | ShardJob::Program { reply, .. }
            | ShardJob::Execute { reply, .. }
            | ShardJob::Seal { reply } => reply,
        }
    }
}

/// A shard's answer to the leader, on the walk's own reply channel.
pub(crate) enum ShardMsg {
    Once {
        block_row: usize,
        block_col: usize,
        /// `(partial product, write–verify iterations)`.
        outcome: Result<(Vector, usize), String>,
    },
    Programmed {
        block_row: usize,
        block_col: usize,
        /// Write–verify iterations the matrix encode used.
        outcome: Result<usize, String>,
    },
    Partial {
        solve: u64,
        block_row: usize,
        block_col: usize,
        outcome: Result<Vector, String>,
    },
    /// This shard is done with the walk (exact reply cardinality contract:
    /// one seal per shard per walk).
    Sealed { shard: usize },
    /// The shard caught a panic while serving this walk.  The shard exits
    /// after sending this — the leader poisons the plane and every later
    /// call returns a clean error.
    Failed { shard: usize, error: String },
}

pub(crate) struct ShardContext {
    pub shard: usize,
    pub cell: usize,
    pub opts: SolveOptions,
    pub backend: Backend,
    pub jobs: mpsc::Receiver<ShardJob>,
    /// Plane-wide measured per-MCA execution timings (feeds the
    /// timing-aware batch distribution and build-time placement).
    pub timings: Arc<Vec<McaTiming>>,
}

/// The counter handles a job handler may touch, cloned out of the cached
/// [`ShardCounters`] so the shard loop's own handles stay borrowable.
#[derive(Clone)]
pub(crate) struct WalkCounters {
    chunks: Counter,
    steals: Counter,
    submca_steals: Counter,
    encode_secs: Counter,
}

/// One shard's cached metric handles (label `shard` is static for the
/// thread's lifetime, so the registry lock is paid once, not per job).
struct ShardCounters {
    busy: Counter,
    idle: Counter,
    jobs: Counter,
    walk: WalkCounters,
}

/// Lazily build the shard's counter handles the first time metrics are
/// found enabled (planes built before the level was raised still record).
fn shard_counters(cache: &mut Option<ShardCounters>, shard: usize) -> &ShardCounters {
    cache.get_or_insert_with(|| {
        let label = shard.to_string();
        let labels: &[(&str, &str)] = &[("shard", &label)];
        let g = obs::global();
        ShardCounters {
            busy: g.counter(
                obs::names::SHARD_BUSY_SECONDS,
                "Per-shard seconds spent processing jobs",
                labels,
            ),
            idle: g.counter(
                obs::names::SHARD_IDLE_SECONDS,
                "Per-shard seconds spent blocked waiting for work",
                labels,
            ),
            jobs: g.counter(obs::names::SHARD_JOBS, "Jobs processed per shard", labels),
            walk: WalkCounters {
                chunks: g.counter(
                    obs::names::SHARD_CHUNKS,
                    "Chunk executions per shard, one per (chunk, vector)",
                    labels,
                ),
                steals: g.counter(
                    obs::names::SHARD_STEALS,
                    "MCAs this shard claimed from another worker's batch queue",
                    labels,
                ),
                submca_steals: g.counter(
                    obs::names::SUBMCA_STEALS,
                    "Sub-MCA steal participations: this shard joined another \
                     MCA's chunk grid and executed at least one chunk",
                    labels,
                ),
                encode_secs: g.counter(
                    obs::names::SHARD_ENCODE_SECONDS,
                    "Seconds this shard spent in the fused extract+encode stage",
                    labels,
                ),
            },
        }
    })
}

/// Render a caught panic payload as text (shared by the shard loop and
/// the leader-side walk supervision in [`crate::plane`]).
pub(crate) fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with a non-string payload".to_string()
    }
}

/// Shard main loop: process jobs until the last plane handle drops (the
/// job channel closes).  A reply channel whose receiver is gone (a leader
/// gave up on its walk) only mutes that walk's replies — the shard keeps
/// serving other walks.  A panic inside a job is caught, reported as
/// [`ShardMsg::Failed`] on the walk's reply channel, and kills the shard:
/// its in-progress executor state can no longer be trusted.
pub(crate) fn run(ctx: ShardContext) {
    let ec = ctx.opts.ec_options();
    let mut counters: Option<ShardCounters> = None;
    // Bounded receive (lint rule C1): the shard never parks forever on a
    // channel — it wakes on a coarse tick so a wedged sender side can
    // never strand a pool thread past plane teardown.
    const IDLE_TICK: Duration = Duration::from_millis(200);
    loop {
        let idle_clock = obs::metrics_clock();
        let job = loop {
            match ctx.jobs.recv_timeout(IDLE_TICK) {
                Ok(job) => break job,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        let handles = if let Some(t0) = idle_clock {
            let h = shard_counters(&mut counters, ctx.shard);
            h.idle.add(t0.elapsed().as_secs_f64());
            h.jobs.inc();
            Some(h.walk.clone())
        } else {
            None
        };
        let busy_clock = obs::metrics_clock();
        let reply = job.reply().clone();
        let handled = catch_unwind(AssertUnwindSafe(|| {
            handle(&ctx, &ec, job, handles.as_ref())
        }));
        if let Some(t0) = busy_clock {
            shard_counters(&mut counters, ctx.shard)
                .busy
                .add(t0.elapsed().as_secs_f64());
        }
        if let Err(payload) = handled {
            let _ = reply.send(ShardMsg::Failed {
                shard: ctx.shard,
                error: panic_text(payload),
            });
            return;
        }
    }
}

/// Span arguments shared by every shard-side stage event.
fn chunk_args(spec: &ChunkSpec) -> Vec<(&'static str, String)> {
    vec![
        ("chunk", format!("({},{})", spec.block_row, spec.block_col)),
        ("mca", spec.mca_index.to_string()),
    ]
}

/// Process one job.  All replies are best-effort sends: a closed reply
/// channel means that walk's leader already returned, and nothing here
/// outlives the job (shared state sits behind the job's `Arc`s).
fn handle(
    ctx: &ShardContext,
    ec: &EcOptions,
    job: ShardJob,
    counters: Option<&WalkCounters>,
) {
    let lane = Lane::Shard(ctx.shard);
    match job {
        ShardJob::RunOnce {
            spec,
            payload,
            x_chunk,
            walk,
            reply,
        } => {
            let mut slot = lock_unpoisoned(&walk.executors[spec.mca_index]);
            let exec = slot.get_or_insert_with(|| {
                new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
            });
            // `run_tile` split into its two halves so encode and execute
            // trace as separate stages — same calls, bit-identical result.
            // Descriptor extraction happens inside the encode stage: the
            // fused extract+encode this shard is paid for.
            let encode_span = obs::span_start();
            let encode_clock = obs::metrics_clock();
            let programmed = payload
                .materialize(&spec, ctx.cell)
                .and_then(|a_tile| exec.program_tile(&a_tile, ec));
            if let (Some(c), Some(t0)) = (counters, encode_clock) {
                c.encode_secs.add(t0.elapsed().as_secs_f64());
            }
            if let Some(sp) = encode_span {
                sp.finish(Stage::Encode, lane, chunk_args(&spec));
            }
            let outcome = match programmed {
                Ok(tile) => {
                    let exec_span = obs::span_start();
                    let out = exec
                        .execute_tile(&tile, &x_chunk, ec)
                        .map(|r| (r.y, r.encode.iters));
                    if let Some(sp) = exec_span {
                        sp.finish(Stage::Execute, lane, chunk_args(&spec));
                    }
                    out
                }
                Err(e) => Err(e),
            };
            if let Some(c) = counters {
                c.chunks.inc();
            }
            let _ = reply.send(ShardMsg::Once {
                block_row: spec.block_row,
                block_col: spec.block_col,
                outcome,
            });
        }
        ShardJob::Program {
            spec,
            payload,
            entry,
            reply,
        } => {
            let mut slot = lock_unpoisoned(&entry.mcas[spec.mca_index]);
            let exec = slot.exec.get_or_insert_with(|| {
                new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
            });
            let encode_span = obs::span_start();
            let encode_clock = obs::metrics_clock();
            let outcome = match payload
                .materialize(&spec, ctx.cell)
                .and_then(|a_tile| exec.program_tile(&a_tile, ec))
            {
                Ok(tile) => {
                    let iters = tile.encode.iters;
                    slot.chunks.push((spec, tile));
                    Ok(iters)
                }
                Err(e) => Err(e),
            };
            if let (Some(c), Some(t0)) = (counters, encode_clock) {
                c.encode_secs.add(t0.elapsed().as_secs_f64());
            }
            if let Some(sp) = encode_span {
                let mut args = chunk_args(&spec);
                args.push(("operand", entry.op.to_string()));
                sp.finish(Stage::Encode, lane, args);
            }
            let _ = reply.send(ShardMsg::Programmed {
                block_row: spec.block_row,
                block_col: spec.block_col,
                outcome,
            });
        }
        ShardJob::Execute { walk, reply } => {
            execute_walk(ctx, ec, &walk, &reply, counters);
            let _ = reply.send(ShardMsg::Sealed { shard: ctx.shard });
        }
        ShardJob::Seal { reply } => {
            let _ = reply.send(ShardMsg::Sealed { shard: ctx.shard });
        }
    }
}

/// One worker's share of a batch walk, in two phases:
///
/// 1. **Queue phase** — claim whole MCAs off the per-shard queues (own
///    queue first, then steal across queues) and drain each claimed MCA's
///    chunk grid.
/// 2. **Sub-MCA phase** — once every queue is empty, scan for MCAs whose
///    grids still have unclaimed chunks (a dominating MCA someone is
///    mid-way through) and join them, splitting the remainder with
///    whoever is already there.
///
/// Both phases execute through [`run_mca_grid`]: the unit of claim is one
/// chunk × the whole batch, every claimant runs under the owner slot's
/// lock with the owner's executor, and every RNG draw is counter-based
/// per `(solve, chunk)` — so the split is invisible in the results.
fn execute_walk(
    ctx: &ShardContext,
    ec: &EcOptions,
    walk: &BatchWalk,
    reply: &mpsc::Sender<ShardMsg>,
    counters: Option<&WalkCounters>,
) {
    while let Some((mca, stolen)) = walk.claim(ctx.shard) {
        if stolen {
            if let Some(c) = counters {
                c.steals.inc();
            }
        }
        run_mca_grid(ctx, ec, walk, mca, reply, counters);
    }
    // Queues drained: steal at sub-MCA granularity from grids still in
    // progress.  Each target's cursor only moves forward, so this loop
    // terminates once every grid is exhausted.
    while let Some(mca) = walk.steal_target() {
        let ran = run_mca_grid(ctx, ec, walk, mca, reply, counters);
        if ran > 0 {
            if let Some(c) = counters {
                c.submca_steals.inc();
            }
        }
    }
}

/// Drain one MCA's chunk grid: repeatedly claim the next unexecuted chunk
/// (atomic cursor) and run the whole batch against it under the slot
/// lock.  Returns how many chunks this call executed (possibly zero, if
/// other workers got there first).
fn run_mca_grid(
    ctx: &ShardContext,
    ec: &EcOptions,
    walk: &BatchWalk,
    mca: usize,
    reply: &mpsc::Sender<ShardMsg>,
    counters: Option<&WalkCounters>,
) -> u64 {
    let lane = Lane::Shard(ctx.shard);
    let entry = &walk.entry;
    let mut chunks_run = 0u64;
    loop {
        let i = walk.grid[mca].fetch_add(1, Ordering::Relaxed);
        let t0 = super::timing::monotonic_now();
        let mut guard = lock_unpoisoned(&entry.mcas[mca]);
        let slot = &mut *guard;
        let Some((spec, tile)) = slot.chunks.get(i) else {
            return chunks_run;
        };
        let mut executed = 0u64;
        for (k, x) in walk.xs.iter().enumerate() {
            let solve = walk.first_solve + k as u64;
            let exec_span = obs::span_start();
            let outcome = match slot.exec.as_mut() {
                Some(exec) => {
                    let x_chunk = x.slice_padded(spec.col0, ctx.cell);
                    let stream = Rng::new(exec_stream_seed(
                        ctx.opts.seed,
                        spec.mca_index,
                        solve,
                        spec.block_row,
                        spec.block_col,
                    ));
                    let saved = exec.mca.replace_rng(stream);
                    let out = exec.execute_tile(tile, &x_chunk, ec).map(|r| r.y);
                    exec.mca.replace_rng(saved);
                    out
                }
                None => Err("resident chunk lost its executor".to_string()),
            };
            if let Some(sp) = exec_span {
                let mut args = chunk_args(spec);
                args.push(("operand", entry.op.to_string()));
                args.push(("solve", solve.to_string()));
                sp.finish(Stage::Execute, lane, args);
            }
            if let Some(c) = counters {
                c.chunks.inc();
            }
            executed += 1;
            let _ = reply.send(ShardMsg::Partial {
                solve,
                block_row: spec.block_row,
                block_col: spec.block_col,
                outcome,
            });
        }
        drop(guard);
        ctx.timings[mca].record(t0.elapsed().as_secs_f64(), executed);
        chunks_run += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_stream_seed_separates_coordinates() {
        let base = exec_stream_seed(42, 0, 0, 0, 0);
        assert_ne!(base, exec_stream_seed(43, 0, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 1, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 1, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 1, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 0, 1));
        assert_eq!(base, exec_stream_seed(42, 0, 0, 0, 0));
    }

    #[test]
    fn mca_seed_is_stable_and_distinct() {
        assert_eq!(mca_seed(7, 3), mca_seed(7, 3));
        assert_ne!(mca_seed(7, 3), mca_seed(7, 4));
        assert_ne!(mca_seed(7, 3), mca_seed(8, 3));
    }

    #[test]
    fn panic_text_renders_common_payloads() {
        let s = catch_unwind(|| panic!("boom")).unwrap_err();
        assert_eq!(panic_text(s), "boom");
        let s = catch_unwind(|| panic!("chunk {}", 3)).unwrap_err();
        assert_eq!(panic_text(s), "chunk 3");
    }

    #[test]
    fn descriptor_payload_materializes_the_same_tile() {
        use crate::matrices::generators;
        use crate::virtualization::{ChunkPlan, SystemGeometry};
        let src = generators::power_law_csr(96, 3, 4.0, 50.0, 0.2, 0x7E57);
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), 96, 96);
        let shared: Arc<dyn MatrixSource> = Arc::new(generators::power_law_csr(
            96, 3, 4.0, 50.0, 0.2, 0x7E57,
        ));
        for spec in plan.chunks() {
            let leader = src.block(spec.row0, spec.col0, 32, 32);
            let shard = TilePayload::Descriptor(shared.clone())
                .materialize(&spec, 32)
                .unwrap();
            assert_eq!(leader, shard, "chunk ({}, {})", spec.block_row, spec.block_col);
            let dense = TilePayload::Dense(leader.clone())
                .materialize(&spec, 32)
                .unwrap();
            assert_eq!(leader, dense);
        }
        // A panicking source surfaces as a chunk error, not a dead shard.
        struct Bomb;
        impl MatrixSource for Bomb {
            fn nrows(&self) -> usize {
                64
            }
            fn ncols(&self) -> usize {
                64
            }
            fn block(&self, _: usize, _: usize, _: usize, _: usize) -> Matrix {
                panic!("bad source")
            }
            fn matvec(&self, _: &Vector) -> Vector {
                unreachable!()
            }
            fn max_abs(&self) -> f64 {
                1.0
            }
        }
        let bomb: Arc<dyn MatrixSource> = Arc::new(Bomb);
        let spec = plan.chunk(0, 0);
        let err = TilePayload::Descriptor(bomb)
            .materialize(&spec, 32)
            .unwrap_err();
        assert!(err.contains("panicked") && err.contains("bad source"), "{err}");
    }
}
