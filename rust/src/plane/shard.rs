//! Shard worker: the single scatter/gather execution loop behind both the
//! one-shot coordinator and the resident serving sessions.
//!
//! A shard is one long-lived thread owning, for every operand resident on
//! it, the [`TileExecutor`]s of the MCAs placed on it (see
//! [`crate::plane::placement`]).  An MCA never migrates, so its RNG
//! stream, its fixed-pattern noise and its energy ledger stay consistent
//! across every job the shard processes.
//!
//! **Determinism contract.**  Each resident operand owns its *own* set of
//! executors: MCA `i`'s simulator for operand `k` is seeded from
//! `(master seed, i)` ([`mca_seed`]) exactly as if the operand had a
//! dedicated plane, and the leader dispatches each operand's chunks in a
//! fixed row-major order over a FIFO channel — so multi-tenant residency
//! is bit-identical to one plane per operand.  Resident execution noise
//! comes from a *counter-based* stream derived from
//! `(master seed, mca, solve index, chunk)` ([`exec_stream_seed`]), so a
//! batch of N vectors is bit-identical to N sequential solves.
//!
//! **Fault containment.**  Every job is processed under
//! [`std::panic::catch_unwind`]: a panicking shard seals the ledgers of
//! the walk it was serving into a [`ShardMsg::Failed`] report and exits,
//! instead of silently dropping out of the reply protocol.  The leader's
//! supervised gather (see [`crate::plane`]) converts that into a clean
//! error — a shard panic can no longer hang a resident `program` or
//! `execute_batch` gather.

use crate::config::SolveOptions;
use crate::ec::{EcOptions, ProgrammedTile, TileExecutor};
use crate::linalg::{Matrix, Vector};
use crate::mca::{EnergyLedger, Mca};
use crate::obs::{self, Counter, Lane, Stage};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::virtualization::ChunkSpec;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;

/// Deterministic per-MCA seed derivation: MCA `i`'s simulator stream is a
/// pure function of the master seed, independent of shard count and
/// placement.
pub fn mca_seed(master: u64, mca_index: usize) -> u64 {
    master
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(mca_index as u64)
}

/// Counter-based execution-stream derivation (Philox-style): the noise for
/// one `(solve, chunk)` pair is a pure function of the master seed and the
/// chunk's coordinates.  This is what makes resident-session results
/// independent of batching, shard count and scheduling order.
pub fn exec_stream_seed(
    master: u64,
    mca_index: usize,
    solve: u64,
    block_row: usize,
    block_col: usize,
) -> u64 {
    let mut h = master ^ 0xA076_1D64_78BD_642F;
    for v in [
        mca_index as u64,
        solve,
        block_row as u64,
        block_col as u64,
    ] {
        h = (h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left(23);
        h = (h ^ (h >> 27)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h ^ (h >> 31)
}

/// Build the persistent executor for one MCA.  Both execution modes (fused
/// one-shot and program/execute residency) construct device state through
/// this single path, so they see identical simulators for a given seed.
pub fn new_executor(
    opts: &SolveOptions,
    cell: usize,
    backend: &Backend,
    mca_index: usize,
) -> TileExecutor {
    let mca = Mca::new(opts.material, cell, cell, mca_seed(opts.seed, mca_index));
    TileExecutor::new(mca, backend.clone())
}

/// One unit of work sent from the leader to a shard.
pub(crate) enum ShardJob {
    /// One-shot fused program + execute for a single chunk (the original
    /// `correctedMatVecMul` shape): answer with [`ShardMsg::Once`].
    RunOnce {
        spec: ChunkSpec,
        a_tile: Matrix,
        x_chunk: Vector,
    },
    /// Program one chunk of operand `op` resident on its MCA: answer with
    /// [`ShardMsg::Programmed`] and keep the tile for later `Execute`s.
    Program {
        op: u64,
        spec: ChunkSpec,
        a_tile: Matrix,
    },
    /// Run a batch of input vectors against every tile of operand `op`
    /// resident on this shard: answer with one [`ShardMsg::Partial`] per
    /// (tile, vector), then a [`ShardMsg::Sealed`] ledger snapshot.
    Execute {
        op: u64,
        first_solve: u64,
        xs: Arc<Vec<Vector>>,
    },
    /// Drop operand `op`'s resident tiles and executors: answer with a
    /// final [`ShardMsg::Sealed`] ledger snapshot.
    Evict { op: u64 },
    /// Close a `RunOnce` (`op` = `None`) or `Program` (`op` = `Some`)
    /// scatter walk: answer with [`ShardMsg::Sealed`].
    Seal { op: Option<u64> },
}

impl ShardJob {
    /// Which operand's ledgers a panic while serving this job should seal.
    fn walk_op(&self) -> Option<u64> {
        match self {
            ShardJob::RunOnce { .. } => None,
            ShardJob::Program { op, .. }
            | ShardJob::Execute { op, .. }
            | ShardJob::Evict { op } => Some(*op),
            ShardJob::Seal { op } => *op,
        }
    }
}

/// A shard's answer to the leader.
pub(crate) enum ShardMsg {
    Once {
        block_row: usize,
        block_col: usize,
        /// `(partial product, write–verify iterations)`.
        outcome: Result<(Vector, usize), String>,
    },
    Programmed {
        block_row: usize,
        block_col: usize,
        /// Write–verify iterations the matrix encode used.
        outcome: Result<usize, String>,
    },
    Partial {
        solve: u64,
        block_row: usize,
        block_col: usize,
        outcome: Result<Vector, String>,
    },
    /// Cumulative per-MCA ledger snapshot, closing one walk.
    Sealed {
        shard: usize,
        ledgers: Vec<(usize, EnergyLedger)>,
    },
    /// The shard caught a panic: its final ledger snapshot plus the panic
    /// message.  The shard exits after sending this — the leader marks the
    /// plane failed and every later call returns a clean error.
    Failed {
        shard: usize,
        error: String,
        ledgers: Vec<(usize, EnergyLedger)>,
    },
}

pub(crate) struct ShardContext {
    pub shard: usize,
    pub cell: usize,
    pub opts: SolveOptions,
    pub backend: Backend,
    pub jobs: mpsc::Receiver<ShardJob>,
    pub out: mpsc::Sender<ShardMsg>,
}

/// Per-operand shard-side residency: this shard's slice of the operand's
/// executors and programmed tiles.
#[derive(Default)]
struct OperandState {
    executors: HashMap<usize, TileExecutor>,
    resident: Vec<(ChunkSpec, ProgrammedTile)>,
}

impl OperandState {
    fn ledgers(&self) -> Vec<(usize, EnergyLedger)> {
        self.executors
            .iter()
            .map(|(idx, e)| (*idx, e.mca.ledger))
            .collect()
    }
}

/// All state a shard thread owns: one executor set per resident operand,
/// plus the separate executor set the fused one-shot path uses.
struct ShardState {
    oneshot: HashMap<usize, TileExecutor>,
    ops: HashMap<u64, OperandState>,
}

impl ShardState {
    fn ledgers_for(&self, op: Option<u64>) -> Vec<(usize, EnergyLedger)> {
        match op {
            None => self
                .oneshot
                .iter()
                .map(|(idx, e)| (*idx, e.mca.ledger))
                .collect(),
            Some(op) => self.ops.get(&op).map(|o| o.ledgers()).unwrap_or_default(),
        }
    }
}

/// One shard's cached metric handles (label `shard` is static for the
/// thread's lifetime, so the registry lock is paid once, not per job).
struct ShardCounters {
    busy: Counter,
    idle: Counter,
    jobs: Counter,
    chunks: Counter,
}

/// Lazily build the shard's counter handles the first time metrics are
/// found enabled (planes built before the level was raised still record).
fn shard_counters(cache: &mut Option<ShardCounters>, shard: usize) -> &ShardCounters {
    cache.get_or_insert_with(|| {
        let label = shard.to_string();
        let labels: &[(&str, &str)] = &[("shard", &label)];
        let g = obs::global();
        ShardCounters {
            busy: g.counter(
                obs::names::SHARD_BUSY_SECONDS,
                "Per-shard seconds spent processing jobs",
                labels,
            ),
            idle: g.counter(
                obs::names::SHARD_IDLE_SECONDS,
                "Per-shard seconds spent blocked waiting for work",
                labels,
            ),
            jobs: g.counter(obs::names::SHARD_JOBS, "Jobs processed per shard", labels),
            chunks: g.counter(
                obs::names::SHARD_CHUNKS,
                "Chunk executions per shard, one per (chunk, vector)",
                labels,
            ),
        }
    })
}

/// Render a caught panic payload as text (shared by the shard loop and
/// the leader-side walk supervision in [`crate::plane`]).
pub(crate) fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with a non-string payload".to_string()
    }
}

/// Shard main loop: process jobs until the leader closes the channel.
///
/// The leader's gather is *supervised* (per-shard seal tracking + liveness
/// checks), but the contract here is still exact reply cardinalities per
/// walk, closed by one `Sealed` per shard.  A panic inside a job no longer
/// breaks that contract silently: it is caught, the walk's ledgers are
/// sealed into a [`ShardMsg::Failed`], and the shard exits.
pub(crate) fn run(ctx: ShardContext) {
    let ec = ctx.opts.ec_options();
    let mut state = ShardState {
        oneshot: HashMap::new(),
        ops: HashMap::new(),
    };
    let mut counters: Option<ShardCounters> = None;
    loop {
        let idle_clock = obs::metrics_clock();
        let job = match ctx.jobs.recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let chunk_counter = if let Some(t0) = idle_clock {
            let h = shard_counters(&mut counters, ctx.shard);
            h.idle.add(t0.elapsed().as_secs_f64());
            h.jobs.inc();
            Some(h.chunks.clone())
        } else {
            None
        };
        let busy_clock = obs::metrics_clock();
        let walk_op = job.walk_op();
        let chunk_counter = chunk_counter.as_ref();
        let handled =
            catch_unwind(AssertUnwindSafe(|| {
                handle(&ctx, &ec, &mut state, job, chunk_counter)
            }));
        if let Some(t0) = busy_clock {
            shard_counters(&mut counters, ctx.shard)
                .busy
                .add(t0.elapsed().as_secs_f64());
        }
        match handled {
            // Job handled; leader still listening.
            Ok(true) => {}
            // Reply channel closed: the leader is gone, stop quietly.
            Ok(false) => return,
            Err(payload) => {
                let ledgers = state.ledgers_for(walk_op);
                let _ = ctx.out.send(ShardMsg::Failed {
                    shard: ctx.shard,
                    error: panic_text(payload),
                    ledgers,
                });
                return;
            }
        }
    }
}

/// Span arguments shared by every shard-side stage event.
fn chunk_args(spec: &ChunkSpec) -> Vec<(&'static str, String)> {
    vec![
        ("chunk", format!("({},{})", spec.block_row, spec.block_col)),
        ("mca", spec.mca_index.to_string()),
    ]
}

/// Process one job.  Returns `false` when the reply channel is closed.
/// `chunks` is the shard's chunk-execution counter when metrics are on.
fn handle(
    ctx: &ShardContext,
    ec: &EcOptions,
    state: &mut ShardState,
    job: ShardJob,
    chunks: Option<&Counter>,
) -> bool {
    let lane = Lane::Shard(ctx.shard);
    match job {
        ShardJob::RunOnce {
            spec,
            a_tile,
            x_chunk,
        } => {
            let exec = state.oneshot.entry(spec.mca_index).or_insert_with(|| {
                new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
            });
            // `run_tile` split into its two halves so encode and execute
            // trace as separate stages — same calls, bit-identical result.
            let encode_span = obs::span_start();
            let programmed = exec.program_tile(&a_tile, ec);
            if let Some(sp) = encode_span {
                sp.finish(Stage::Encode, lane, chunk_args(&spec));
            }
            let outcome = match programmed {
                Ok(tile) => {
                    let exec_span = obs::span_start();
                    let out = exec
                        .execute_tile(&tile, &x_chunk, ec)
                        .map(|r| (r.y, r.encode.iters));
                    if let Some(sp) = exec_span {
                        sp.finish(Stage::Execute, lane, chunk_args(&spec));
                    }
                    out
                }
                Err(e) => Err(e),
            };
            if let Some(c) = chunks {
                c.inc();
            }
            let msg = ShardMsg::Once {
                block_row: spec.block_row,
                block_col: spec.block_col,
                outcome,
            };
            ctx.out.send(msg).is_ok()
        }
        ShardJob::Program { op, spec, a_tile } => {
            let opstate = state.ops.entry(op).or_default();
            let exec = opstate.executors.entry(spec.mca_index).or_insert_with(|| {
                new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
            });
            let encode_span = obs::span_start();
            let outcome = match exec.program_tile(&a_tile, ec) {
                Ok(tile) => {
                    let iters = tile.encode.iters;
                    opstate.resident.push((spec, tile));
                    Ok(iters)
                }
                Err(e) => Err(e),
            };
            if let Some(sp) = encode_span {
                let mut args = chunk_args(&spec);
                args.push(("operand", op.to_string()));
                sp.finish(Stage::Encode, lane, args);
            }
            let msg = ShardMsg::Programmed {
                block_row: spec.block_row,
                block_col: spec.block_col,
                outcome,
            };
            ctx.out.send(msg).is_ok()
        }
        ShardJob::Execute {
            op,
            first_solve,
            xs,
        } => {
            let Some(opstate) = state.ops.get_mut(&op) else {
                // No chunks of this operand were placed on this shard:
                // the walk still closes with an (empty) seal.
                let msg = ShardMsg::Sealed {
                    shard: ctx.shard,
                    ledgers: Vec::new(),
                };
                return ctx.out.send(msg).is_ok();
            };
            for (spec, tile) in opstate.resident.iter() {
                for (k, x) in xs.iter().enumerate() {
                    let solve = first_solve + k as u64;
                    let exec_span = obs::span_start();
                    let outcome = match opstate.executors.get_mut(&spec.mca_index) {
                        Some(exec) => {
                            let x_chunk = x.slice_padded(spec.col0, ctx.cell);
                            let stream = Rng::new(exec_stream_seed(
                                ctx.opts.seed,
                                spec.mca_index,
                                solve,
                                spec.block_row,
                                spec.block_col,
                            ));
                            let saved = exec.mca.replace_rng(stream);
                            let out = exec.execute_tile(tile, &x_chunk, ec).map(|r| r.y);
                            exec.mca.replace_rng(saved);
                            out
                        }
                        None => Err("resident chunk lost its executor".to_string()),
                    };
                    if let Some(sp) = exec_span {
                        let mut args = chunk_args(spec);
                        args.push(("operand", op.to_string()));
                        args.push(("solve", solve.to_string()));
                        sp.finish(Stage::Execute, lane, args);
                    }
                    if let Some(c) = chunks {
                        c.inc();
                    }
                    let msg = ShardMsg::Partial {
                        solve,
                        block_row: spec.block_row,
                        block_col: spec.block_col,
                        outcome,
                    };
                    if ctx.out.send(msg).is_err() {
                        return false;
                    }
                }
            }
            let msg = ShardMsg::Sealed {
                shard: ctx.shard,
                ledgers: opstate.ledgers(),
            };
            ctx.out.send(msg).is_ok()
        }
        ShardJob::Evict { op } => {
            let ledgers = state
                .ops
                .remove(&op)
                .map(|o| o.ledgers())
                .unwrap_or_default();
            let msg = ShardMsg::Sealed {
                shard: ctx.shard,
                ledgers,
            };
            ctx.out.send(msg).is_ok()
        }
        ShardJob::Seal { op } => {
            let msg = ShardMsg::Sealed {
                shard: ctx.shard,
                ledgers: state.ledgers_for(op),
            };
            ctx.out.send(msg).is_ok()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_stream_seed_separates_coordinates() {
        let base = exec_stream_seed(42, 0, 0, 0, 0);
        assert_ne!(base, exec_stream_seed(43, 0, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 1, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 1, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 1, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 0, 1));
        assert_eq!(base, exec_stream_seed(42, 0, 0, 0, 0));
    }

    #[test]
    fn mca_seed_is_stable_and_distinct() {
        assert_eq!(mca_seed(7, 3), mca_seed(7, 3));
        assert_ne!(mca_seed(7, 3), mca_seed(7, 4));
        assert_ne!(mca_seed(7, 3), mca_seed(8, 3));
    }

    #[test]
    fn panic_text_renders_common_payloads() {
        let s = catch_unwind(|| panic!("boom")).unwrap_err();
        assert_eq!(panic_text(s), "boom");
        let s = catch_unwind(|| panic!("chunk {}", 3)).unwrap_err();
        assert_eq!(panic_text(s), "chunk 3");
    }
}
