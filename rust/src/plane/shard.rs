//! Shard worker: the single scatter/gather execution loop behind both the
//! one-shot coordinator and the resident serving sessions.
//!
//! A shard is one long-lived thread owning the [`TileExecutor`]s of the
//! MCAs placed on it (see [`crate::plane::placement`]).  An MCA never
//! migrates, so its RNG stream, its fixed-pattern noise and its energy
//! ledger stay consistent across every job the shard processes.
//!
//! **Determinism contract.**  MCA `i`'s simulator is seeded from
//! `(master seed, i)` ([`mca_seed`]) and the leader dispatches each MCA's
//! chunks in a fixed row-major order over a FIFO channel, so programming
//! consumes every per-MCA stream in the same sequence no matter how many
//! shards run, which policy placed the MCAs, or how threads are scheduled.
//! Resident execution noise comes from a *counter-based* stream derived
//! from `(master seed, mca, solve index, chunk)` ([`exec_stream_seed`]), so
//! a batch of N vectors is bit-identical to N sequential solves.

use crate::config::SolveOptions;
use crate::ec::{ProgrammedTile, TileExecutor};
use crate::linalg::{Matrix, Vector};
use crate::mca::{EnergyLedger, Mca};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::virtualization::ChunkSpec;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Deterministic per-MCA seed derivation: MCA `i`'s simulator stream is a
/// pure function of the master seed, independent of shard count and
/// placement.
pub fn mca_seed(master: u64, mca_index: usize) -> u64 {
    master
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(mca_index as u64)
}

/// Counter-based execution-stream derivation (Philox-style): the noise for
/// one `(solve, chunk)` pair is a pure function of the master seed and the
/// chunk's coordinates.  This is what makes resident-session results
/// independent of batching, shard count and scheduling order.
pub fn exec_stream_seed(
    master: u64,
    mca_index: usize,
    solve: u64,
    block_row: usize,
    block_col: usize,
) -> u64 {
    let mut h = master ^ 0xA076_1D64_78BD_642F;
    for v in [
        mca_index as u64,
        solve,
        block_row as u64,
        block_col as u64,
    ] {
        h = (h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left(23);
        h = (h ^ (h >> 27)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h ^ (h >> 31)
}

/// Build the persistent executor for one MCA.  Both execution modes (fused
/// one-shot and program/execute residency) construct device state through
/// this single path, so they see identical simulators for a given seed.
pub fn new_executor(
    opts: &SolveOptions,
    cell: usize,
    backend: &Backend,
    mca_index: usize,
) -> TileExecutor {
    let mca = Mca::new(opts.material, cell, cell, mca_seed(opts.seed, mca_index));
    TileExecutor::new(mca, backend.clone())
}

/// One unit of work sent from the leader to a shard.
pub(crate) enum ShardJob {
    /// One-shot fused program + execute for a single chunk (the original
    /// `correctedMatVecMul` shape): answer with [`ShardMsg::Once`].
    RunOnce {
        spec: ChunkSpec,
        a_tile: Matrix,
        x_chunk: Vector,
    },
    /// Program one chunk resident on its MCA: answer with
    /// [`ShardMsg::Programmed`] and keep the tile for later `Execute`s.
    Program { spec: ChunkSpec, a_tile: Matrix },
    /// Run a batch of input vectors against every resident tile: answer
    /// with one [`ShardMsg::Partial`] per (tile, vector), then a
    /// [`ShardMsg::Sealed`] ledger snapshot.
    Execute {
        first_solve: u64,
        xs: Arc<Vec<Vector>>,
    },
    /// Close a `RunOnce`/`Program` scatter walk: answer with
    /// [`ShardMsg::Sealed`].
    Seal,
}

/// A shard's answer to the leader.
pub(crate) enum ShardMsg {
    Once {
        block_row: usize,
        block_col: usize,
        /// `(partial product, write–verify iterations)`.
        outcome: Result<(Vector, usize), String>,
    },
    Programmed {
        block_row: usize,
        block_col: usize,
        /// Write–verify iterations the matrix encode used.
        outcome: Result<usize, String>,
    },
    Partial {
        solve: u64,
        block_row: usize,
        block_col: usize,
        outcome: Result<Vector, String>,
    },
    /// Cumulative per-MCA ledger snapshot, closing one walk.
    Sealed {
        ledgers: Vec<(usize, EnergyLedger)>,
    },
}

pub(crate) struct ShardContext {
    pub cell: usize,
    pub opts: SolveOptions,
    pub backend: Backend,
    pub jobs: mpsc::Receiver<ShardJob>,
    pub out: mpsc::Sender<ShardMsg>,
}

/// Shard main loop: process jobs until the leader closes the channel.
///
/// The leader counts on exact reply cardinalities (one `Once`/`Programmed`
/// per dispatched chunk, chunks × vectors `Partial`s per batch, one
/// `Sealed` per walk), so every path below must send — never panic — or
/// the gather would hang while other shards keep the reply channel open.
pub(crate) fn run(ctx: ShardContext) {
    let ec = ctx.opts.ec_options();
    let mut executors: HashMap<usize, TileExecutor> = HashMap::new();
    let mut resident: Vec<(ChunkSpec, ProgrammedTile)> = Vec::new();
    while let Ok(job) = ctx.jobs.recv() {
        match job {
            ShardJob::RunOnce {
                spec,
                a_tile,
                x_chunk,
            } => {
                let exec = executors.entry(spec.mca_index).or_insert_with(|| {
                    new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
                });
                let outcome = exec
                    .run_tile(&a_tile, &x_chunk, &ec)
                    .map(|r| (r.y, r.encode.iters));
                let msg = ShardMsg::Once {
                    block_row: spec.block_row,
                    block_col: spec.block_col,
                    outcome,
                };
                if ctx.out.send(msg).is_err() {
                    return;
                }
            }
            ShardJob::Program { spec, a_tile } => {
                let exec = executors.entry(spec.mca_index).or_insert_with(|| {
                    new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
                });
                let outcome = match exec.program_tile(&a_tile, &ec) {
                    Ok(tile) => {
                        let iters = tile.encode.iters;
                        resident.push((spec, tile));
                        Ok(iters)
                    }
                    Err(e) => Err(e),
                };
                let msg = ShardMsg::Programmed {
                    block_row: spec.block_row,
                    block_col: spec.block_col,
                    outcome,
                };
                if ctx.out.send(msg).is_err() {
                    return;
                }
            }
            ShardJob::Execute { first_solve, xs } => {
                for (spec, tile) in &resident {
                    for (k, x) in xs.iter().enumerate() {
                        let solve = first_solve + k as u64;
                        let outcome = match executors.get_mut(&spec.mca_index) {
                            Some(exec) => {
                                let x_chunk = x.slice_padded(spec.col0, ctx.cell);
                                let stream = Rng::new(exec_stream_seed(
                                    ctx.opts.seed,
                                    spec.mca_index,
                                    solve,
                                    spec.block_row,
                                    spec.block_col,
                                ));
                                let saved = exec.mca.replace_rng(stream);
                                let out = exec.execute_tile(tile, &x_chunk, &ec).map(|r| r.y);
                                exec.mca.replace_rng(saved);
                                out
                            }
                            None => Err("resident chunk lost its executor".to_string()),
                        };
                        let msg = ShardMsg::Partial {
                            solve,
                            block_row: spec.block_row,
                            block_col: spec.block_col,
                            outcome,
                        };
                        if ctx.out.send(msg).is_err() {
                            return;
                        }
                    }
                }
                if send_sealed(&ctx, &executors).is_err() {
                    return;
                }
            }
            ShardJob::Seal => {
                if send_sealed(&ctx, &executors).is_err() {
                    return;
                }
            }
        }
    }
}

fn send_sealed(
    ctx: &ShardContext,
    executors: &HashMap<usize, TileExecutor>,
) -> Result<(), mpsc::SendError<ShardMsg>> {
    let ledgers = executors.iter().map(|(idx, e)| (*idx, e.mca.ledger)).collect();
    ctx.out.send(ShardMsg::Sealed { ledgers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_stream_seed_separates_coordinates() {
        let base = exec_stream_seed(42, 0, 0, 0, 0);
        assert_ne!(base, exec_stream_seed(43, 0, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 1, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 1, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 1, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 0, 1));
        assert_eq!(base, exec_stream_seed(42, 0, 0, 0, 0));
    }

    #[test]
    fn mca_seed_is_stable_and_distinct() {
        assert_eq!(mca_seed(7, 3), mca_seed(7, 3));
        assert_ne!(mca_seed(7, 3), mca_seed(7, 4));
        assert_ne!(mca_seed(7, 3), mca_seed(8, 3));
    }
}
