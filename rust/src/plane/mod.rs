//! The sharded execution plane: one scatter/gather implementation behind
//! both one-shot solves and resident serving sessions.
//!
//! Historically the one-shot coordinator and the serving layer each owned
//! a private copy of the same machinery (thread pool, chunk dispatch,
//! partial-product gather, ledger collection).  The plane unifies them —
//! and since the concurrency redesign its serving surface is a clone-able
//! [`PlaneHandle`]: every admission method takes `&self`, so any number
//! of threads (sessions, solvers, iterative operators) share one shard
//! pool without an external mutex:
//!
//! ```text
//!                         ┌────────────────────────────┐
//!   one-shot              │      PlaneHandle (×N)      │     resident clients
//!   (coordinator)         │                            │     (server::Session, …)
//!                         │  placement: MCA→shard      │
//!   execute_once(A, x) ───┤  shard 0 ── MCA {0, 3, …}  ├──  program(A)  → op0
//!     program+execute     │  shard 1 ── MCA {1, 4, …}  │    program(B)  → op1
//!     fused per chunk,    │  shard 2 ── MCA {2, 5, …}  │    execute_batch(op0, xs) ┐
//!     fresh executors     │   (long-lived threads,     │    execute_batch(op1, xs) ┘ concurrent
//!     per walk            │    work-stealing batches)  │    evict(op0)
//!                         └────────────────────────────┘
//! ```
//!
//! * The **leader** (whichever caller thread admitted the walk)
//!   enumerates occupied chunks through
//!   [`ChunkPlan::nonzero_chunks`](crate::virtualization::ChunkPlan::nonzero_chunks) —
//!   O(occupied blocks) for sources with exact structure or a cheap
//!   column bound.  Over a *borrowed* source (`program` /
//!   `execute_once`) it streams extracted, zero-padded tiles over
//!   bounded channels with the extraction **double-buffered**: a
//!   producer thread extracts chunk `N + 1` while chunk `N` dispatches
//!   to its shard.  Over a *shared* (`Arc`'d) source (`program_shared`
//!   / `execute_once_shared`) it dispatches compact chunk
//!   **descriptors** instead and the shards extract their own tiles,
//!   fused into conductance encoding — the leader's per-chunk stage
//!   shrinks to enumerate + dispatch.  Either way even a 65,536²
//!   operand never materializes densely.
//! * Each **shard** is a long-lived worker thread.  Operand state
//!   (executors, programmed tiles) lives in per-`(operand, MCA)` locked
//!   slots shared via `Arc`, so shards interleave jobs of many concurrent
//!   walks.  Batch workers **steal** whole MCAs from each other's queues
//!   when irregular sparsity leaves theirs short, and once every queue is
//!   empty they steal at **sub-MCA granularity** — joining the chunk grid
//!   of the MCA with the most unclaimed chunks — so a single dominating
//!   MCA spreads over the pool instead of serializing on one worker.
//! * A [`TileAllocator`] tracks which tile slots of which MCA hold which
//!   operand's chunks: eviction frees slots for reuse, and an optional
//!   per-MCA capacity (`SystemConfig::tile_slots`) makes over-subscription
//!   a clean [`PlaneError::Capacity`].
//! * The leader gathers partial products on a **per-walk reply channel**
//!   and reduces them in deterministic chunk order ([`reduce_partials`]),
//!   so results are bit-reproducible for a given seed regardless of shard
//!   count, placement policy, concurrency level or steal order (see
//!   [`handle`] for the full determinism argument).
//!
//! **Fault tolerance.**  Shard jobs run under `catch_unwind` (a panicking
//! shard reports `ShardMsg::Failed` on the walk's reply channel and
//! exits), leader-side tile extraction is unwind-caught too, and every
//! gather is a *supervised* receive: per-shard seal tracking, a liveness
//! check against the worker [`JoinHandle`](std::thread::JoinHandle)s, and
//! a hard deadline (`MELISO_WALK_TIMEOUT_SECS`).  A shard panic mid-walk
//! therefore surfaces as a typed [`PlaneError`] from `program` /
//! `execute_batch` / `execute_once` — never a hang — and the plane
//! poisons itself so later calls fail fast instead of desynchronizing.
//!
//! Embedders usually reach the plane through
//! [`Meliso`](crate::solver::Meliso) (`build_plane` / `open_session_on`),
//! but it is a public runtime of its own:
//!
//! ```
//! use meliso::plane::ExecutionPlane;
//! use meliso::prelude::*;
//! use meliso::runtime::native::NativeBackend;
//! use std::sync::Arc;
//!
//! let src = meliso::matrices::registry::build("iperturb66").unwrap();
//! let cfg = SystemConfig::single_mca(128);
//! let opts = SolveOptions::default().with_workers(2);
//! let plane =
//!     ExecutionPlane::build(src.as_ref(), &cfg, &opts, Arc::new(NativeBackend::new())).unwrap();
//! let x = Vector::standard_normal(src.ncols(), 1);
//! let report = plane.execute_once(src.as_ref(), &x).unwrap(); // consumes the plane
//! assert_eq!(report.y.len(), 66);
//! ```
//!
//! For the serving surface, see the [`PlaneHandle`] example.

pub mod alloc;
pub mod error;
pub mod handle;
pub mod placement;
pub(crate) mod shard;
pub mod timing;

pub use self::alloc::{OperandId, TileAllocator};
pub use error::PlaneError;
pub use handle::PlaneHandle;
pub use timing::reset_domains;
pub use placement::{
    LoadBalancedPlacement, Placement, PlacementPolicy, RoundRobinPlacement,
    SparsityAwarePlacement, TimingAwarePlacement,
};
pub use shard::{exec_stream_seed, mca_seed, new_executor};

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::metrics::SolveReport;
use crate::runtime::Backend;
use std::collections::BTreeMap;

/// Reduce gathered per-chunk partial products into the output vector in
/// deterministic `(block_row, block_col)` order, so the sum is
/// bit-reproducible regardless of shard scheduling.  Rows past `m` (the
/// zero-padded tail of the last block row) are dropped.
pub fn reduce_partials(
    m: usize,
    tile: usize,
    partials: &BTreeMap<(usize, usize), Vector>,
) -> Vector {
    let mut y = Vector::zeros(m);
    for ((bi, _bj), part) in partials {
        let row0 = bi * tile;
        for (k, v) in part.data().iter().enumerate() {
            let idx = row0 + k;
            if idx < m {
                y.set(idx, y.get(idx) + v);
            }
        }
    }
    y
}

/// One-time programming cost and shape summary of a resident operand.
#[derive(Clone, Debug)]
pub struct ProgramReport {
    pub m: usize,
    pub n: usize,
    pub chunks_total: usize,
    /// Chunks actually written to the grid (non-zero blocks).
    pub chunks_resident: usize,
    pub chunks_skipped: usize,
    pub mcas_used: usize,
    pub normalization_factor: usize,
    pub mean_wv_iters: f64,
    /// Total write energy across MCAs — paid once for the residency.
    pub write_energy_j: f64,
    /// Max write latency across MCAs (wall-clock model: rows serial per
    /// MCA, MCAs parallel).
    pub write_latency_s: f64,
    pub wall_seconds: f64,
}

/// Result of one served solve.
#[derive(Clone, Debug)]
pub struct ServeSolve {
    pub y: Vector,
    /// Monotonic per-residency solve index (drives the noise counter).
    pub solve_index: u64,
    /// Wall-clock share of this vector (batch wall / batch size).
    pub wall_seconds: f64,
}

/// One executed batch: the per-vector results plus the whole batch's wall
/// clock (what serving statistics account against).
pub struct BatchOutcome {
    pub solves: Vec<ServeSolve>,
    pub wall_seconds: f64,
}

/// The one-shot view of a sharded execution plane.
///
/// This is a thin wrapper over [`PlaneHandle`] that preserves the
/// historical consumed-plane shape: [`execute_once`](Self::execute_once)
/// takes `self`, runs program + execute fused per chunk against a fresh
/// executor set, and tears the pool down when the last handle drops.
/// For the resident serving surface (`program` / `execute_batch` /
/// `evict`, all `&self` and clone-able) use [`handle`](Self::handle) or
/// build a [`PlaneHandle`] directly.
pub struct ExecutionPlane {
    handle: PlaneHandle,
}

impl ExecutionPlane {
    /// Spawn the shard pool sized for `source`'s chunk plan (see
    /// [`PlaneHandle::build`]).
    pub fn build(
        source: &dyn MatrixSource,
        config: &SystemConfig,
        opts: &SolveOptions,
        backend: Backend,
    ) -> Result<ExecutionPlane, PlaneError> {
        Ok(ExecutionPlane {
            handle: PlaneHandle::build(source, config, opts, backend)?,
        })
    }

    /// A clone-able handle to the same shard pool, for the resident
    /// serving surface.
    pub fn handle(&self) -> &PlaneHandle {
        &self.handle
    }

    /// Convert into the clone-able serving handle.
    pub fn into_handle(self) -> PlaneHandle {
        self.handle
    }

    /// Number of shard worker threads.
    pub fn shards(&self) -> usize {
        self.handle.shards()
    }

    /// MCA index → shard index, as decided by the placement policy.
    pub fn assignment(&self) -> &[usize] {
        self.handle.assignment()
    }

    /// The physical system configuration the pool was built for.
    pub fn system_config(&self) -> SystemConfig {
        self.handle.system_config()
    }

    /// The solve options every walk on this plane shares.
    pub fn options(&self) -> &SolveOptions {
        self.handle.options()
    }

    /// The failure that poisoned this plane, if any.
    pub fn failure(&self) -> Option<String> {
        self.handle.failure()
    }

    /// Total `(write, read)` energy across the plane so far.
    pub fn energy_totals(&self) -> (f64, f64) {
        self.handle.energy_totals()
    }

    /// Run one distributed MVM end-to-end (the one-shot path): program +
    /// execute fused per chunk, exact ground-truth comparison when
    /// `opts.ground_truth` is set, full [`SolveReport`].  Consumes the
    /// plane; the shard pool joins when the last handle drops.
    pub fn execute_once(
        self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, PlaneError> {
        self.handle.execute_once(source, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::linalg::Matrix;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    fn dense(m: usize, n: usize, seed: u64) -> DenseSource {
        DenseSource::new(Matrix::standard_normal(m, n, seed))
    }

    const ALL_PLACEMENTS: [Placement; 4] = [
        Placement::RoundRobin,
        Placement::LoadBalanced,
        Placement::SparsityAware,
        Placement::TimingAware,
    ];

    #[test]
    fn one_shot_bit_reproducible_across_shards_and_placements() {
        let src = dense(64, 64, 7);
        let x = Vector::standard_normal(64, 8);
        let config = SystemConfig::new(2, 2, 32);
        let run = |workers: usize, placement: Placement| {
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_seed(99)
                .with_workers(workers)
                .with_placement(placement);
            ExecutionPlane::build(&src, &config, &opts, native())
                .unwrap()
                .execute_once(&src, &x)
                .unwrap()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 4] {
            for placement in ALL_PLACEMENTS {
                let r = run(workers, placement);
                assert_eq!(
                    reference.y, r.y,
                    "{workers} workers, {}",
                    placement.name()
                );
            }
        }
    }

    #[test]
    fn resident_plane_program_then_batch() {
        let src = dense(48, 48, 21);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 4);
        assert_eq!(program.chunks_resident, 4);
        assert_eq!(plane.resident_operands(), 1);
        assert_eq!(plane.slots_in_use(), 4);
        let xs: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 30 + k)).collect();
        let batch = plane.execute_batch(id, &xs).unwrap();
        assert_eq!(batch.solves.len(), 2);
        for (k, s) in batch.solves.iter().enumerate() {
            assert_eq!(s.solve_index, k as u64);
            let b = src.matvec(&xs[k]);
            let err = s.y.sub(&b).norm_l2() / b.norm_l2();
            assert!(err < 0.1, "solve {k}: {err}");
        }
    }

    #[test]
    fn execute_with_unknown_operand_is_error() {
        let src = dense(32, 32, 5);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            PlaneHandle::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let x = Vector::standard_normal(32, 6);
        let err = plane
            .execute_batch(OperandId(0), std::slice::from_ref(&x))
            .unwrap_err();
        assert!(matches!(err, PlaneError::StaleOperand { .. }), "{err:?}");
        assert!(err.to_string().contains("not resident"), "{err}");
    }

    #[test]
    fn evicted_operand_id_is_stale() {
        let src = dense(32, 32, 9);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            PlaneHandle::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let (id, _) = plane.program(&src).unwrap();
        plane.evict(id).unwrap();
        assert_eq!(plane.resident_operands(), 0);
        assert_eq!(plane.slots_in_use(), 0);
        let x = Vector::standard_normal(32, 10);
        let err = plane
            .execute_batch(id, std::slice::from_ref(&x))
            .unwrap_err();
        assert!(matches!(err, PlaneError::StaleOperand { .. }), "{err:?}");
        assert!(matches!(
            plane.evict(id),
            Err(PlaneError::StaleOperand { .. })
        ));
    }

    #[test]
    fn two_operands_interleave_bit_identical_to_dedicated_planes() {
        let src_a = dense(48, 48, 31);
        let src_b = dense(48, 48, 32);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(77)
            .with_workers(3);
        let xs_a: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 40 + k)).collect();
        let xs_b: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 50 + k)).collect();

        // Dedicated planes, one operand each (the historical layout).
        let dedicated = |src: &DenseSource, xs: &[Vector]| {
            let plane = PlaneHandle::build(src, &config, &opts, native()).unwrap();
            let (id, _) = plane.program(src).unwrap();
            let mut out = Vec::new();
            for x in xs {
                out.push(
                    plane
                        .execute_batch(id, std::slice::from_ref(x))
                        .unwrap()
                        .solves
                        .remove(0)
                        .y,
                );
            }
            out
        };
        let ded_a = dedicated(&src_a, &xs_a);
        let ded_b = dedicated(&src_b, &xs_b);

        // One shared plane, batches interleaved A/B/A/B.
        let plane = PlaneHandle::build(&src_a, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src_a).unwrap();
        let (idb, _) = plane.program(&src_b).unwrap();
        assert_ne!(ida, idb);
        assert_eq!(plane.resident_operands(), 2);
        let mut shared_a = Vec::new();
        let mut shared_b = Vec::new();
        for k in 0..2 {
            shared_a.push(
                plane
                    .execute_batch(ida, std::slice::from_ref(&xs_a[k]))
                    .unwrap()
                    .solves
                    .remove(0)
                    .y,
            );
            shared_b.push(
                plane
                    .execute_batch(idb, std::slice::from_ref(&xs_b[k]))
                    .unwrap()
                    .solves
                    .remove(0)
                    .y,
            );
        }
        assert_eq!(ded_a, shared_a, "operand A diverged under multi-tenancy");
        assert_eq!(ded_b, shared_b, "operand B diverged under multi-tenancy");
    }

    #[test]
    fn evict_then_reprogram_reuses_tile_slots() {
        let src = dense(64, 64, 41);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (ida, pa) = plane.program(&src).unwrap();
        let high = plane.slot_high_water();
        assert_eq!(plane.slots_in_use(), pa.chunks_resident);
        plane.evict(ida).unwrap();
        assert_eq!(plane.slots_in_use(), 0);
        // Reprogramming an equally-shaped operand reuses the freed slots:
        // the high-water mark does not grow.
        let other = dense(64, 64, 42);
        let (idb, pb) = plane.program(&other).unwrap();
        assert_eq!(plane.slots_in_use(), pb.chunks_resident);
        assert_eq!(plane.slot_high_water(), high);
        let x = Vector::standard_normal(64, 43);
        assert!(plane.execute_batch(idb, std::slice::from_ref(&x)).is_ok());
    }

    #[test]
    fn evicting_an_operand_with_inflight_batch_is_operand_busy() {
        use crate::testing::faults::GateBackend;
        let src = dense(48, 48, 71);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_workers(2);
        let gated = GateBackend::new(NativeBackend::new());
        let gate = gated.handle();
        let plane = PlaneHandle::build(&src, &config, &opts, Arc::new(gated)).unwrap();
        // The gate starts open so programming (which also reads the
        // backend) completes; close it once the operand is resident.
        let (id, _) = plane.program(&src).unwrap();
        gate.close();
        let x = Vector::standard_normal(48, 72);
        std::thread::scope(|s| {
            let batch = s.spawn(|| plane.execute_batch(id, std::slice::from_ref(&x)));
            // Wait until the batch is demonstrably mid-flight: a shard
            // read is parked at the gate.
            while gate.waiting() == 0 {
                std::thread::yield_now();
            }
            let err = plane.evict(id).unwrap_err();
            assert!(
                matches!(err, PlaneError::OperandBusy { inflight: 1, .. }),
                "{err:?}"
            );
            assert!(err.to_string().contains("in-flight"), "{err}");
            gate.open();
            // The held batch completes normally once released …
            assert!(batch.join().unwrap().is_ok());
        });
        // … and a drained operand evicts cleanly.
        plane.evict(id).unwrap();
        assert_eq!(plane.resident_operands(), 0);
    }

    #[test]
    fn tile_slot_capacity_is_enforced() {
        let src = dense(64, 64, 45);
        // 2x2 grid of 32² cells: a 64² operand needs 1 slot per MCA; with
        // capacity 1 a second operand cannot fit until the first leaves.
        let config = SystemConfig::new(2, 2, 32).with_tile_slots(1);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src).unwrap();
        let err = plane.program(&dense(64, 64, 46)).unwrap_err();
        assert!(matches!(err, PlaneError::Capacity { .. }), "{err:?}");
        assert!(err.to_string().contains("out of tile slots"), "{err}");
        // The failed program was retired; the first residency still serves.
        let x = Vector::standard_normal(64, 47);
        assert!(plane.execute_batch(ida, std::slice::from_ref(&x)).is_ok());
        // Evicting frees the slots for the next tenant.
        plane.evict(ida).unwrap();
        assert!(plane.program(&dense(64, 64, 46)).is_ok());
    }

    #[test]
    fn operands_of_different_dims_share_one_plane() {
        let src_a = dense(64, 64, 51);
        let src_b = dense(40, 40, 52);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane = PlaneHandle::build(&src_a, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src_a).unwrap();
        let (idb, pb) = plane.program(&src_b).unwrap();
        assert_eq!((pb.m, pb.n), (40, 40));
        let xa = Vector::standard_normal(64, 53);
        let xb = Vector::standard_normal(40, 54);
        let ya = &plane
            .execute_batch(ida, std::slice::from_ref(&xa))
            .unwrap()
            .solves[0]
            .y;
        let ba = src_a.matvec(&xa);
        assert!(ya.sub(&ba).norm_l2() / ba.norm_l2() < 0.1);
        let yb = &plane
            .execute_batch(idb, std::slice::from_ref(&xb))
            .unwrap()
            .solves[0]
            .y;
        let bb = src_b.matvec(&xb);
        assert!(yb.sub(&bb).norm_l2() / bb.norm_l2() < 0.1);
        // Dimension checks are per-residency.
        assert!(matches!(
            plane.execute_batch(idb, std::slice::from_ref(&xa)),
            Err(PlaneError::InvalidInput(_))
        ));
    }

    #[test]
    fn execute_once_refuses_a_serving_plane() {
        let src = dense(32, 32, 55);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        plane.handle().program(&src).unwrap();
        let x = Vector::standard_normal(32, 56);
        assert!(matches!(
            plane.execute_once(&src, &x),
            Err(PlaneError::InvalidInput(_))
        ));
    }

    #[test]
    fn one_shot_adapts_to_operand_dims_but_rejects_bad_x() {
        // The pool is sized at build time but plans per call, so a
        // different-dims operand still solves one-shot; a vector that does
        // not match the operand is rejected.
        let src = dense(32, 32, 11);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let other = dense(16, 16, 12);
        let bad_x = Vector::standard_normal(32, 13);
        assert!(plane.execute_once(&other, &bad_x).is_err());
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let x = Vector::standard_normal(16, 13);
        let report = plane.execute_once(&other, &x).unwrap();
        assert_eq!(report.y.len(), 16);
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
    }

    #[test]
    fn sparse_operand_streams_occupied_chunks_only() {
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_placement(Placement::SparsityAware);
        let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 64);
        assert!(program.chunks_skipped > 30, "{}", program.chunks_skipped);
        assert_eq!(
            program.chunks_resident + program.chunks_skipped,
            program.chunks_total
        );
        let x = Vector::standard_normal(256, 9);
        let b = src.matvec(&x);
        let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
        let err = batch.solves[0].y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    /// A source whose every block is certainly zero: programs successfully
    /// with zero resident chunks and must still serve (all-zero) solves.
    struct ZeroSource(usize);

    impl MatrixSource for ZeroSource {
        fn nrows(&self) -> usize {
            self.0
        }

        fn ncols(&self) -> usize {
            self.0
        }

        fn block(&self, _r0: usize, _c0: usize, h: usize, w: usize) -> Matrix {
            Matrix::zeros(h, w)
        }

        fn matvec(&self, _x: &Vector) -> Vector {
            Vector::zeros(self.0)
        }

        fn block_is_zero(&self, _r0: usize, _c0: usize, _h: usize, _w: usize) -> bool {
            true
        }

        fn max_abs(&self) -> f64 {
            0.0
        }
    }

    #[test]
    fn all_zero_operand_programs_and_serves_zero_solves() {
        let src = ZeroSource(64);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_resident, 0);
        assert_eq!(program.chunks_skipped, program.chunks_total);
        let x = Vector::standard_normal(64, 40);
        let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
        assert_eq!(batch.solves.len(), 1);
        assert_eq!(batch.solves[0].y, Vector::zeros(64));
    }

    #[test]
    fn failed_batch_keeps_counters_and_ledgers_consistent() {
        use crate::testing::faults::FaultBackend;
        let src = dense(48, 48, 61);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(5)
            .with_workers(2);
        let xs0: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 70 + k)).collect();
        let xs1: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 80 + k)).collect();

        // Clean reference run: both batches succeed.
        let clean = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
        let (idc, _) = clean.program(&src).unwrap();
        let pre_clean = clean.operand_energy_totals(idc).unwrap();
        let _ = clean.execute_batch(idc, &xs0).unwrap();
        let mid_clean = clean.operand_energy_totals(idc).unwrap();
        let y_clean: Vec<Vector> = clean
            .execute_batch(idc, &xs1)
            .unwrap()
            .solves
            .into_iter()
            .map(|s| s.y)
            .collect();
        let post_clean = clean.operand_energy_totals(idc).unwrap();
        assert!(mid_clean.1 > pre_clean.1, "reads charge energy");

        // Faulty run: the first batch fails at the backend, the second
        // succeeds and must be bit-identical to the clean run's second
        // batch (same solve indices → same counter-based noise), with the
        // same energy delta across the successful batch.
        let flaky = FaultBackend::erroring(NativeBackend::new());
        let handle = flaky.handle();
        let faulty = PlaneHandle::build(&src, &config, &opts, Arc::new(flaky)).unwrap();
        let (idf, _) = faulty.program(&src).unwrap();
        handle.fail_next_reads(true);
        let err = faulty.execute_batch(idf, &xs0).unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        handle.fail_next_reads(false);
        let mid_faulty = faulty.operand_energy_totals(idf).unwrap();
        let y_faulty: Vec<Vector> = faulty
            .execute_batch(idf, &xs1)
            .unwrap()
            .solves
            .into_iter()
            .map(|s| s.y)
            .collect();
        let post_faulty = faulty.operand_energy_totals(idf).unwrap();

        assert_eq!(y_clean, y_faulty, "recovery batch diverged after a failed batch");
        // The recovery batch must charge exactly the energy the clean
        // run's second batch does.  Deltas are compared with a tight
        // relative tolerance: the *amounts* are identical, but the
        // running totals they are subtracted from differ (the failed
        // batch charged differently than a successful one), so the f64
        // subtraction can differ in the last ulps.
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-18;
        let delta_clean = (post_clean.0 - mid_clean.0, post_clean.1 - mid_clean.1);
        let delta_faulty = (post_faulty.0 - mid_faulty.0, post_faulty.1 - mid_faulty.1);
        assert!(
            close(delta_clean.0, delta_faulty.0) && close(delta_clean.1, delta_faulty.1),
            "energy accounting diverged: clean {delta_clean:?} vs faulty {delta_faulty:?}"
        );
    }

    #[test]
    fn batches_are_identical_across_placements_and_steal_orders() {
        // The steal order is timing-dependent and differs run to run; the
        // result must not.  Run the same programmed operand + batch under
        // every placement policy (timing-aware redistributes by measured
        // wall time, so its claim queues differ) and several worker
        // counts, and require bit-identical outputs.
        let src = BandedSource::new(192, 6, 1.0, 8.0, 0.3, 17);
        let config = SystemConfig::new(2, 2, 32);
        let xs: Vec<Vector> = (0..3).map(|k| Vector::standard_normal(192, 90 + k)).collect();
        let run = |workers: usize, placement: Placement| {
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_seed(123)
                .with_workers(workers)
                .with_placement(placement);
            let plane = PlaneHandle::build(&src, &config, &opts, native()).unwrap();
            let (id, _) = plane.program(&src).unwrap();
            // Two rounds so the timing-aware policy has measurements to
            // redistribute by in the second round.
            let first: Vec<Vector> = plane
                .execute_batch(id, &xs)
                .unwrap()
                .solves
                .into_iter()
                .map(|s| s.y)
                .collect();
            let second: Vec<Vector> = plane
                .execute_batch(id, &xs)
                .unwrap()
                .solves
                .into_iter()
                .map(|s| s.y)
                .collect();
            (first, second)
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 4] {
            for placement in ALL_PLACEMENTS {
                let r = run(workers, placement);
                assert_eq!(
                    reference, r,
                    "{workers} workers, {} diverged",
                    placement.name()
                );
            }
        }
    }

    #[test]
    fn reduce_partials_tail_rows_are_dropped() {
        // m = 40 with tile 32: block row 1 contributes rows 32..40 only;
        // its padded tail (entries 8..32) must not leak into y.
        let mut partials = BTreeMap::new();
        partials.insert((0usize, 0usize), Vector::from_vec(vec![1.0; 32]));
        let mut tail = vec![2.0; 32];
        for (i, t) in tail.iter_mut().enumerate().skip(8) {
            *t = 100.0 + i as f64; // padded garbage that must be dropped
        }
        partials.insert((1usize, 0usize), Vector::from_vec(tail));
        let y = reduce_partials(40, 32, &partials);
        assert_eq!(y.len(), 40);
        for i in 0..32 {
            assert_eq!(y.get(i), 1.0, "row {i}");
        }
        for i in 32..40 {
            assert_eq!(y.get(i), 2.0, "row {i}");
        }
    }
}
