//! The sharded execution plane: one scatter/gather implementation behind
//! both one-shot solves and resident serving sessions.
//!
//! Historically the one-shot coordinator and the serving layer each owned
//! a private copy of the same machinery (thread pool, chunk dispatch,
//! partial-product gather, ledger collection).  [`ExecutionPlane`] unifies
//! them:
//!
//! ```text
//!                        ┌────────────────────────────┐
//!   one-shot             │       ExecutionPlane       │        resident
//!   (coordinator)        │                            │        (server::Session)
//!                        │  PlacementPolicy: MCA→shard│
//!   execute_once(A, x) ──┤  shard 0 ── MCA {0, 3, …}  ├── program(A)
//!     program+execute    │  shard 1 ── MCA {1, 4, …}  │     write–verify once
//!     fused per chunk,   │  shard 2 ── MCA {2, 5, …}  │   execute_batch(xs)
//!     teardown after     │   (long-lived threads)     │     reads only, ∞ solves
//!                        └────────────────────────────┘
//! ```
//!
//! * The **leader** enumerates occupied chunks through
//!   [`ChunkPlan::nonzero_chunks`] — O(occupied blocks) for sources with a
//!   cheap column-range bound — and streams one extracted, zero-padded
//!   tile at a time over bounded channels (backpressure), so even a
//!   65,536² operand never materializes densely.
//! * Each **shard** is a long-lived worker thread owning the
//!   [`TileExecutor`](crate::ec::TileExecutor)s of the MCAs a
//!   [`PlacementPolicy`] assigned to it; per-shard programming runs in
//!   parallel across shards.
//! * The leader gathers partial products and reduces them in
//!   **deterministic chunk order** ([`reduce_partials`]), so results are
//!   bit-reproducible for a given seed regardless of shard count,
//!   placement policy or thread scheduling.

pub mod placement;
pub(crate) mod shard;

pub use placement::{
    LoadBalancedPlacement, Placement, PlacementPolicy, RoundRobinPlacement,
    SparsityAwarePlacement,
};
pub use shard::{exec_stream_seed, mca_seed, new_executor};

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::{Matrix, Vector};
use crate::matrices::MatrixSource;
use crate::mca::EnergyLedger;
use crate::metrics::SolveReport;
use crate::runtime::Backend;
use crate::virtualization::{ChunkPlan, ChunkSpec};
use shard::{ShardContext, ShardJob, ShardMsg};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Bound on in-flight jobs per shard (backpressure: caps leader-side tile
/// extraction memory at `depth × shards` tiles).
pub(crate) const JOB_QUEUE_DEPTH: usize = 4;

/// Reduce gathered per-chunk partial products into the output vector in
/// deterministic `(block_row, block_col)` order, so the sum is
/// bit-reproducible regardless of shard scheduling.  Rows past `m` (the
/// zero-padded tail of the last block row) are dropped.
pub fn reduce_partials(
    m: usize,
    tile: usize,
    partials: &BTreeMap<(usize, usize), Vector>,
) -> Vector {
    let mut y = Vector::zeros(m);
    for ((bi, _bj), part) in partials {
        let row0 = bi * tile;
        for (k, v) in part.data().iter().enumerate() {
            let idx = row0 + k;
            if idx < m {
                y.set(idx, y.get(idx) + v);
            }
        }
    }
    y
}

/// One-time programming cost and shape summary of a resident operand.
#[derive(Clone, Debug)]
pub struct ProgramReport {
    pub m: usize,
    pub n: usize,
    pub chunks_total: usize,
    /// Chunks actually written to the grid (non-zero blocks).
    pub chunks_resident: usize,
    pub chunks_skipped: usize,
    pub mcas_used: usize,
    pub normalization_factor: usize,
    pub mean_wv_iters: f64,
    /// Total write energy across MCAs — paid once for the residency.
    pub write_energy_j: f64,
    /// Max write latency across MCAs (wall-clock model: rows serial per
    /// MCA, MCAs parallel).
    pub write_latency_s: f64,
    pub wall_seconds: f64,
}

/// Result of one served solve.
#[derive(Clone, Debug)]
pub struct ServeSolve {
    pub y: Vector,
    /// Monotonic per-residency solve index (drives the noise counter).
    pub solve_index: u64,
    /// Wall-clock share of this vector (batch wall / batch size).
    pub wall_seconds: f64,
}

/// One executed batch: the per-vector results plus the whole batch's wall
/// clock (what serving statistics account against).
pub struct BatchOutcome {
    pub solves: Vec<ServeSolve>,
    pub wall_seconds: f64,
}

/// A sharded execution plane bound to one operand's [`ChunkPlan`].
///
/// Built by [`build`](ExecutionPlane::build), which spawns the shard pool
/// under the configured [`Placement`] policy.  Two execution modes share
/// it:
///
/// * [`execute_once`](ExecutionPlane::execute_once) — the one-shot path:
///   program + execute fused per chunk, full [`SolveReport`], plane
///   consumed (workers join on drop).
/// * [`program`](ExecutionPlane::program) then
///   [`execute_batch`](ExecutionPlane::execute_batch) — the resident path:
///   the write–verify pass is paid once, every batch afterwards costs only
///   input encodes and crossbar reads.
pub struct ExecutionPlane {
    opts: SolveOptions,
    plan: ChunkPlan,
    senders: Vec<mpsc::SyncSender<ShardJob>>,
    results: mpsc::Receiver<ShardMsg>,
    handles: Vec<JoinHandle<()>>,
    /// MCA index → shard index (stable for the plane's lifetime).
    assignment: Vec<usize>,
    /// Set once [`program`](Self::program) has started (even a failed
    /// pass may leave tiles resident on some shards, so a plane is never
    /// re-programmable).  Distinct from `resident_chunks`: an operand
    /// whose every block is zero programs successfully with zero resident
    /// chunks and still serves (all-zero) solves.
    programmed: bool,
    /// Set only when a programming pass completed successfully —
    /// [`execute_batch`](Self::execute_batch) refuses to serve from a
    /// partially programmed plane (missing chunks would silently drop
    /// their contribution to `y`).
    program_ok: bool,
    resident_chunks: usize,
    next_solve: u64,
    /// Latest cumulative ledger snapshot per MCA.
    ledgers: Vec<EnergyLedger>,
}

impl ExecutionPlane {
    /// Spawn the shard pool for `source`'s chunk plan.  `source` is only
    /// used for placement statistics here; tiles are extracted lazily by
    /// the execution calls.
    pub fn build(
        source: &dyn MatrixSource,
        config: &SystemConfig,
        opts: &SolveOptions,
        backend: Backend,
    ) -> Result<ExecutionPlane, String> {
        let (m, n) = (source.nrows(), source.ncols());
        let plan = ChunkPlan::new(config.geometry(), m, n);
        let tile = config.geometry().cell_size;
        if !backend.tile_sizes().contains(&tile) {
            return Err(format!(
                "cell size {tile} has no compiled artifact (available: {:?})",
                backend.tile_sizes()
            ));
        }
        let mcas = plan.geometry.mcas();
        let shards = opts.workers.max(1).min(mcas);
        let policy = opts.placement.policy();
        let assignment = policy.assign(&plan, source, shards);
        if assignment.len() != mcas || assignment.iter().any(|&s| s >= shards) {
            return Err(format!(
                "placement {} produced a malformed assignment ({} entries for {mcas} MCAs, \
                 {shards} shards)",
                policy.name(),
                assignment.len()
            ));
        }

        let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(JOB_QUEUE_DEPTH);
            senders.push(tx);
            let ctx = ShardContext {
                cell: tile,
                opts: opts.clone(),
                backend: backend.clone(),
                jobs: rx,
                out: msg_tx.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("meliso-shard-{s}"))
                    .spawn(move || shard::run(ctx))
                    .map_err(|e| format!("spawn shard {s}: {e}"))?,
            );
        }
        drop(msg_tx);

        Ok(ExecutionPlane {
            opts: opts.clone(),
            plan,
            senders,
            results: msg_rx,
            handles,
            assignment,
            programmed: false,
            program_ok: false,
            resident_chunks: 0,
            next_solve: 0,
            ledgers: vec![EnergyLedger::default(); mcas],
        })
    }

    pub fn plan(&self) -> &ChunkPlan {
        &self.plan
    }

    /// Number of shard worker threads.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// MCA index → shard index, as decided by the placement policy.
    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Chunks currently resident (0 before [`program`](Self::program)).
    pub fn resident_chunks(&self) -> usize {
        self.resident_chunks
    }

    /// Latest cumulative per-MCA ledger snapshots.
    pub fn ledgers(&self) -> &[EnergyLedger] {
        &self.ledgers
    }

    /// Total (write, read) energy across all MCAs so far.
    pub fn energy_totals(&self) -> (f64, f64) {
        (
            self.ledgers.iter().map(|l| l.write_energy_j).sum(),
            self.ledgers.iter().map(|l| l.read_energy_j).sum(),
        )
    }

    /// Stream the occupied chunks to the shards: enumerate through
    /// [`ChunkPlan::nonzero_chunks`], extract one zero-padded tile at a
    /// time, and dispatch to the owning shard.  Returns
    /// `(dispatched, skipped)`.
    fn scatter<F>(&self, source: &dyn MatrixSource, mut job: F) -> Result<(usize, usize), String>
    where
        F: FnMut(ChunkSpec, Matrix) -> ShardJob,
    {
        let tile = self.plan.geometry.cell_size;
        let mut dispatched = 0usize;
        for spec in self.plan.nonzero_chunks(source) {
            let a_tile = source.block(spec.row0, spec.col0, tile, tile);
            let s = self.assignment[spec.mca_index];
            self.senders[s]
                .send(job(spec, a_tile))
                .map_err(|_| format!("shard {s} died"))?;
            dispatched += 1;
        }
        // Close the walk so every shard snapshots its ledgers.
        for (s, tx) in self.senders.iter().enumerate() {
            tx.send(ShardJob::Seal)
                .map_err(|_| format!("shard {s} died at seal"))?;
        }
        Ok((dispatched, self.plan.total_chunks() - dispatched))
    }

    fn check_dims(&self, source: &dyn MatrixSource) -> Result<(), String> {
        if source.nrows() != self.plan.m || source.ncols() != self.plan.n {
            return Err(format!(
                "operand is {}x{} but the plane was built for {}x{}",
                source.nrows(),
                source.ncols(),
                self.plan.m,
                self.plan.n
            ));
        }
        Ok(())
    }

    /// Run one distributed MVM end-to-end (the one-shot path): program +
    /// execute fused per chunk, exact ground-truth comparison when
    /// `opts.ground_truth` is set, full [`SolveReport`].  Consumes the
    /// plane; the shard pool joins on drop.
    pub fn execute_once(
        mut self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, String> {
        if self.programmed {
            // The programming pass consumed the per-MCA persistent streams;
            // fusing another program+execute on top would break the
            // bit-reproducibility contract and double-charge write energy.
            return Err(
                "this plane already holds a resident operand; build a fresh plane for \
                 one-shot solves"
                    .to_string(),
            );
        }
        let start = Instant::now();
        self.check_dims(source)?;
        let (m, n) = (self.plan.m, self.plan.n);
        if x.len() != n {
            return Err(format!("x has length {} but A has {n} columns", x.len()));
        }
        let tile = self.plan.geometry.cell_size;
        let (dispatched, skipped) = self.scatter(source, |spec, a_tile| ShardJob::RunOnce {
            spec,
            a_tile,
            x_chunk: x.slice_padded(spec.col0, tile),
        })?;
        // One-shot: the walk is fully dispatched, so close the job
        // channels now.  A shard that panics then drops its reply sender
        // on exit, turning the gather below into a clean error instead of
        // a hang (parity with the pre-plane coordinator).
        let shards = self.senders.len();
        self.senders.clear();
        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        let mut wv_sum = 0.0f64;
        let mut got = 0usize;
        let mut sealed = 0usize;
        while got < dispatched || sealed < shards {
            match self.results.recv() {
                Ok(ShardMsg::Once {
                    block_row,
                    block_col,
                    outcome,
                }) => {
                    got += 1;
                    let (partial, iters) =
                        outcome.map_err(|e| format!("chunk ({block_row},{block_col}): {e}"))?;
                    wv_sum += iters as f64;
                    partials.insert((block_row, block_col), partial);
                }
                Ok(ShardMsg::Sealed { ledgers }) => {
                    sealed += 1;
                    for (idx, l) in ledgers {
                        self.ledgers[idx] = l;
                    }
                }
                Ok(_) => {}
                Err(_) => {
                    return Err("shards exited before delivering all results".to_string())
                }
            }
        }
        let y = reduce_partials(m, tile, &partials);

        // Ground truth (opt-out: O(m·n) host work, infeasible at 65k²).
        let mut report = SolveReport::empty(m);
        if self.opts.ground_truth {
            let b = source.matvec(x);
            report.rel_err_l2 = crate::metrics::rel_err_l2(&y, &b);
            report.rel_err_inf = crate::metrics::rel_err_inf(&y, &b);
        } else {
            report.rel_err_l2 = f64::NAN;
            report.rel_err_inf = f64::NAN;
        }
        report.y = y;
        report.chunks_total = self.plan.total_chunks();
        report.chunks_skipped = skipped;
        report.normalization_factor = self.plan.normalization_factor();
        report.row_reassignments = self.plan.row_reassignments();
        report.mean_wv_iters = if dispatched > 0 {
            wv_sum / dispatched as f64
        } else {
            0.0
        };
        report.fill_from_ledgers(&self.ledgers);
        report.wall_seconds = start.elapsed().as_secs_f64();
        crate::log_info!(
            "plane",
            "solve {}x{n}: {} chunks ({} skipped) on {} shards, eps_l2={:.4e}, wall={:.2}s",
            m,
            dispatched,
            skipped,
            shards,
            report.rel_err_l2,
            report.wall_seconds
        );
        Ok(report)
    }

    /// Program `source` resident: scatter and write–verify every non-zero
    /// chunk (per-shard programming runs in parallel) and return the
    /// one-time programming report.  Afterwards
    /// [`execute_batch`](Self::execute_batch) serves unlimited solves.
    pub fn program(&mut self, source: &dyn MatrixSource) -> Result<ProgramReport, String> {
        if self.programmed {
            return Err("an operand is already resident on this plane".to_string());
        }
        let start = Instant::now();
        self.check_dims(source)?;
        // Flag before dispatch: even a failed pass may leave some chunks
        // resident on shards, so a retry on the same plane must be
        // rejected (it would duplicate residency and desynchronize every
        // later gather).
        self.programmed = true;
        let (m, n) = (self.plan.m, self.plan.n);
        let (dispatched, skipped) =
            self.scatter(source, |spec, a_tile| ShardJob::Program { spec, a_tile })?;

        let shards = self.senders.len();
        let mut iters_sum = 0.0f64;
        let mut acks = 0usize;
        let mut sealed = 0usize;
        let mut first_err: Option<String> = None;
        while acks < dispatched || sealed < shards {
            match self.results.recv() {
                Ok(ShardMsg::Programmed {
                    block_row,
                    block_col,
                    outcome,
                }) => {
                    acks += 1;
                    match outcome {
                        Ok(iters) => iters_sum += iters as f64,
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(format!(
                                    "programming chunk ({block_row},{block_col}): {e}"
                                ));
                            }
                        }
                    }
                }
                Ok(ShardMsg::Sealed { ledgers }) => {
                    sealed += 1;
                    for (idx, l) in ledgers {
                        self.ledgers[idx] = l;
                    }
                }
                Ok(_) => {}
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("shards exited during programming".to_string());
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        self.resident_chunks = dispatched;
        self.program_ok = true;

        let used: Vec<&EnergyLedger> =
            self.ledgers.iter().filter(|l| l.write_passes > 0).collect();
        let write_energy_j: f64 = used.iter().map(|l| l.write_energy_j).sum();
        let write_latency_s = used.iter().map(|l| l.write_latency_s).fold(0.0, f64::max);
        let report = ProgramReport {
            m,
            n,
            chunks_total: self.plan.total_chunks(),
            chunks_resident: dispatched,
            chunks_skipped: skipped,
            mcas_used: used.len(),
            normalization_factor: self.plan.normalization_factor(),
            mean_wv_iters: if dispatched > 0 {
                iters_sum / dispatched as f64
            } else {
                0.0
            },
            write_energy_j,
            write_latency_s,
            wall_seconds: start.elapsed().as_secs_f64(),
        };
        crate::log_info!(
            "plane",
            "programmed {m}x{n}: {} resident chunks ({} skipped) on {} MCAs / {} shards, \
             E_w {:.3e} J, wall {:.2}s",
            dispatched,
            skipped,
            report.mcas_used,
            shards,
            write_energy_j,
            report.wall_seconds
        );
        Ok(report)
    }

    /// Serve a batch of solves against the resident operand in one chunk
    /// walk: every resident tile is visited once and all input vectors run
    /// against it.  Bit-identical to the same vectors solved sequentially
    /// (counter-based execution noise streams — see [`exec_stream_seed`]).
    pub fn execute_batch(&mut self, xs: &[Vector]) -> Result<BatchOutcome, String> {
        let n = self.plan.n;
        for (k, x) in xs.iter().enumerate() {
            if x.len() != n {
                return Err(format!(
                    "batch vector {k} has length {} but A has {n} columns",
                    x.len()
                ));
            }
        }
        if xs.is_empty() {
            return Ok(BatchOutcome {
                solves: Vec::new(),
                wall_seconds: 0.0,
            });
        }
        if !self.program_ok {
            return Err(if self.programmed {
                "programming failed on this plane; build a fresh plane".to_string()
            } else {
                "no operand resident on this plane (call program first)".to_string()
            });
        }
        let start = Instant::now();
        let first_solve = self.next_solve;
        self.next_solve += xs.len() as u64;
        let shared = Arc::new(xs.to_vec());
        for (s, tx) in self.senders.iter().enumerate() {
            tx.send(ShardJob::Execute {
                first_solve,
                xs: shared.clone(),
            })
            .map_err(|_| format!("shard {s} died"))?;
        }

        // Gather: one partial per (resident chunk, vector), then one
        // ledger snapshot per shard.  Drained fully even on error so the
        // ledgers stay synced and the next batch starts clean.
        let shards = self.senders.len();
        let expected = self.resident_chunks * xs.len();
        let mut per_solve: Vec<BTreeMap<(usize, usize), Vector>> =
            (0..xs.len()).map(|_| BTreeMap::new()).collect();
        let mut got = 0usize;
        let mut sealed = 0usize;
        let mut first_err: Option<String> = None;
        while got < expected || sealed < shards {
            match self.results.recv() {
                Ok(ShardMsg::Partial {
                    solve,
                    block_row,
                    block_col,
                    outcome,
                }) => {
                    got += 1;
                    match outcome {
                        Ok(v) => {
                            per_solve[(solve - first_solve) as usize]
                                .insert((block_row, block_col), v);
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(format!(
                                    "chunk ({block_row},{block_col}) solve {solve}: {e}"
                                ));
                            }
                        }
                    }
                }
                Ok(ShardMsg::Sealed { ledgers }) => {
                    sealed += 1;
                    for (idx, l) in ledgers {
                        self.ledgers[idx] = l;
                    }
                }
                Ok(_) => {}
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("shards exited mid-solve".to_string());
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let wall = start.elapsed().as_secs_f64();
        let m = self.plan.m;
        let tile = self.plan.geometry.cell_size;
        let solves = per_solve
            .into_iter()
            .enumerate()
            .map(|(k, partials)| ServeSolve {
                y: reduce_partials(m, tile, &partials),
                solve_index: first_solve + k as u64,
                wall_seconds: wall / xs.len() as f64,
            })
            .collect();
        Ok(BatchOutcome {
            solves,
            wall_seconds: wall,
        })
    }
}

impl Drop for ExecutionPlane {
    fn drop(&mut self) {
        // Closing the job channels ends the shard loops.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::runtime::native::NativeBackend;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    fn dense(m: usize, n: usize, seed: u64) -> DenseSource {
        DenseSource::new(Matrix::standard_normal(m, n, seed))
    }

    #[test]
    fn one_shot_bit_reproducible_across_shards_and_placements() {
        let src = dense(64, 64, 7);
        let x = Vector::standard_normal(64, 8);
        let config = SystemConfig::new(2, 2, 32);
        let run = |workers: usize, placement: Placement| {
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_seed(99)
                .with_workers(workers)
                .with_placement(placement);
            ExecutionPlane::build(&src, &config, &opts, native())
                .unwrap()
                .execute_once(&src, &x)
                .unwrap()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 4] {
            for placement in [
                Placement::RoundRobin,
                Placement::LoadBalanced,
                Placement::SparsityAware,
            ] {
                let r = run(workers, placement);
                assert_eq!(
                    reference.y, r.y,
                    "{workers} workers, {}",
                    placement.name()
                );
            }
        }
    }

    #[test]
    fn resident_plane_program_then_batch() {
        let src = dense(48, 48, 21);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let program = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 4);
        assert_eq!(program.chunks_resident, 4);
        let xs: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 30 + k)).collect();
        let batch = plane.execute_batch(&xs).unwrap();
        assert_eq!(batch.solves.len(), 2);
        for (k, s) in batch.solves.iter().enumerate() {
            assert_eq!(s.solve_index, k as u64);
            let b = src.matvec(&xs[k]);
            let err = s.y.sub(&b).norm_l2() / b.norm_l2();
            assert!(err < 0.1, "solve {k}: {err}");
        }
    }

    #[test]
    fn execute_before_program_is_error() {
        let src = dense(32, 32, 5);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let x = Vector::standard_normal(32, 6);
        let err = plane.execute_batch(std::slice::from_ref(&x)).unwrap_err();
        assert!(err.contains("no operand resident"), "{err}");
    }

    #[test]
    fn double_program_is_error() {
        let src = dense(32, 32, 9);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        plane.program(&src).unwrap();
        assert!(plane.program(&src).is_err());
    }

    #[test]
    fn plane_rejects_mismatched_operand() {
        let src = dense(32, 32, 11);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let other = dense(16, 16, 12);
        let x = Vector::standard_normal(16, 13);
        assert!(plane.execute_once(&other, &x).is_err());
    }

    #[test]
    fn sparse_operand_streams_occupied_chunks_only() {
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_placement(Placement::SparsityAware);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let program = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 64);
        assert!(program.chunks_skipped > 30, "{}", program.chunks_skipped);
        assert_eq!(
            program.chunks_resident + program.chunks_skipped,
            program.chunks_total
        );
        let x = Vector::standard_normal(256, 9);
        let b = src.matvec(&x);
        let batch = plane.execute_batch(std::slice::from_ref(&x)).unwrap();
        let err = batch.solves[0].y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    /// A source whose every block is certainly zero: programs successfully
    /// with zero resident chunks and must still serve (all-zero) solves.
    struct ZeroSource(usize);

    impl MatrixSource for ZeroSource {
        fn nrows(&self) -> usize {
            self.0
        }

        fn ncols(&self) -> usize {
            self.0
        }

        fn block(&self, _r0: usize, _c0: usize, h: usize, w: usize) -> Matrix {
            Matrix::zeros(h, w)
        }

        fn matvec(&self, _x: &Vector) -> Vector {
            Vector::zeros(self.0)
        }

        fn block_is_zero(&self, _r0: usize, _c0: usize, _h: usize, _w: usize) -> bool {
            true
        }

        fn max_abs(&self) -> f64 {
            0.0
        }
    }

    #[test]
    fn all_zero_operand_programs_and_serves_zero_solves() {
        let src = ZeroSource(64);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let program = plane.program(&src).unwrap();
        assert_eq!(program.chunks_resident, 0);
        assert_eq!(program.chunks_skipped, program.chunks_total);
        let x = Vector::standard_normal(64, 40);
        let batch = plane.execute_batch(std::slice::from_ref(&x)).unwrap();
        assert_eq!(batch.solves.len(), 1);
        assert_eq!(batch.solves[0].y, Vector::zeros(64));
    }

    #[test]
    fn reduce_partials_tail_rows_are_dropped() {
        // m = 40 with tile 32: block row 1 contributes rows 32..40 only;
        // its padded tail (entries 8..32) must not leak into y.
        let mut partials = BTreeMap::new();
        partials.insert((0usize, 0usize), Vector::from_vec(vec![1.0; 32]));
        let mut tail = vec![2.0; 32];
        for (i, t) in tail.iter_mut().enumerate().skip(8) {
            *t = 100.0 + i as f64; // padded garbage that must be dropped
        }
        partials.insert((1usize, 0usize), Vector::from_vec(tail));
        let y = reduce_partials(40, 32, &partials);
        assert_eq!(y.len(), 40);
        for i in 0..32 {
            assert_eq!(y.get(i), 1.0, "row {i}");
        }
        for i in 32..40 {
            assert_eq!(y.get(i), 2.0, "row {i}");
        }
    }
}
