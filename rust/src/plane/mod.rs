//! The sharded execution plane: one scatter/gather implementation behind
//! both one-shot solves and resident serving sessions.
//!
//! Historically the one-shot coordinator and the serving layer each owned
//! a private copy of the same machinery (thread pool, chunk dispatch,
//! partial-product gather, ledger collection).  [`ExecutionPlane`] unifies
//! them — and since the multi-tenant refactor it hosts *many* resident
//! operands on one shard pool:
//!
//! ```text
//!                        ┌────────────────────────────┐
//!   one-shot             │       ExecutionPlane       │        resident
//!   (coordinator)        │                            │        (server::Session)
//!                        │  PlacementPolicy: MCA→shard│
//!   execute_once(A, x) ──┤  shard 0 ── MCA {0, 3, …}  ├── program(A)   → op0
//!     program+execute    │  shard 1 ── MCA {1, 4, …}  │   program(B)   → op1
//!     fused per chunk,   │  shard 2 ── MCA {2, 5, …}  │   execute_batch(op0, xs)
//!     teardown after     │   (long-lived threads)     │   execute_batch(op1, xs)
//!                        └────────────────────────────┘   evict(op0)
//! ```
//!
//! * The **leader** enumerates occupied chunks through
//!   [`ChunkPlan::nonzero_chunks`] — O(occupied blocks) for sources with a
//!   cheap column-range bound — and streams one extracted, zero-padded
//!   tile at a time over bounded channels (backpressure), so even a
//!   65,536² operand never materializes densely.
//! * Each **shard** is a long-lived worker thread owning, per resident
//!   operand, the [`TileExecutor`](crate::ec::TileExecutor)s of the MCAs a
//!   [`PlacementPolicy`] assigned to it.  Each operand gets a *fresh*
//!   executor set seeded exactly like a dedicated plane would be, so
//!   multi-tenant residency is **bit-identical** to one plane per operand.
//! * A [`TileAllocator`] tracks which tile slots of which MCA hold which
//!   operand's chunks: eviction frees slots for reuse, and an optional
//!   per-MCA capacity (`SystemConfig::tile_slots`) makes over-subscription
//!   a clean error.
//! * The leader gathers partial products and reduces them in
//!   **deterministic chunk order** ([`reduce_partials`]), so results are
//!   bit-reproducible for a given seed regardless of shard count,
//!   placement policy or thread scheduling.
//!
//! **Fault tolerance.**  Shard jobs run under `catch_unwind` (a panicking
//! shard seals its ledgers into a `ShardMsg::Failed` report and
//! exits), leader-side tile extraction is unwind-caught too, and every
//! gather is a *supervised* receive: per-shard seal tracking plus a
//! liveness check against the worker [`JoinHandle`]s.  A shard panic
//! mid-walk therefore surfaces as a clean `Err` from `program` /
//! `execute_batch` / `execute_once` — never a hang — and the plane marks
//! itself failed so later calls fail fast instead of desynchronizing.
//!
//! Embedders usually reach the plane through
//! [`Meliso`](crate::solver::Meliso) (`build_plane` / `open_session_on`),
//! but it is a public runtime of its own:
//!
//! ```
//! use meliso::plane::ExecutionPlane;
//! use meliso::prelude::*;
//! use meliso::runtime::native::NativeBackend;
//! use std::sync::Arc;
//!
//! let src = meliso::matrices::registry::build("iperturb66").unwrap();
//! let cfg = SystemConfig::single_mca(128);
//! let opts = SolveOptions::default().with_workers(2);
//! let plane =
//!     ExecutionPlane::build(src.as_ref(), &cfg, &opts, Arc::new(NativeBackend::new())).unwrap();
//! let x = Vector::standard_normal(src.ncols(), 1);
//! let report = plane.execute_once(src.as_ref(), &x).unwrap(); // consumes the plane
//! assert_eq!(report.y.len(), 66);
//! ```

pub mod alloc;
pub mod placement;
pub(crate) mod shard;

pub use self::alloc::{OperandId, TileAllocator};
pub use placement::{
    LoadBalancedPlacement, Placement, PlacementPolicy, RoundRobinPlacement,
    SparsityAwarePlacement,
};
pub use shard::{exec_stream_seed, mca_seed, new_executor};

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::{Matrix, Vector};
use crate::matrices::MatrixSource;
use crate::mca::EnergyLedger;
use crate::metrics::SolveReport;
use crate::obs::{self, Lane, Stage};
use crate::runtime::Backend;
use crate::virtualization::{ChunkPlan, ChunkSpec};
use shard::{ShardContext, ShardJob, ShardMsg};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Bound on in-flight jobs per shard (backpressure: caps leader-side tile
/// extraction memory at `depth × shards` tiles).
pub(crate) const JOB_QUEUE_DEPTH: usize = 4;

/// Supervision interval of the gather loops: how often a blocked receive
/// wakes up to check shard liveness.
const SUPERVISE_INTERVAL: Duration = Duration::from_millis(200);

/// Reduce gathered per-chunk partial products into the output vector in
/// deterministic `(block_row, block_col)` order, so the sum is
/// bit-reproducible regardless of shard scheduling.  Rows past `m` (the
/// zero-padded tail of the last block row) are dropped.
pub fn reduce_partials(
    m: usize,
    tile: usize,
    partials: &BTreeMap<(usize, usize), Vector>,
) -> Vector {
    let mut y = Vector::zeros(m);
    for ((bi, _bj), part) in partials {
        let row0 = bi * tile;
        for (k, v) in part.data().iter().enumerate() {
            let idx = row0 + k;
            if idx < m {
                y.set(idx, y.get(idx) + v);
            }
        }
    }
    y
}

/// One-time programming cost and shape summary of a resident operand.
#[derive(Clone, Debug)]
pub struct ProgramReport {
    pub m: usize,
    pub n: usize,
    pub chunks_total: usize,
    /// Chunks actually written to the grid (non-zero blocks).
    pub chunks_resident: usize,
    pub chunks_skipped: usize,
    pub mcas_used: usize,
    pub normalization_factor: usize,
    pub mean_wv_iters: f64,
    /// Total write energy across MCAs — paid once for the residency.
    pub write_energy_j: f64,
    /// Max write latency across MCAs (wall-clock model: rows serial per
    /// MCA, MCAs parallel).
    pub write_latency_s: f64,
    pub wall_seconds: f64,
}

/// Result of one served solve.
#[derive(Clone, Debug)]
pub struct ServeSolve {
    pub y: Vector,
    /// Monotonic per-residency solve index (drives the noise counter).
    pub solve_index: u64,
    /// Wall-clock share of this vector (batch wall / batch size).
    pub wall_seconds: f64,
}

/// One executed batch: the per-vector results plus the whole batch's wall
/// clock (what serving statistics account against).
pub struct BatchOutcome {
    pub solves: Vec<ServeSolve>,
    pub wall_seconds: f64,
}

/// One operand's leader-side residency bookkeeping.
struct Residency {
    plan: ChunkPlan,
    chunks_resident: usize,
    /// Monotonic solve counter (drives the counter-based noise streams);
    /// advances even for failed batches so retries never reuse noise.
    next_solve: u64,
    /// This operand's cumulative per-MCA ledger slice.
    ledgers: Vec<EnergyLedger>,
    /// `(mca, slot)` pairs held in the tile allocator.
    slots: Vec<(usize, usize)>,
}

impl Residency {
    fn energy_totals(&self) -> (f64, f64) {
        (
            self.ledgers.iter().map(|l| l.write_energy_j).sum(),
            self.ledgers.iter().map(|l| l.read_energy_j).sum(),
        )
    }
}

/// Outcome of one supervised gather: chunk-level errors are recoverable
/// (the plane stays serviceable), fatal errors (a shard panicked or
/// exited mid-walk) poison the plane.
struct WalkOutcome {
    chunk_err: Option<String>,
    fatal: Option<String>,
}

/// Mutable bookkeeping of one supervised gather.
struct GatherState {
    done: Vec<bool>,
    pending: usize,
    chunk_err: Option<String>,
    fatal: Option<String>,
}

/// Route one shard reply: seals and failures update the per-shard done
/// tracking; everything else goes to the walk-specific `on_msg` handler.
fn dispatch_msg<F: FnMut(ShardMsg) -> Option<String>>(
    st: &mut GatherState,
    on_msg: &mut F,
    msg: ShardMsg,
) {
    match msg {
        ShardMsg::Sealed { shard, ledgers } => {
            if let Some(d) = st.done.get_mut(shard) {
                if !*d {
                    *d = true;
                    st.pending -= 1;
                }
            }
            if let Some(e) = on_msg(ShardMsg::Sealed { shard, ledgers }) {
                st.chunk_err.get_or_insert(e);
            }
        }
        ShardMsg::Failed {
            shard,
            error,
            ledgers,
        } => {
            if let Some(d) = st.done.get_mut(shard) {
                if !*d {
                    *d = true;
                    st.pending -= 1;
                }
            }
            // Deliver the dying shard's final ledgers so energy totals
            // stay as synced as they can be.
            let _ = on_msg(ShardMsg::Sealed { shard, ledgers });
            st.fatal
                .get_or_insert(format!("shard {shard} panicked: {error}"));
        }
        msg => {
            if let Some(e) = on_msg(msg) {
                st.chunk_err.get_or_insert(e);
            }
        }
    }
}

/// Supervised gather: drain one walk's replies until every shard has
/// sealed, with a periodic liveness check against the worker handles so a
/// shard that dies without sealing (panic, abort) surfaces as an error
/// instead of blocking the receive forever.
///
/// `on_msg` handles the walk-specific messages (`Once` / `Programmed` /
/// `Partial`) and stores `Sealed` ledgers; it returns a chunk-level error
/// to record (first one wins).
fn drain_walk(
    results: &mpsc::Receiver<ShardMsg>,
    handles: &[JoinHandle<()>],
    shards: usize,
    mut on_msg: impl FnMut(ShardMsg) -> Option<String>,
) -> WalkOutcome {
    let mut st = GatherState {
        done: vec![false; shards],
        pending: shards,
        chunk_err: None,
        fatal: None,
    };
    while st.pending > 0 {
        match results.recv_timeout(SUPERVISE_INTERVAL) {
            Ok(msg) => dispatch_msg(&mut st, &mut on_msg, msg),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Liveness sweep, race-free against a shard sealing right
                // at the deadline: snapshot liveness FIRST, then drain the
                // queue.  A shard sends its seal strictly before exiting,
                // so if the snapshot saw it finished, its seal (if any)
                // is consumed by the drain below before the verdict.
                let finished: Vec<bool> = (0..shards)
                    .map(|s| handles.get(s).map(|h| h.is_finished()).unwrap_or(true))
                    .collect();
                while let Ok(msg) = results.try_recv() {
                    dispatch_msg(&mut st, &mut on_msg, msg);
                }
                for (s, &gone) in finished.iter().enumerate() {
                    if gone && !st.done[s] {
                        st.done[s] = true;
                        st.pending -= 1;
                        st.fatal
                            .get_or_insert(format!("shard {s} exited without sealing its walk"));
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                st.fatal
                    .get_or_insert("all shards exited before completing the walk".to_string());
                break;
            }
        }
    }
    WalkOutcome {
        chunk_err: st.chunk_err,
        fatal: st.fatal,
    }
}

/// Close a leader-side `Plan` span (shared by the one-shot, program and
/// batch paths; a no-op `None` when tracing is off).
fn note_plan(span: Option<obs::SpanTimer>, path: &'static str, chunks: usize, m: usize, n: usize) {
    if let Some(sp) = span {
        sp.finish(
            Stage::Plan,
            Lane::Leader,
            vec![
                ("path", path.to_string()),
                ("m", m.to_string()),
                ("n", n.to_string()),
                ("chunks", chunks.to_string()),
            ],
        );
    }
}

/// Account one supervised gather: fold the blocked-wait seconds into the
/// leader's gather-wait counter and close the `Gather` span.  Both handles
/// are `None` when the corresponding level is off.
fn note_gather(clock: Option<Instant>, span: Option<obs::SpanTimer>, path: &'static str) {
    if let Some(t0) = clock {
        obs::global()
            .counter(
                obs::names::PLANE_GATHER_WAIT,
                "Seconds the leader spent in supervised gathers",
                &[],
            )
            .add(t0.elapsed().as_secs_f64());
    }
    if let Some(sp) = span {
        sp.finish(Stage::Gather, Lane::Leader, vec![("path", path.to_string())]);
    }
}

/// A sharded execution plane hosting any number of resident operands.
///
/// Built by [`build`](ExecutionPlane::build), which spawns the shard pool
/// under the configured [`Placement`] policy.  Two execution modes share
/// it:
///
/// * [`execute_once`](ExecutionPlane::execute_once) — the one-shot path:
///   program + execute fused per chunk, full [`SolveReport`], plane
///   consumed (workers join on drop).
/// * [`program`](ExecutionPlane::program) then
///   [`execute_batch`](ExecutionPlane::execute_batch) — the resident path:
///   the write–verify pass is paid once per operand, every batch
///   afterwards costs only input encodes and crossbar reads.  Many
///   operands share the pool concurrently; [`evict`](ExecutionPlane::evict)
///   releases one residency's tile slots for reuse.
pub struct ExecutionPlane {
    config: SystemConfig,
    opts: SolveOptions,
    senders: Vec<mpsc::SyncSender<ShardJob>>,
    results: mpsc::Receiver<ShardMsg>,
    handles: Vec<JoinHandle<()>>,
    /// MCA index → shard index (stable for the plane's lifetime).
    assignment: Vec<usize>,
    /// Live residencies by operand id.
    residencies: BTreeMap<u64, Residency>,
    alloc: TileAllocator,
    next_operand: u64,
    /// Ledger snapshots of the fused one-shot path.
    oneshot_ledgers: Vec<EnergyLedger>,
    /// `(write, read)` energy of evicted residencies, so plane-wide totals
    /// stay monotone across evictions.
    retired_energy: (f64, f64),
    /// Set when a shard died (panic or unexpected exit): the pool can no
    /// longer complete gathers consistently, so every later call fails
    /// fast with this message instead of desynchronizing.
    failed: Option<String>,
}

impl ExecutionPlane {
    /// Spawn the shard pool sized for `source`'s chunk plan.  `source` is
    /// only used for placement statistics and geometry validation here;
    /// tiles are extracted lazily by the execution calls, and operands of
    /// *other* dimensions may be programmed later — the pool is shared.
    pub fn build(
        source: &dyn MatrixSource,
        config: &SystemConfig,
        opts: &SolveOptions,
        backend: Backend,
    ) -> Result<ExecutionPlane, String> {
        let (m, n) = (source.nrows(), source.ncols());
        let plan = ChunkPlan::new(config.geometry(), m, n);
        let tile = config.geometry().cell_size;
        if !backend.tile_sizes().contains(&tile) {
            return Err(format!(
                "cell size {tile} has no compiled artifact (available: {:?})",
                backend.tile_sizes()
            ));
        }
        let mcas = plan.geometry.mcas();
        let shards = opts.workers.max(1).min(mcas);
        let policy = opts.placement.policy();
        let assignment = policy.assign(&plan, source, shards);
        if assignment.len() != mcas || assignment.iter().any(|&s| s >= shards) {
            return Err(format!(
                "placement {} produced a malformed assignment ({} entries for {mcas} MCAs, \
                 {shards} shards)",
                policy.name(),
                assignment.len()
            ));
        }

        let (msg_tx, msg_rx) = mpsc::channel::<ShardMsg>();
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<ShardJob>(JOB_QUEUE_DEPTH);
            senders.push(tx);
            let ctx = ShardContext {
                shard: s,
                cell: tile,
                opts: opts.clone(),
                backend: backend.clone(),
                jobs: rx,
                out: msg_tx.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("meliso-shard-{s}"))
                    .spawn(move || shard::run(ctx))
                    .map_err(|e| format!("spawn shard {s}: {e}"))?,
            );
        }
        drop(msg_tx);

        Ok(ExecutionPlane {
            config: *config,
            opts: opts.clone(),
            senders,
            results: msg_rx,
            handles,
            assignment,
            residencies: BTreeMap::new(),
            alloc: TileAllocator::new(mcas, config.tile_slots),
            next_operand: 0,
            oneshot_ledgers: vec![EnergyLedger::default(); mcas],
            retired_energy: (0.0, 0.0),
            failed: None,
        })
    }

    /// Number of shard worker threads.
    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    /// MCA index → shard index, as decided by the placement policy.
    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// The physical system configuration the pool was built for.
    pub fn system_config(&self) -> SystemConfig {
        self.config
    }

    /// The solve options every residency on this plane shares.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Operands currently resident.
    pub fn resident_operands(&self) -> usize {
        self.residencies.len()
    }

    /// Chunks currently resident across all operands.
    pub fn resident_chunks(&self) -> usize {
        self.residencies.values().map(|r| r.chunks_resident).sum()
    }

    /// Tile slots currently held across all MCAs.
    pub fn slots_in_use(&self) -> usize {
        self.alloc.in_use()
    }

    /// Highest tile-slot count any MCA has ever needed (eviction makes
    /// slots reusable, so reprogramming does not grow this).
    pub fn slot_high_water(&self) -> usize {
        self.alloc.high_water()
    }

    /// The failure that poisoned this plane, if any (a shard panicked or
    /// exited mid-walk).
    pub fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// Total (write, read) energy across the plane so far: one-shot
    /// executors, live residencies, and evicted (retired) residencies.
    pub fn energy_totals(&self) -> (f64, f64) {
        let mut w: f64 = self.oneshot_ledgers.iter().map(|l| l.write_energy_j).sum();
        let mut r: f64 = self.oneshot_ledgers.iter().map(|l| l.read_energy_j).sum();
        w += self.retired_energy.0;
        r += self.retired_energy.1;
        for res in self.residencies.values() {
            let (rw, rr) = res.energy_totals();
            w += rw;
            r += rr;
        }
        (w, r)
    }

    /// (write, read) energy attributable to one resident operand, or
    /// `None` when `id` is not resident.
    pub fn operand_energy_totals(&self, id: OperandId) -> Option<(f64, f64)> {
        self.residencies.get(&id.0).map(|r| r.energy_totals())
    }

    /// Publish the plane's residency gauges to the global registry (the
    /// allocator publishes the slot-occupancy gauges itself).
    fn publish_occupancy(&self) {
        if !obs::metrics_on() {
            return;
        }
        let g = obs::global();
        g.gauge(
            obs::names::PLANE_RESIDENT_OPERANDS,
            "Operands currently resident on the plane",
            &[],
        )
        .set(self.residencies.len() as f64);
        g.gauge(
            obs::names::PLANE_RESIDENT_CHUNKS,
            "Chunks currently resident on the plane",
            &[],
        )
        .set(self.resident_chunks() as f64);
    }

    fn ensure_live(&self) -> Result<(), String> {
        match &self.failed {
            Some(e) => Err(format!("execution plane failed: {e}")),
            None => Ok(()),
        }
    }

    /// Run one distributed MVM end-to-end (the one-shot path): program +
    /// execute fused per chunk, exact ground-truth comparison when
    /// `opts.ground_truth` is set, full [`SolveReport`].  Consumes the
    /// plane; the shard pool joins on drop.
    pub fn execute_once(
        mut self,
        source: &dyn MatrixSource,
        x: &Vector,
    ) -> Result<SolveReport, String> {
        self.ensure_live()?;
        if !self.residencies.is_empty() {
            // The one-shot path consumes the plane, tearing down every
            // residency with it; fusing it onto a serving plane is always
            // a caller bug.
            return Err(
                "this plane holds resident operands; build a fresh plane for one-shot solves"
                    .to_string(),
            );
        }
        let start = Instant::now();
        let plan_span = obs::span_start();
        let plan = ChunkPlan::new(self.config.geometry(), source.nrows(), source.ncols());
        let (m, n) = (plan.m, plan.n);
        note_plan(plan_span, "one-shot", plan.total_chunks(), m, n);
        if x.len() != n {
            return Err(format!("x has length {} but A has {n} columns", x.len()));
        }
        let tile = plan.geometry.cell_size;
        let (dispatched, walk_err) = scatter_walk(
            &self.senders,
            &self.assignment,
            &plan,
            source,
            None,
            |spec, a_tile| {
                Ok(ShardJob::RunOnce {
                    spec,
                    a_tile,
                    x_chunk: x.slice_padded(spec.col0, tile),
                })
            },
        );
        // One-shot: fully dispatched, so close the job channels now; the
        // workers drain, seal, and exit.
        let shards = self.senders.len();
        self.senders.clear();

        let mut partials: BTreeMap<(usize, usize), Vector> = BTreeMap::new();
        let mut wv_sum = 0.0f64;
        let mut got = 0usize;
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = {
            let results = &self.results;
            let handles = &self.handles;
            let ledgers = &mut self.oneshot_ledgers;
            drain_walk(results, handles, shards, |msg| match msg {
                ShardMsg::Once {
                    block_row,
                    block_col,
                    outcome,
                } => {
                    got += 1;
                    match outcome {
                        Ok((partial, iters)) => {
                            wv_sum += iters as f64;
                            partials.insert((block_row, block_col), partial);
                            None
                        }
                        Err(e) => Some(format!("chunk ({block_row},{block_col}): {e}")),
                    }
                }
                ShardMsg::Sealed { ledgers: ls, .. } => {
                    for (idx, l) in ls {
                        if let Some(slot) = ledgers.get_mut(idx) {
                            *slot = l;
                        }
                    }
                    None
                }
                _ => None,
            })
        };
        note_gather(gather_clock, gather_span, "one-shot");
        if let Some(fatal) = outcome.fatal {
            self.failed = Some(fatal.clone());
            return Err(fatal);
        }
        if let Some(e) = walk_err.or(outcome.chunk_err) {
            return Err(e);
        }
        if got < dispatched {
            return Err("shards exited before delivering all results".to_string());
        }
        let skipped = plan.total_chunks() - dispatched;
        let reduce_span = obs::span_start();
        let y = reduce_partials(m, tile, &partials);
        if let Some(sp) = reduce_span {
            sp.finish(
                Stage::Reduce,
                Lane::Leader,
                vec![("chunks", partials.len().to_string())],
            );
        }

        // Ground truth (opt-out: O(m·n) host work, infeasible at 65k²).
        let mut report = SolveReport::empty(m);
        if self.opts.ground_truth {
            let b = source.matvec(x);
            report.rel_err_l2 = crate::metrics::rel_err_l2(&y, &b);
            report.rel_err_inf = crate::metrics::rel_err_inf(&y, &b);
        } else {
            report.rel_err_l2 = f64::NAN;
            report.rel_err_inf = f64::NAN;
        }
        report.y = y;
        report.chunks_total = plan.total_chunks();
        report.chunks_skipped = skipped;
        report.normalization_factor = plan.normalization_factor();
        report.row_reassignments = plan.row_reassignments();
        report.mean_wv_iters = if dispatched > 0 {
            wv_sum / dispatched as f64
        } else {
            0.0
        };
        report.fill_from_ledgers(&self.oneshot_ledgers);
        report.wall_seconds = start.elapsed().as_secs_f64();
        crate::log_info!(
            "plane",
            "solve {}x{n}: {} chunks ({} skipped) on {} shards, eps_l2={:.4e}, wall={:.2}s",
            m,
            dispatched,
            skipped,
            shards,
            report.rel_err_l2,
            report.wall_seconds
        );
        Ok(report)
    }

    /// Program `source` resident: scatter and write–verify every non-zero
    /// chunk (per-shard programming runs in parallel) and return the
    /// operand's handle with its one-time programming report.  Afterwards
    /// [`execute_batch`](Self::execute_batch) serves unlimited solves
    /// against it, interleaved freely with other residencies.
    ///
    /// On failure the partial residency is evicted (tile slots and
    /// shard-side state reclaimed), so the plane stays serviceable and a
    /// retry programs a fresh, bit-reproducible residency.
    pub fn program(
        &mut self,
        source: &dyn MatrixSource,
    ) -> Result<(OperandId, ProgramReport), String> {
        self.ensure_live()?;
        let start = Instant::now();
        let plan_span = obs::span_start();
        let plan = ChunkPlan::new(self.config.geometry(), source.nrows(), source.ncols());
        let (m, n) = (plan.m, plan.n);
        note_plan(plan_span, "program", plan.total_chunks(), m, n);
        let op = self.next_operand;
        self.next_operand += 1;
        let id = OperandId(op);
        let mcas = plan.geometry.mcas();

        let mut slots: Vec<(usize, usize)> = Vec::new();
        let (dispatched, walk_err) = {
            let alloc = &mut self.alloc;
            let slots = &mut slots;
            scatter_walk(
                &self.senders,
                &self.assignment,
                &plan,
                source,
                Some(op),
                |spec, a_tile| {
                    let slot = alloc.alloc(spec.mca_index)?;
                    slots.push((spec.mca_index, slot));
                    Ok(ShardJob::Program { op, spec, a_tile })
                },
            )
        };

        let shards = self.senders.len();
        let mut res = Residency {
            plan: plan.clone(),
            chunks_resident: dispatched,
            next_solve: 0,
            ledgers: vec![EnergyLedger::default(); mcas],
            slots,
        };
        let mut iters_sum = 0.0f64;
        let mut acks = 0usize;
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = {
            let results = &self.results;
            let handles = &self.handles;
            let ledgers = &mut res.ledgers;
            drain_walk(results, handles, shards, |msg| match msg {
                ShardMsg::Programmed {
                    block_row,
                    block_col,
                    outcome,
                } => {
                    acks += 1;
                    match outcome {
                        Ok(iters) => {
                            iters_sum += iters as f64;
                            None
                        }
                        Err(e) => {
                            Some(format!("programming chunk ({block_row},{block_col}): {e}"))
                        }
                    }
                }
                ShardMsg::Sealed { ledgers: ls, .. } => {
                    for (idx, l) in ls {
                        if let Some(slot) = ledgers.get_mut(idx) {
                            *slot = l;
                        }
                    }
                    None
                }
                _ => None,
            })
        };
        note_gather(gather_clock, gather_span, "program");
        if let Some(fatal) = outcome.fatal {
            self.failed = Some(fatal.clone());
            self.retire(op, res);
            return Err(fatal);
        }
        let mut err = walk_err.or(outcome.chunk_err);
        if err.is_none() && acks < dispatched {
            err = Some("shards exited before acknowledging every chunk".to_string());
        }
        if let Some(e) = err {
            // Reclaim the partial residency so the plane stays clean.
            self.retire(op, res);
            return Err(e);
        }

        let used: Vec<&EnergyLedger> = res.ledgers.iter().filter(|l| l.write_passes > 0).collect();
        let write_energy_j: f64 = used.iter().map(|l| l.write_energy_j).sum();
        let write_latency_s = used.iter().map(|l| l.write_latency_s).fold(0.0, f64::max);
        let report = ProgramReport {
            m,
            n,
            chunks_total: plan.total_chunks(),
            chunks_resident: dispatched,
            chunks_skipped: plan.total_chunks() - dispatched,
            mcas_used: used.len(),
            normalization_factor: plan.normalization_factor(),
            mean_wv_iters: if dispatched > 0 {
                iters_sum / dispatched as f64
            } else {
                0.0
            },
            write_energy_j,
            write_latency_s,
            wall_seconds: start.elapsed().as_secs_f64(),
        };
        self.residencies.insert(op, res);
        self.publish_occupancy();
        crate::log_info!(
            "plane",
            "programmed {id} ({m}x{n}): {} resident chunks ({} skipped) on {} MCAs / {} \
             shards, E_w {:.3e} J, wall {:.2}s ({} operands resident)",
            report.chunks_resident,
            report.chunks_skipped,
            report.mcas_used,
            shards,
            write_energy_j,
            report.wall_seconds,
            self.residencies.len()
        );
        Ok((id, report))
    }

    /// Serve a batch of solves against resident operand `id` in one chunk
    /// walk: every resident tile is visited once and all input vectors run
    /// against it.  Bit-identical to the same vectors solved sequentially,
    /// and to the same operand served from a dedicated plane (counter-based
    /// execution noise streams — see [`exec_stream_seed`]).
    ///
    /// A failed batch (chunk-level shard error) leaves the residency
    /// consistent: ledgers are fully synced and the solve counter has
    /// advanced past the failed batch, so a subsequent batch draws exactly
    /// the noise it would have in an error-free run.
    pub fn execute_batch(&mut self, id: OperandId, xs: &[Vector]) -> Result<BatchOutcome, String> {
        self.ensure_live()?;
        let res = self.residencies.get(&id.0).ok_or_else(|| {
            format!("operand {id} is not resident on this plane (never programmed, or evicted)")
        })?;
        let n = res.plan.n;
        for (k, x) in xs.iter().enumerate() {
            if x.len() != n {
                return Err(format!(
                    "batch vector {k} has length {} but A has {n} columns",
                    x.len()
                ));
            }
        }
        if xs.is_empty() {
            return Ok(BatchOutcome {
                solves: Vec::new(),
                wall_seconds: 0.0,
            });
        }
        let start = Instant::now();
        let plan_span = obs::span_start();
        let (m, tile, first_solve) = {
            let res = self.residencies.get_mut(&id.0).expect("checked above");
            let first = res.next_solve;
            res.next_solve += xs.len() as u64;
            (res.plan.m, res.plan.geometry.cell_size, first)
        };
        let shared = Arc::new(xs.to_vec());
        // Best-effort broadcast: a dead shard (its receiver dropped after a
        // panic) is skipped — its Failed report is already on the results
        // channel — while every live shard still gets the job, so the
        // supervised drain below terminates.
        let mut dead: Option<usize> = None;
        for (s, tx) in self.senders.iter().enumerate() {
            let job = ShardJob::Execute {
                op: id.0,
                first_solve,
                xs: shared.clone(),
            };
            if tx.send(job).is_err() && dead.is_none() {
                dead = Some(s);
            }
        }
        if let Some(sp) = plan_span {
            sp.finish(
                Stage::Plan,
                Lane::Leader,
                vec![
                    ("path", "batch".to_string()),
                    ("operand", id.0.to_string()),
                    ("batch", xs.len().to_string()),
                ],
            );
        }
        // A dead shard implies a panic already reported (or about to be)
        // on the results channel; drain the walk so the Failed message is
        // consumed, then fail the plane.
        if let Some(s) = dead {
            let shards = self.senders.len();
            let outcome = drain_walk(&self.results, &self.handles, shards, |_| None);
            let fatal = outcome
                .fatal
                .unwrap_or_else(|| format!("shard {s} died mid-batch"));
            self.failed = Some(fatal.clone());
            return Err(fatal);
        }

        // Gather: partials per (resident chunk, vector), then one ledger
        // snapshot per shard.  Drained fully even on error so the ledgers
        // stay synced and the next batch starts clean.
        let shards = self.senders.len();
        let mut per_solve: Vec<BTreeMap<(usize, usize), Vector>> =
            (0..xs.len()).map(|_| BTreeMap::new()).collect();
        let gather_span = obs::span_start();
        let gather_clock = obs::metrics_clock();
        let outcome = {
            let results = &self.results;
            let handles = &self.handles;
            let res = self.residencies.get_mut(&id.0).expect("checked above");
            let ledgers = &mut res.ledgers;
            drain_walk(results, handles, shards, |msg| match msg {
                ShardMsg::Partial {
                    solve,
                    block_row,
                    block_col,
                    outcome,
                } => match outcome {
                    Ok(v) => {
                        let k = solve.wrapping_sub(first_solve) as usize;
                        match per_solve.get_mut(k) {
                            Some(slot) => {
                                slot.insert((block_row, block_col), v);
                                None
                            }
                            None => Some(format!(
                                "chunk ({block_row},{block_col}): stray partial for solve \
                                 {solve} (batch starts at {first_solve})"
                            )),
                        }
                    }
                    Err(e) => {
                        Some(format!("chunk ({block_row},{block_col}) solve {solve}: {e}"))
                    }
                },
                ShardMsg::Sealed { ledgers: ls, .. } => {
                    for (idx, l) in ls {
                        if let Some(slot) = ledgers.get_mut(idx) {
                            *slot = l;
                        }
                    }
                    None
                }
                _ => None,
            })
        };
        note_gather(gather_clock, gather_span, "batch");
        if let Some(fatal) = outcome.fatal {
            self.failed = Some(fatal.clone());
            return Err(fatal);
        }
        if let Some(e) = outcome.chunk_err {
            return Err(e);
        }
        let wall = start.elapsed().as_secs_f64();
        let reduce_span = obs::span_start();
        let solves: Vec<ServeSolve> = per_solve
            .into_iter()
            .enumerate()
            .map(|(k, partials)| ServeSolve {
                y: reduce_partials(m, tile, &partials),
                solve_index: first_solve + k as u64,
                wall_seconds: wall / xs.len() as f64,
            })
            .collect();
        if let Some(sp) = reduce_span {
            sp.finish(
                Stage::Reduce,
                Lane::Leader,
                vec![
                    ("operand", id.0.to_string()),
                    ("batch", xs.len().to_string()),
                ],
            );
        }
        Ok(BatchOutcome {
            solves,
            wall_seconds: wall,
        })
    }

    /// Evict resident operand `id`: drop its tiles and executors on every
    /// shard, fold its energy into the plane's retired totals, and return
    /// its tile slots to the allocator for reuse.  The id becomes stale —
    /// later calls with it are clean errors.
    ///
    /// Eviction works on a *failed* plane too (the shard walk is skipped;
    /// leader-side bookkeeping is still reclaimed) and returns `Ok` — the
    /// pool failure stays observable through [`failure`](Self::failure).
    /// `Err` here means only one thing: `id` was not resident.
    pub fn evict(&mut self, id: OperandId) -> Result<(), String> {
        let res = self.residencies.remove(&id.0).ok_or_else(|| {
            format!("operand {id} is not resident on this plane (already evicted?)")
        })?;
        self.retire(id.0, res);
        Ok(())
    }

    /// Drop operand `op`'s shard-side state (when the pool is still live),
    /// free its tile slots, and fold its final energy into the retired
    /// totals.  Used by [`evict`](Self::evict) and by failed-programming
    /// cleanup.
    fn retire(&mut self, op: u64, mut res: Residency) {
        if self.failed.is_none() {
            // Best-effort broadcast (see execute_batch): skip dead shards
            // so the drain below still terminates.
            let mut dead: Option<usize> = None;
            for (s, tx) in self.senders.iter().enumerate() {
                if tx.send(ShardJob::Evict { op }).is_err() && dead.is_none() {
                    dead = Some(s);
                }
            }
            let shards = self.senders.len();
            let outcome = {
                let results = &self.results;
                let handles = &self.handles;
                let ledgers = &mut res.ledgers;
                drain_walk(results, handles, shards, |msg| {
                    if let ShardMsg::Sealed { ledgers: ls, .. } = msg {
                        for (idx, l) in ls {
                            if let Some(slot) = ledgers.get_mut(idx) {
                                *slot = l;
                            }
                        }
                    }
                    None
                })
            };
            if let Some(fatal) = outcome.fatal {
                self.failed = Some(fatal);
            } else if let Some(s) = dead {
                self.failed = Some(format!("shard {s} died during evict"));
            }
        }
        for (mca, slot) in &res.slots {
            self.alloc.free(*mca, *slot);
        }
        let (w, r) = res.energy_totals();
        self.retired_energy.0 += w;
        self.retired_energy.1 += r;
        if obs::metrics_on() {
            obs::global()
                .counter(
                    obs::names::PLANE_EVICTIONS,
                    "Operand evictions/retirements from the plane",
                    &[],
                )
                .inc();
        }
        self.publish_occupancy();
    }
}

/// Stream the occupied chunks of `plan` to the shards: enumerate through
/// [`ChunkPlan::nonzero_chunks`], extract one zero-padded tile at a time
/// (unwind-caught), build the job via `make_job` (which may refuse — e.g.
/// tile-slot exhaustion), and dispatch to the owning shard.  Returns
/// `(dispatched, walk_err)`.
///
/// The walk is **always closed**: every shard gets a best-effort
/// `Seal { op: seal_op }` even after an error, so the matching supervised
/// gather terminates on a partial walk (a dead shard already reported a
/// `Failed` before its channel dropped).
fn scatter_walk<F>(
    senders: &[mpsc::SyncSender<ShardJob>],
    assignment: &[usize],
    plan: &ChunkPlan,
    source: &dyn MatrixSource,
    seal_op: Option<u64>,
    mut make_job: F,
) -> (usize, Option<String>)
where
    F: FnMut(ChunkSpec, Matrix) -> Result<ShardJob, String>,
{
    let tile = plan.geometry.cell_size;
    let mut dispatched = 0usize;
    let mut walk_err: Option<String> = None;
    let extract_metrics = if obs::metrics_on() {
        let g = obs::global();
        Some((
            g.counter(
                obs::names::PLANE_TILES_EXTRACTED,
                "Tiles extracted and dispatched by the leader",
                &[],
            ),
            g.counter(
                obs::names::PLANE_EXTRACT_SECONDS,
                "Seconds the leader spent extracting and dispatching tiles",
                &[],
            ),
        ))
    } else {
        None
    };
    {
        let mut iter = plan.nonzero_chunks(source);
        loop {
            let spec = match next_chunk(&mut iter) {
                Ok(Some(spec)) => spec,
                Ok(None) => break,
                Err(e) => {
                    walk_err = Some(e);
                    break;
                }
            };
            let span = obs::span_start();
            let t0 = extract_metrics.as_ref().map(|_| Instant::now());
            let a_tile = match extract_tile(source, &spec, tile) {
                Ok(t) => t,
                Err(e) => {
                    walk_err = Some(e);
                    break;
                }
            };
            let job = match make_job(spec, a_tile) {
                Ok(job) => job,
                Err(e) => {
                    walk_err = Some(e);
                    break;
                }
            };
            let s = assignment[spec.mca_index];
            if senders[s].send(job).is_err() {
                walk_err = Some(format!("shard {s} died mid-walk"));
                break;
            }
            dispatched += 1;
            if let (Some((tiles, secs)), Some(t0)) = (&extract_metrics, t0) {
                tiles.inc();
                secs.add(t0.elapsed().as_secs_f64());
            }
            if let Some(sp) = span {
                sp.finish(
                    Stage::Extract,
                    Lane::Leader,
                    vec![
                        ("chunk", format!("({},{})", spec.block_row, spec.block_col)),
                        ("mca", spec.mca_index.to_string()),
                    ],
                );
            }
        }
    }
    for tx in senders {
        let _ = tx.send(ShardJob::Seal { op: seal_op });
    }
    (dispatched, walk_err)
}

/// Advance the chunk walk one step, converting a panic inside the
/// source's sparsity probes into an error.
fn next_chunk(iter: &mut dyn Iterator<Item = ChunkSpec>) -> Result<Option<ChunkSpec>, String> {
    catch_unwind(AssertUnwindSafe(|| iter.next()))
        .map_err(|p| format!("operand chunk walk panicked: {}", shard::panic_text(p)))
}

/// Extract one zero-padded tile, converting a panic inside the source's
/// `block` into an error.
fn extract_tile(
    source: &dyn MatrixSource,
    spec: &ChunkSpec,
    tile: usize,
) -> Result<Matrix, String> {
    catch_unwind(AssertUnwindSafe(|| {
        source.block(spec.row0, spec.col0, tile, tile)
    }))
    .map_err(|p| {
        format!(
            "extracting chunk ({},{}) panicked: {}",
            spec.block_row,
            spec.block_col,
            shard::panic_text(p)
        )
    })
}

impl Drop for ExecutionPlane {
    fn drop(&mut self) {
        // Closing the job channels ends the shard loops.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::runtime::native::NativeBackend;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    fn dense(m: usize, n: usize, seed: u64) -> DenseSource {
        DenseSource::new(Matrix::standard_normal(m, n, seed))
    }

    #[test]
    fn one_shot_bit_reproducible_across_shards_and_placements() {
        let src = dense(64, 64, 7);
        let x = Vector::standard_normal(64, 8);
        let config = SystemConfig::new(2, 2, 32);
        let run = |workers: usize, placement: Placement| {
            let opts = SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_seed(99)
                .with_workers(workers)
                .with_placement(placement);
            ExecutionPlane::build(&src, &config, &opts, native())
                .unwrap()
                .execute_once(&src, &x)
                .unwrap()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 4] {
            for placement in [
                Placement::RoundRobin,
                Placement::LoadBalanced,
                Placement::SparsityAware,
            ] {
                let r = run(workers, placement);
                assert_eq!(
                    reference.y, r.y,
                    "{workers} workers, {}",
                    placement.name()
                );
            }
        }
    }

    #[test]
    fn resident_plane_program_then_batch() {
        let src = dense(48, 48, 21);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 4);
        assert_eq!(program.chunks_resident, 4);
        assert_eq!(plane.resident_operands(), 1);
        assert_eq!(plane.slots_in_use(), 4);
        let xs: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 30 + k)).collect();
        let batch = plane.execute_batch(id, &xs).unwrap();
        assert_eq!(batch.solves.len(), 2);
        for (k, s) in batch.solves.iter().enumerate() {
            assert_eq!(s.solve_index, k as u64);
            let b = src.matvec(&xs[k]);
            let err = s.y.sub(&b).norm_l2() / b.norm_l2();
            assert!(err < 0.1, "solve {k}: {err}");
        }
    }

    #[test]
    fn execute_with_unknown_operand_is_error() {
        let src = dense(32, 32, 5);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let x = Vector::standard_normal(32, 6);
        let err = plane
            .execute_batch(OperandId(0), std::slice::from_ref(&x))
            .unwrap_err();
        assert!(err.contains("not resident"), "{err}");
    }

    #[test]
    fn evicted_operand_id_is_stale() {
        let src = dense(32, 32, 9);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let (id, _) = plane.program(&src).unwrap();
        plane.evict(id).unwrap();
        assert_eq!(plane.resident_operands(), 0);
        assert_eq!(plane.slots_in_use(), 0);
        let x = Vector::standard_normal(32, 10);
        let err = plane
            .execute_batch(id, std::slice::from_ref(&x))
            .unwrap_err();
        assert!(err.contains("not resident"), "{err}");
        assert!(plane.evict(id).is_err());
    }

    #[test]
    fn two_operands_interleave_bit_identical_to_dedicated_planes() {
        let src_a = dense(48, 48, 31);
        let src_b = dense(48, 48, 32);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(77)
            .with_workers(3);
        let xs_a: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 40 + k)).collect();
        let xs_b: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 50 + k)).collect();

        // Dedicated planes, one operand each (the historical layout).
        let dedicated = |src: &DenseSource, xs: &[Vector]| {
            let mut plane = ExecutionPlane::build(src, &config, &opts, native()).unwrap();
            let (id, _) = plane.program(src).unwrap();
            let mut out = Vec::new();
            for x in xs {
                out.push(
                    plane
                        .execute_batch(id, std::slice::from_ref(x))
                        .unwrap()
                        .solves
                        .remove(0)
                        .y,
                );
            }
            out
        };
        let ded_a = dedicated(&src_a, &xs_a);
        let ded_b = dedicated(&src_b, &xs_b);

        // One shared plane, batches interleaved A/B/A/B.
        let mut plane = ExecutionPlane::build(&src_a, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src_a).unwrap();
        let (idb, _) = plane.program(&src_b).unwrap();
        assert_ne!(ida, idb);
        assert_eq!(plane.resident_operands(), 2);
        let mut shared_a = Vec::new();
        let mut shared_b = Vec::new();
        for k in 0..2 {
            shared_a.push(
                plane
                    .execute_batch(ida, std::slice::from_ref(&xs_a[k]))
                    .unwrap()
                    .solves
                    .remove(0)
                    .y,
            );
            shared_b.push(
                plane
                    .execute_batch(idb, std::slice::from_ref(&xs_b[k]))
                    .unwrap()
                    .solves
                    .remove(0)
                    .y,
            );
        }
        assert_eq!(ded_a, shared_a, "operand A diverged under multi-tenancy");
        assert_eq!(ded_b, shared_b, "operand B diverged under multi-tenancy");
    }

    #[test]
    fn evict_then_reprogram_reuses_tile_slots() {
        let src = dense(64, 64, 41);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (ida, pa) = plane.program(&src).unwrap();
        let high = plane.slot_high_water();
        assert_eq!(plane.slots_in_use(), pa.chunks_resident);
        plane.evict(ida).unwrap();
        assert_eq!(plane.slots_in_use(), 0);
        // Reprogramming an equally-shaped operand reuses the freed slots:
        // the high-water mark does not grow.
        let other = dense(64, 64, 42);
        let (idb, pb) = plane.program(&other).unwrap();
        assert_eq!(plane.slots_in_use(), pb.chunks_resident);
        assert_eq!(plane.slot_high_water(), high);
        let x = Vector::standard_normal(64, 43);
        assert!(plane.execute_batch(idb, std::slice::from_ref(&x)).is_ok());
    }

    #[test]
    fn tile_slot_capacity_is_enforced() {
        let src = dense(64, 64, 45);
        // 2x2 grid of 32² cells: a 64² operand needs 1 slot per MCA; with
        // capacity 1 a second operand cannot fit until the first leaves.
        let config = SystemConfig::new(2, 2, 32).with_tile_slots(1);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src).unwrap();
        let err = plane.program(&dense(64, 64, 46)).unwrap_err();
        assert!(err.contains("out of tile slots"), "{err}");
        // The failed program was retired; the first residency still serves.
        let x = Vector::standard_normal(64, 47);
        assert!(plane.execute_batch(ida, std::slice::from_ref(&x)).is_ok());
        // Evicting frees the slots for the next tenant.
        plane.evict(ida).unwrap();
        assert!(plane.program(&dense(64, 64, 46)).is_ok());
    }

    #[test]
    fn operands_of_different_dims_share_one_plane() {
        let src_a = dense(64, 64, 51);
        let src_b = dense(40, 40, 52);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src_a, &config, &opts, native()).unwrap();
        let (ida, _) = plane.program(&src_a).unwrap();
        let (idb, pb) = plane.program(&src_b).unwrap();
        assert_eq!((pb.m, pb.n), (40, 40));
        let xa = Vector::standard_normal(64, 53);
        let xb = Vector::standard_normal(40, 54);
        let ya = &plane
            .execute_batch(ida, std::slice::from_ref(&xa))
            .unwrap()
            .solves[0]
            .y;
        let ba = src_a.matvec(&xa);
        assert!(ya.sub(&ba).norm_l2() / ba.norm_l2() < 0.1);
        let yb = &plane
            .execute_batch(idb, std::slice::from_ref(&xb))
            .unwrap()
            .solves[0]
            .y;
        let bb = src_b.matvec(&xb);
        assert!(yb.sub(&bb).norm_l2() / bb.norm_l2() < 0.1);
        // Dimension checks are per-residency.
        assert!(plane
            .execute_batch(idb, std::slice::from_ref(&xa))
            .is_err());
    }

    #[test]
    fn execute_once_refuses_a_serving_plane() {
        let src = dense(32, 32, 55);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        plane.program(&src).unwrap();
        let x = Vector::standard_normal(32, 56);
        assert!(plane.execute_once(&src, &x).is_err());
    }

    #[test]
    fn one_shot_adapts_to_operand_dims_but_rejects_bad_x() {
        // The pool is sized at build time but plans per call, so a
        // different-dims operand still solves one-shot; a vector that does
        // not match the operand is rejected.
        let src = dense(32, 32, 11);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let other = dense(16, 16, 12);
        let bad_x = Vector::standard_normal(32, 13);
        assert!(plane.execute_once(&other, &bad_x).is_err());
        let plane =
            ExecutionPlane::build(&src, &SystemConfig::single_mca(32), &opts, native()).unwrap();
        let x = Vector::standard_normal(16, 13);
        let report = plane.execute_once(&other, &x).unwrap();
        assert_eq!(report.y.len(), 16);
        assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);
    }

    #[test]
    fn sparse_operand_streams_occupied_chunks_only() {
        let src = BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_placement(Placement::SparsityAware);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_total, 64);
        assert!(program.chunks_skipped > 30, "{}", program.chunks_skipped);
        assert_eq!(
            program.chunks_resident + program.chunks_skipped,
            program.chunks_total
        );
        let x = Vector::standard_normal(256, 9);
        let b = src.matvec(&x);
        let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
        let err = batch.solves[0].y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    /// A source whose every block is certainly zero: programs successfully
    /// with zero resident chunks and must still serve (all-zero) solves.
    struct ZeroSource(usize);

    impl MatrixSource for ZeroSource {
        fn nrows(&self) -> usize {
            self.0
        }

        fn ncols(&self) -> usize {
            self.0
        }

        fn block(&self, _r0: usize, _c0: usize, h: usize, w: usize) -> Matrix {
            Matrix::zeros(h, w)
        }

        fn matvec(&self, _x: &Vector) -> Vector {
            Vector::zeros(self.0)
        }

        fn block_is_zero(&self, _r0: usize, _c0: usize, _h: usize, _w: usize) -> bool {
            true
        }

        fn max_abs(&self) -> f64 {
            0.0
        }
    }

    #[test]
    fn all_zero_operand_programs_and_serves_zero_solves() {
        let src = ZeroSource(64);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default().with_device(Material::EpiRam);
        let mut plane = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (id, program) = plane.program(&src).unwrap();
        assert_eq!(program.chunks_resident, 0);
        assert_eq!(program.chunks_skipped, program.chunks_total);
        let x = Vector::standard_normal(64, 40);
        let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
        assert_eq!(batch.solves.len(), 1);
        assert_eq!(batch.solves[0].y, Vector::zeros(64));
    }

    #[test]
    fn failed_batch_keeps_counters_and_ledgers_consistent() {
        use crate::testing::faults::FaultBackend;
        let src = dense(48, 48, 61);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(5)
            .with_workers(2);
        let xs0: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 70 + k)).collect();
        let xs1: Vec<Vector> = (0..2).map(|k| Vector::standard_normal(48, 80 + k)).collect();

        // Clean reference run: both batches succeed.
        let mut clean = ExecutionPlane::build(&src, &config, &opts, native()).unwrap();
        let (idc, _) = clean.program(&src).unwrap();
        let pre_clean = clean.operand_energy_totals(idc).unwrap();
        let _ = clean.execute_batch(idc, &xs0).unwrap();
        let mid_clean = clean.operand_energy_totals(idc).unwrap();
        let y_clean: Vec<Vector> = clean
            .execute_batch(idc, &xs1)
            .unwrap()
            .solves
            .into_iter()
            .map(|s| s.y)
            .collect();
        let post_clean = clean.operand_energy_totals(idc).unwrap();
        assert!(mid_clean.1 > pre_clean.1, "reads charge energy");

        // Faulty run: the first batch fails at the backend, the second
        // succeeds and must be bit-identical to the clean run's second
        // batch (same solve indices → same counter-based noise), with the
        // same energy delta across the successful batch.
        let flaky = FaultBackend::erroring(NativeBackend::new());
        let handle = flaky.handle();
        let mut faulty =
            ExecutionPlane::build(&src, &config, &opts, Arc::new(flaky)).unwrap();
        let (idf, _) = faulty.program(&src).unwrap();
        handle.fail_next_reads(true);
        let err = faulty.execute_batch(idf, &xs0).unwrap_err();
        assert!(err.contains("injected"), "{err}");
        handle.fail_next_reads(false);
        let mid_faulty = faulty.operand_energy_totals(idf).unwrap();
        let y_faulty: Vec<Vector> = faulty
            .execute_batch(idf, &xs1)
            .unwrap()
            .solves
            .into_iter()
            .map(|s| s.y)
            .collect();
        let post_faulty = faulty.operand_energy_totals(idf).unwrap();

        assert_eq!(y_clean, y_faulty, "recovery batch diverged after a failed batch");
        // The recovery batch must charge exactly the energy the clean
        // run's second batch does.  Deltas are compared with a tight
        // relative tolerance: the *amounts* are identical, but the
        // running totals they are subtracted from differ (the failed
        // batch charged differently than a successful one), so the f64
        // subtraction can differ in the last ulps.
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-18;
        let delta_clean = (post_clean.0 - mid_clean.0, post_clean.1 - mid_clean.1);
        let delta_faulty = (post_faulty.0 - mid_faulty.0, post_faulty.1 - mid_faulty.1);
        assert!(
            close(delta_clean.0, delta_faulty.0) && close(delta_clean.1, delta_faulty.1),
            "energy accounting diverged: clean {delta_clean:?} vs faulty {delta_faulty:?}"
        );
    }

    #[test]
    fn reduce_partials_tail_rows_are_dropped() {
        // m = 40 with tile 32: block row 1 contributes rows 32..40 only;
        // its padded tail (entries 8..32) must not leak into y.
        let mut partials = BTreeMap::new();
        partials.insert((0usize, 0usize), Vector::from_vec(vec![1.0; 32]));
        let mut tail = vec![2.0; 32];
        for (i, t) in tail.iter_mut().enumerate().skip(8) {
            *t = 100.0 + i as f64; // padded garbage that must be dropped
        }
        partials.insert((1usize, 0usize), Vector::from_vec(tail));
        let y = reduce_partials(40, 32, &partials);
        assert_eq!(y.len(), 40);
        for i in 0..32 {
            assert_eq!(y.get(i), 1.0, "row {i}");
        }
        for i in 32..40 {
            assert_eq!(y.get(i), 2.0, "row {i}");
        }
    }
}
