//! Typed errors of the execution plane.
//!
//! Every public entry point of [`crate::plane`] returns [`PlaneError`]
//! instead of a bare `String`, so embedders can match on *why* a call
//! failed (stale operand vs. capacity vs. a dead shard) instead of
//! grepping messages.  [`std::fmt::Display`] renders the same operator
//!-facing text the string-based API produced, and `From<PlaneError> for
//! String` keeps `?` working in string-typed callers (the CLI).

use super::alloc::OperandId;
use std::fmt;

/// Why a plane call failed.
#[derive(Debug, Clone, PartialEq)]
pub enum PlaneError {
    /// The requested cell size has no compiled kernel artifact.
    UnsupportedCell { cell: usize, available: Vec<usize> },
    /// The shard pool could not be built (thread spawn, malformed
    /// placement assignment).
    Build(String),
    /// A caller-side validation failed (dimension mismatch, one-shot on a
    /// serving plane, …).  The plane is untouched.
    InvalidInput(String),
    /// The [`OperandId`] is not resident on this plane: it was never
    /// programmed here, or it has been evicted.
    StaleOperand { id: OperandId },
    /// The operand still has in-flight batches; evicting now would race
    /// the executing shards for the tile slots.  Drain (or drop the other
    /// callers) and retry.
    OperandBusy { id: OperandId, inflight: usize },
    /// An MCA ran out of tile slots while programming.
    Capacity { mca: usize, slots: usize },
    /// A chunk-level failure (backend error, extraction panic).  The
    /// plane stays serviceable; the failed walk's effects are rolled back
    /// (program) or accounted (batch).
    Chunk(String),
    /// A shard worker panicked or exited mid-walk.  The plane is
    /// poisoned: every later call fails fast with [`PlaneError::Failed`].
    ShardDead(String),
    /// A supervised gather exceeded its deadline while the shards were
    /// still alive (see `MELISO_WALK_TIMEOUT_SECS`).  The plane is
    /// poisoned — the walk's replies can no longer be trusted complete.
    Timeout(String),
    /// The plane was poisoned by an earlier fatal error; this call
    /// failed fast without touching the shards.
    Failed(String),
}

impl fmt::Display for PlaneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlaneError::UnsupportedCell { cell, available } => write!(
                f,
                "cell size {cell} has no compiled artifact (available: {available:?})"
            ),
            PlaneError::Build(e) => write!(f, "{e}"),
            PlaneError::InvalidInput(e) => write!(f, "{e}"),
            PlaneError::StaleOperand { id } => write!(
                f,
                "operand {id} is not resident on this plane (never programmed, or evicted)"
            ),
            PlaneError::OperandBusy { id, inflight } => write!(
                f,
                "operand {id} has {inflight} in-flight batch(es); drain them before evicting"
            ),
            PlaneError::Capacity { mca, slots } => write!(
                f,
                "MCA {mca} is out of tile slots ({slots} per MCA, all in use); evict an \
                 operand or raise system.tile_slots"
            ),
            PlaneError::Chunk(e) => write!(f, "{e}"),
            PlaneError::ShardDead(e) => write!(f, "{e}"),
            PlaneError::Timeout(e) => write!(f, "{e}"),
            PlaneError::Failed(e) => write!(f, "execution plane failed: {e}"),
        }
    }
}

impl std::error::Error for PlaneError {}

impl From<PlaneError> for String {
    fn from(e: PlaneError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_preserves_operator_facing_text() {
        let e = PlaneError::StaleOperand { id: OperandId(3) };
        assert!(e.to_string().contains("not resident"), "{e}");
        let e = PlaneError::Capacity { mca: 1, slots: 2 };
        assert!(e.to_string().contains("out of tile slots"), "{e}");
        let e = PlaneError::UnsupportedCell {
            cell: 48,
            available: vec![32, 64],
        };
        assert!(e.to_string().contains("cell size 48"), "{e}");
        let e = PlaneError::Failed("shard 0 panicked: boom".into());
        let s = e.to_string();
        assert!(s.contains("failed") && s.contains("panicked"), "{s}");
        let e = PlaneError::OperandBusy {
            id: OperandId(1),
            inflight: 2,
        };
        assert!(e.to_string().contains("in-flight"), "{e}");
    }

    #[test]
    fn converts_into_string_for_legacy_callers() {
        let s: String = PlaneError::Timeout("walk timed out after 600s".into()).into();
        assert!(s.contains("timed out"), "{s}");
    }

    #[test]
    fn is_a_std_error() {
        let e: Box<dyn std::error::Error> = Box::new(PlaneError::Build("spawn failed".into()));
        assert_eq!(e.to_string(), "spawn failed");
    }
}
