//! Tile-level residency allocator: which physical tile slots of which MCA
//! hold which operand's chunks.
//!
//! An MCA is reassigned (time-multiplexed) across many chunks — the
//! paper's Fig 5 normalization factor — and, since the plane became
//! multi-tenant, across many *operands* too.  The allocator tracks one
//! **slot** per resident chunk on its owning MCA:
//!
//! * allocation is deterministic (lowest freed slot first, then the next
//!   never-used index), so evict-then-reprogram reuses the same physical
//!   slots instead of growing the footprint;
//! * an optional per-MCA capacity (`SystemConfig::tile_slots`, `0` =
//!   unbounded) turns over-subscription into a clean error instead of
//!   silent unbounded residency.

use super::error::PlaneError;
use crate::obs;
use std::collections::BTreeSet;

/// Handle to one operand resident on a
/// [`PlaneHandle`](crate::plane::PlaneHandle), returned by
/// [`program`](crate::plane::PlaneHandle::program) and consumed by
/// [`execute_batch`](crate::plane::PlaneHandle::execute_batch) /
/// [`evict`](crate::plane::PlaneHandle::evict).  Ids are never reused
/// within a plane's lifetime, so a stale handle (evicted operand) is a
/// clean error rather than an aliased residency.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct OperandId(pub(crate) u64);

impl std::fmt::Display for OperandId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "op{}", self.0)
    }
}

/// Per-MCA tile-slot bookkeeping for one plane.
pub struct TileAllocator {
    /// Per-MCA slot capacity; `0` = unbounded.
    capacity: usize,
    /// Per-MCA next never-used slot index (the high-water mark).
    next_fresh: Vec<usize>,
    /// Per-MCA freed slots, reallocated lowest-first.
    free: Vec<BTreeSet<usize>>,
    in_use: usize,
}

impl TileAllocator {
    pub fn new(mcas: usize, capacity: usize) -> TileAllocator {
        TileAllocator {
            capacity,
            next_fresh: vec![0; mcas],
            free: vec![BTreeSet::new(); mcas],
            in_use: 0,
        }
    }

    /// Per-MCA slot capacity (`0` = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Claim one tile slot on `mca`: the lowest freed slot if any, else the
    /// next never-used index (capacity permitting).
    pub fn alloc(&mut self, mca: usize) -> Result<usize, PlaneError> {
        if let Some(&slot) = self.free[mca].iter().next() {
            self.free[mca].remove(&slot);
            self.in_use += 1;
            self.publish();
            return Ok(slot);
        }
        let fresh = self.next_fresh[mca];
        if self.capacity > 0 && fresh >= self.capacity {
            return Err(PlaneError::Capacity {
                mca,
                slots: self.capacity,
            });
        }
        self.next_fresh[mca] = fresh + 1;
        self.in_use += 1;
        self.publish();
        Ok(fresh)
    }

    /// Return a slot to `mca`'s free list.
    pub fn free(&mut self, mca: usize, slot: usize) {
        debug_assert!(slot < self.next_fresh[mca], "freeing a never-allocated slot");
        if self.free[mca].insert(slot) {
            self.in_use -= 1;
            self.publish();
        }
    }

    /// Mirror the occupancy into the global registry's gauges.
    fn publish(&self) {
        if !obs::metrics_on() {
            return;
        }
        let g = obs::global();
        g.gauge(
            obs::names::PLANE_SLOTS_IN_USE,
            "Tile slots currently held across all MCAs",
            &[],
        )
        .set(self.in_use as f64);
        g.gauge(
            obs::names::PLANE_SLOT_HIGH_WATER,
            "Highest per-MCA tile-slot count ever needed",
            &[],
        )
        .set(self.high_water() as f64);
    }

    /// Slots currently held across all MCAs.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Highest slot count any MCA has ever needed (never shrinks; evicted
    /// slots are reused before this grows).
    pub fn high_water(&self) -> usize {
        self.next_fresh.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_sequential_per_mca() {
        let mut a = TileAllocator::new(2, 0);
        assert_eq!(a.alloc(0).unwrap(), 0);
        assert_eq!(a.alloc(0).unwrap(), 1);
        assert_eq!(a.alloc(1).unwrap(), 0);
        assert_eq!(a.in_use(), 3);
        assert_eq!(a.high_water(), 2);
    }

    #[test]
    fn freed_slots_are_reused_lowest_first() {
        let mut a = TileAllocator::new(1, 0);
        for want in 0..4 {
            assert_eq!(a.alloc(0).unwrap(), want);
        }
        a.free(0, 2);
        a.free(0, 1);
        assert_eq!(a.in_use(), 2);
        // Lowest freed slot first, then the other — no fresh growth.
        assert_eq!(a.alloc(0).unwrap(), 1);
        assert_eq!(a.alloc(0).unwrap(), 2);
        assert_eq!(a.high_water(), 4);
        // Only once both freed slots are reclaimed does fresh allocation
        // resume.
        assert_eq!(a.alloc(0).unwrap(), 4);
        assert_eq!(a.high_water(), 5);
    }

    #[test]
    fn capacity_is_enforced_and_freed_slots_lift_it() {
        let mut a = TileAllocator::new(1, 2);
        a.alloc(0).unwrap();
        a.alloc(0).unwrap();
        let err = a.alloc(0).unwrap_err();
        assert!(err.to_string().contains("out of tile slots"), "{err}");
        a.free(0, 0);
        assert_eq!(a.alloc(0).unwrap(), 0);
    }

    #[test]
    fn zero_capacity_means_unbounded() {
        let mut a = TileAllocator::new(1, 0);
        for _ in 0..10_000 {
            a.alloc(0).unwrap();
        }
        assert_eq!(a.in_use(), 10_000);
    }

    #[test]
    fn double_free_is_idempotent() {
        let mut a = TileAllocator::new(1, 0);
        a.alloc(0).unwrap();
        a.free(0, 0);
        a.free(0, 0);
        assert_eq!(a.in_use(), 0);
    }

    #[test]
    fn operand_id_formats() {
        assert_eq!(OperandId(3).to_string(), "op3");
        assert_ne!(OperandId(1), OperandId(2));
    }
}
