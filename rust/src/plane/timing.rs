//! Measured per-MCA execution timing, shared across plane builds.
//!
//! Each batch worker records how long every chunk claim took on which
//! MCA.  [`McaTiming`] folds those samples into an exponentially-weighted
//! moving average of nanoseconds per `(chunk, vector)` execution — an
//! EWMA tracks device- and placement-induced drift (a hot MCA slowing
//! down under contention) where a lifetime mean would average it away.
//!
//! The timings live in a process-global **domain registry** keyed by
//! `(seed, tile geometry, cell size)`: every plane built for the same
//! domain shares one `Arc<Vec<McaTiming>>`, so measurements taken while
//! one plane serves batches inform the *build-time* assignment of the
//! next plane built for that domain (see `PlaneHandle::build` — with
//! `--placement timing-aware`, measured means weight the initial
//! shard assignment instead of only redistributing per batch).
//!
//! Timing never influences numerics: results are bit-identical whatever
//! the measurements say (noise is counter-based per `(operand, solve,
//! chunk)`), so sharing state across planes is observability-grade, not
//! correctness-grade.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

/// The one sanctioned monotonic-clock read on execution paths.
///
/// The determinism contract (meliso-lint rule D2) confines clock reads to
/// `obs/` and this file: timing feeds placement and metrics, never
/// numerics, and funnelling every plane/shard `Instant::now()` through
/// here keeps that reviewable in one place.
pub(crate) fn monotonic_now() -> Instant {
    Instant::now()
}

/// EWMA smoothing factor: each new per-chunk sample moves the average a
/// quarter of the way.  Large enough to follow load shifts within a few
/// batches, small enough to damp single-claim jitter.
const ALPHA: f64 = 0.25;

/// Measured execution wall time of one MCA: an EWMA of nanoseconds per
/// chunk execution plus a lifetime sample count, both lock-free.
#[derive(Default)]
pub struct McaTiming {
    /// EWMA of nanos per `(chunk, vector)` execution, stored as `f64`
    /// bits.  `0` doubles as "no sample yet" (a genuine 0.0 ns sample
    /// would re-arm initialization, which is harmless).
    ewma_bits: AtomicU64,
    /// Total chunk executions folded in (monotone).
    chunks: AtomicU64,
}

impl McaTiming {
    /// Fold one measurement: `secs` of wall time covering `chunks`
    /// `(chunk, vector)` executions.
    pub(crate) fn record(&self, secs: f64, chunks: u64) {
        if chunks == 0 {
            return;
        }
        let sample = secs * 1e9 / chunks as f64;
        self.chunks.fetch_add(chunks, Ordering::Relaxed);
        let mut cur = self.ewma_bits.load(Ordering::Relaxed);
        loop {
            let next = if cur == 0 {
                sample
            } else {
                let prev = f64::from_bits(cur);
                prev + ALPHA * (sample - prev)
            };
            match self.ewma_bits.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Smoothed nanoseconds per chunk execution, `None` until the MCA has
    /// executed at least once.
    pub(crate) fn mean_nanos(&self) -> Option<f64> {
        let bits = self.ewma_bits.load(Ordering::Relaxed);
        if bits == 0 {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }

    /// Lifetime chunk executions measured.
    pub(crate) fn samples(&self) -> u64 {
        self.chunks.load(Ordering::Relaxed)
    }
}

/// A timing domain: planes with the same seed and geometry share
/// measurements (their MCAs are the same devices with the same chunk
/// binding, so per-MCA timing transfers between builds).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub(crate) struct DomainKey {
    pub seed: u64,
    pub tile_rows: usize,
    pub tile_cols: usize,
    pub cell_size: usize,
}

fn registry() -> &'static Mutex<BTreeMap<DomainKey, Arc<Vec<McaTiming>>>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<DomainKey, Arc<Vec<McaTiming>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// The shared timing vector for `key` (one entry per MCA), creating it on
/// first use.  A key whose recorded MCA count no longer matches (the same
/// seed rebuilt at a different geometry cannot happen, since geometry is
/// part of the key) always returns a consistently-sized vector.
pub(crate) fn domain(key: DomainKey, mcas: usize) -> Arc<Vec<McaTiming>> {
    let mut reg = registry().lock().unwrap_or_else(PoisonError::into_inner);
    let entry = reg
        .entry(key)
        .or_insert_with(|| Arc::new((0..mcas).map(|_| McaTiming::default()).collect()));
    if entry.len() != mcas {
        // Defensive: never hand a mismatched vector to a plane.
        *entry = Arc::new((0..mcas).map(|_| McaTiming::default()).collect());
    }
    entry.clone()
}

/// Drop all accumulated timing domains.  Benches and tests that compare
/// cold-build behavior call this to keep runs independent; planes already
/// holding a domain `Arc` keep recording into their (now unregistered)
/// vector.
pub fn reset_domains() {
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_tracks_recent_samples() {
        let t = McaTiming::default();
        assert_eq!(t.mean_nanos(), None);
        t.record(1e-6, 1); // 1000 ns/chunk
        assert_eq!(t.mean_nanos(), Some(1000.0));
        // A shifted load moves the mean a quarter of the way per sample.
        t.record(2e-6, 1); // 2000 ns/chunk
        let m = t.mean_nanos().unwrap();
        assert!((m - 1250.0).abs() < 1e-9, "{m}");
        assert_eq!(t.samples(), 2);
        // Zero-chunk measurements are ignored.
        t.record(5.0, 0);
        assert_eq!(t.samples(), 2);
    }

    #[test]
    fn ewma_converges_toward_sustained_rate() {
        let t = McaTiming::default();
        t.record(9e-6, 1); // one slow outlier: 9000 ns
        for _ in 0..32 {
            t.record(1e-6, 1); // sustained 1000 ns
        }
        let m = t.mean_nanos().unwrap();
        assert!((m - 1000.0).abs() < 10.0, "outlier should decay: {m}");
    }

    #[test]
    fn domains_are_shared_per_key_and_resettable() {
        let key = DomainKey {
            seed: 0xD0D0_0001,
            tile_rows: 2,
            tile_cols: 2,
            cell_size: 32,
        };
        let a = domain(key, 4);
        a[1].record(1e-6, 2);
        let b = domain(key, 4);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(b[1].samples(), 2);
        // A different key is a different domain.
        let other = domain(
            DomainKey {
                seed: 0xD0D0_0002,
                ..key
            },
            4,
        );
        assert!(!Arc::ptr_eq(&a, &other));
        assert_eq!(other[1].samples(), 0);
        reset_domains();
        let c = domain(key, 4);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c[1].samples(), 0);
    }

    #[test]
    fn record_is_safe_under_contention() {
        let t = Arc::new(McaTiming::default());
        std::thread::scope(|s| {
            for k in 0..4 {
                let t = t.clone();
                s.spawn(move || {
                    for i in 0..250 {
                        t.record((1 + (i + k) % 3) as f64 * 1e-6, 1);
                    }
                });
            }
        });
        assert_eq!(t.samples(), 1000);
        let m = t.mean_nanos().unwrap();
        assert!(m >= 1000.0 - 1e-6 && m <= 3000.0 + 1e-6, "{m}");
    }
}
