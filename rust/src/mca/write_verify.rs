//! The `adjustableWriteandVerify` protocols (paper Algorithms 1–2).
//!
//! Closed-loop programming: after the initial `MCAsetWeights` pass, each
//! verify iteration reads the array back (with read noise), and — while the
//! tile-level delta norm exceeds the tolerance and the iteration budget
//! lasts — reprograms the out-of-tolerance cells with a partial correction
//! step.  The correction realizes only `verify_gain` of the requested
//! delta (LTP/LTD nonlinearity), carries closed-loop gain noise `η`, lands
//! on the quantized level grid, and cannot beat the device's programming
//! floor.  Every pass also injects write disturb into *all* cells, which is
//! what makes extra iterations counterproductive for EpiRAM (Fig S1).

use crate::device::pulse;
use crate::linalg::Matrix;
use crate::mca::{mapping, Mca};

/// Options for a write–verify encode (paper: `ε`, `N`, `p`).
#[derive(Clone, Copy, Debug)]
pub struct WriteVerifyOpts {
    /// Maximum verify iterations `N` (0 = single-shot `MCAsetWeights`).
    pub max_iters: usize,
    /// Relative tolerance on the tile delta norm (`ε = rel_tol · ‖A‖_p`).
    pub rel_tol: f64,
    /// Use the ∞-norm (`true`) or 2-norm (`false`) for `δ(A, Ã)`.
    pub norm_inf: bool,
}

impl Default for WriteVerifyOpts {
    fn default() -> Self {
        WriteVerifyOpts {
            max_iters: 0,
            rel_tol: 1e-4,
            norm_inf: false,
        }
    }
}

impl WriteVerifyOpts {
    pub fn with_iters(mut self, k: usize) -> Self {
        self.max_iters = k;
        self
    }
}

/// Outcome statistics of one encode.
#[derive(Clone, Copy, Debug, Default)]
pub struct EncodeStats {
    /// Verify iterations actually executed.
    pub iters: usize,
    /// Final relative delta norm `δ(A, Ã) / ‖A‖`.
    pub final_rel_delta: f64,
    /// Cells rewritten across all verify passes.
    pub rewrites: usize,
}

/// `adjustableMatWriteandVerify` over a value-domain tile.
pub fn write_verify_matrix(
    mca: &mut Mca,
    target: &Matrix,
    opts: &WriteVerifyOpts,
) -> (Matrix, EncodeStats) {
    let scale = mapping::tile_scale(target);
    let params = mca.params;
    let norm = target_norm(target, opts.norm_inf).max(f64::MIN_POSITIVE);
    let tol = opts.rel_tol * norm;

    // Initial MCAsetWeights pass (records its own energy).
    let mut encoded = mca.set_weights(target);
    let mut stats = EncodeStats::default();

    for _ in 0..opts.max_iters {
        let delta = encoded.delta_norm(target, opts.norm_inf);
        if delta <= tol {
            break;
        }
        stats.iters += 1;

        // One verify pass: read back with read noise, correct
        // out-of-tolerance cells with a partial closed-loop step.
        let gain = params.verify_gain();
        // Per-cell acceptance band: a cell is "done" once its error is
        // within the device's achievable precision (programming floor or
        // half a quantization step, whichever is coarser).  Converged cells
        // are not rewritten again, which is what keeps the steady-state
        // verify-pass cost at the paper's ~1.4x EC energy overhead.
        let cell_tol = scale * 1.5 * params.sigma_floor.max(params.level_step() / 2.0);
        let mut rewrites = 0usize;
        let mut rows_touched = 0usize;
        for i in 0..target.nrows() {
            let mut row_dirty = false;
            for j in 0..target.ncols() {
                let w = target.get(i, j);
                let cur = encoded.get(i, j);
                let meas = cur * (1.0 + params.sigma_read * mca.rng_mut().normal());
                let err = w - meas;
                if err.abs() <= cell_tol {
                    continue;
                }
                // Partial correction with gain noise; re-quantized; floored
                // by programming noise proportional to the device floor.
                let eta = params.gain_eta * mca.rng_mut().normal();
                let step = gain * err * (1.0 + eta);
                let ideal = cur + step;
                let g = (ideal / scale).clamp(-1.0, 1.0);
                let (gp, gn) = mapping::differential_sides(g);
                let q = (mapping::quantize(gp, params.levels)
                    - mapping::quantize(gn, params.levels))
                    * scale;
                let floor_noise = scale * params.sigma_floor * mca.rng_mut().normal();
                encoded.set(i, j, q + floor_noise * 0.2);
                rewrites += 1;
                row_dirty = true;
            }
            if row_dirty {
                rows_touched += 1;
            }
        }

        // Disturb: every pass stresses the whole array.
        if params.sigma_disturb > 0.0 {
            for v in encoded.data_mut() {
                if *v != 0.0 {
                    *v *= 1.0 + params.sigma_disturb * mca.rng_mut().normal();
                }
            }
        }

        stats.rewrites += rewrites;
        mca.ledger
            .record_write(pulse::verify_pass_cost(&params, rewrites, rows_touched));
        if rewrites == 0 {
            break;
        }
    }

    stats.final_rel_delta = encoded.delta_norm(target, opts.norm_inf) / norm;
    (encoded, stats)
}

fn target_norm(m: &Matrix, inf: bool) -> f64 {
    if inf {
        m.max_abs()
    } else {
        m.fro_norm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;

    fn encode_err(material: Material, k: usize, seed: u64) -> f64 {
        let mut mca = Mca::new(material, 66, 66, seed);
        let a = Matrix::standard_normal(66, 66, 123);
        let opts = WriteVerifyOpts::default().with_iters(k);
        let (enc, _) = mca.write_verify_matrix(&a, &opts);
        enc.delta_norm(&a, false) / a.fro_norm()
    }

    #[test]
    fn verify_iterations_reduce_error() {
        for material in [Material::TaOxHfOx, Material::AlOxHfO2, Material::AgASi] {
            let e0: f64 = (0..5).map(|s| encode_err(material, 0, s)).sum::<f64>() / 5.0;
            let e5: f64 = (0..5).map(|s| encode_err(material, 5, s)).sum::<f64>() / 5.0;
            assert!(
                e5 < e0 * 0.8,
                "{material}: k=0 err {e0:.4}, k=5 err {e5:.4}"
            );
        }
    }

    #[test]
    fn taox_converges_fast_agasi_slow() {
        // TaOx stabilizes by k≈2; Ag-aSi needs ~11 (paper Fig 2).
        let avg = |m: Material, k: usize| {
            (0..6).map(|s| encode_err(m, k, s * 7 + 1)).sum::<f64>() / 6.0
        };
        let ta2 = avg(Material::TaOxHfOx, 2);
        let ta12 = avg(Material::TaOxHfOx, 12);
        // TaOx: k=2 already within 40% of k=12.
        assert!(ta2 < ta12 * 1.9, "ta2={ta2:.4} ta12={ta12:.4}");

        let ag2 = avg(Material::AgASi, 2);
        let ag12 = avg(Material::AgASi, 12);
        // Ag-aSi: k=2 still far from converged.
        assert!(ag2 > ag12 * 1.35, "ag2={ag2:.4} ag12={ag12:.4}");
    }

    #[test]
    fn epiram_extra_iterations_can_hurt() {
        // Disturb ~ floor: error at k=8 should NOT be much better than k=1,
        // and is often worse (Fig S1's EpiRAM trend).
        let avg = |k: usize| {
            (0..8)
                .map(|s| encode_err(Material::EpiRam, k, s * 13 + 3))
                .sum::<f64>()
                / 8.0
        };
        let e1 = avg(1);
        let e8 = avg(8);
        assert!(e8 > e1 * 0.7, "e1={e1:.5} e8={e8:.5}");
    }

    #[test]
    fn stats_count_iterations() {
        let mut mca = Mca::new(Material::AlOxHfO2, 32, 32, 9);
        let a = Matrix::standard_normal(32, 32, 5);
        let opts = WriteVerifyOpts {
            max_iters: 4,
            rel_tol: 1e-9, // unreachable -> run all iterations
            norm_inf: false,
        };
        let (_, stats) = mca.write_verify_matrix(&a, &opts);
        assert_eq!(stats.iters, 4);
        assert!(stats.rewrites > 0);
        assert!(stats.final_rel_delta > 0.0);
    }

    #[test]
    fn loose_tolerance_stops_early() {
        let mut mca = Mca::new(Material::EpiRam, 32, 32, 11);
        let a = Matrix::standard_normal(32, 32, 6);
        let opts = WriteVerifyOpts {
            max_iters: 20,
            rel_tol: 10.0, // immediately satisfied
            norm_inf: false,
        };
        let (_, stats) = mca.write_verify_matrix(&a, &opts);
        assert_eq!(stats.iters, 0);
    }

    #[test]
    fn verify_costs_accumulate_in_ledger() {
        let mut mca = Mca::new(Material::TaOxHfOx, 32, 32, 13);
        let a = Matrix::standard_normal(32, 32, 8);
        let before = mca.ledger;
        let opts = WriteVerifyOpts {
            max_iters: 3,
            rel_tol: 1e-9,
            norm_inf: false,
        };
        mca.write_verify_matrix(&a, &opts);
        assert!(mca.ledger.write_energy_j > before.write_energy_j);
        assert!(mca.ledger.write_passes >= 2); // initial + >=1 verify
    }

    #[test]
    fn inf_norm_option_respected() {
        let mut mca = Mca::new(Material::AgASi, 16, 16, 17);
        let a = Matrix::standard_normal(16, 16, 9);
        let opts = WriteVerifyOpts {
            max_iters: 2,
            rel_tol: 1e-9,
            norm_inf: true,
        };
        let (_, stats) = mca.write_verify_matrix(&a, &opts);
        assert!(stats.final_rel_delta > 0.0);
    }
}
