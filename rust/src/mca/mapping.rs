//! Value ↔ conductance mapping.
//!
//! Signed values map onto a *differential pair* of conductances
//! (`w = (G⁺ − G⁻) · scale`), each side quantized to the device's level
//! grid within the normalized window [0, 1].  The per-tile scale is the
//! tile's max-|value| (peripheral DAC ranging), so quantization error is
//! relative to the tile's dynamic range — which is exactly why matrices
//! with wide dynamic range (bcsstk02) suffer more than near-identity ones.

use crate::device::DeviceParams;
use crate::linalg::Matrix;

/// Per-tile conductance scale (max-abs ranging, paper's NeuroSim+ default).
pub fn tile_scale(tile: &Matrix) -> f64 {
    let m = tile.max_abs();
    if m == 0.0 {
        1.0
    } else {
        m
    }
}

/// Quantize a normalized conductance `g ∈ [0, 1]` to the device level grid.
#[inline]
pub fn quantize(g: f64, levels: u32) -> f64 {
    let l = levels as f64;
    (g.clamp(0.0, 1.0) * l).round() / l
}

/// Encode one signed value through the differential pair with programming
/// error `eps` (relative, supplied by the caller's noise model).
///
/// Returns the value-domain encoded weight.
#[inline]
pub fn encode_value(w: f64, scale: f64, params: &DeviceParams, eps: f64) -> f64 {
    if w == 0.0 {
        // Both sides at G_min: differential zero survives exactly (the
        // common-mode leakage cancels in the differential readout).
        return 0.0;
    }
    let g = (w / scale).clamp(-1.0, 1.0);
    let (gp, gn) = if g >= 0.0 { (g, 0.0) } else { (0.0, -g) };
    // Quantize each side, then apply the (shared-step) programming error —
    // the pair is programmed in one closed-loop step, so the error is
    // common to the differential value, matching the paper's Eq. 2/3
    // multiplicative model.
    let qp = quantize(gp, params.levels);
    let qn = quantize(gn, params.levels);
    (qp - qn) * scale * (1.0 + eps)
}

/// Decompose a signed normalized value into its differential sides
/// (used by tests and the energy model's pulse accounting).
#[inline]
pub fn differential_sides(g: f64) -> (f64, f64) {
    if g >= 0.0 {
        (g, 0.0)
    } else {
        (0.0, -g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;

    #[test]
    fn quantize_snaps_to_grid() {
        assert_eq!(quantize(0.5, 2), 0.5);
        assert_eq!(quantize(0.26, 2), 0.5);
        assert_eq!(quantize(0.24, 2), 0.0);
        assert_eq!(quantize(1.2, 4), 1.0);
        assert_eq!(quantize(-0.3, 4), 0.0);
    }

    #[test]
    fn quantize_error_bounded_by_half_step() {
        let levels = 32;
        for k in 0..1000 {
            let g = k as f64 / 1000.0;
            let q = quantize(g, levels);
            assert!((q - g).abs() <= 0.5 / levels as f64 + 1e-12);
        }
    }

    #[test]
    fn encode_zero_is_exact() {
        let p = Material::TaOxHfOx.params();
        assert_eq!(encode_value(0.0, 5.0, &p, 0.1), 0.0);
    }

    #[test]
    fn encode_noise_free_error_is_quantization_only() {
        let p = Material::EpiRam.params();
        let scale = 2.0;
        for k in 1..100 {
            let w = scale * (k as f64 / 100.0);
            let enc = encode_value(w, scale, &p, 0.0);
            assert!(
                (enc - w).abs() <= scale * 0.5 / p.levels as f64 + 1e-12,
                "w={w}, enc={enc}"
            );
        }
    }

    #[test]
    fn encode_respects_sign() {
        let p = Material::AgASi.params();
        assert!(encode_value(1.0, 2.0, &p, 0.0) > 0.0);
        assert!(encode_value(-1.0, 2.0, &p, 0.0) < 0.0);
    }

    #[test]
    fn encode_saturates_out_of_range() {
        let p = Material::TaOxHfOx.params();
        // |w| > scale clamps to full-scale.
        let enc = encode_value(10.0, 2.0, &p, 0.0);
        assert!((enc - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lower_levels_mean_coarser_grid() {
        let hi = Material::EpiRam.params(); // 512 levels
        let lo = Material::TaOxHfOx.params(); // 32 levels
        let scale = 1.0;
        let w = 0.3171;
        let err_hi = (encode_value(w, scale, &hi, 0.0) - w).abs();
        let err_lo = (encode_value(w, scale, &lo, 0.0) - w).abs();
        assert!(err_lo >= err_hi);
    }

    #[test]
    fn differential_sides_cover_signs() {
        assert_eq!(differential_sides(0.7), (0.7, 0.0));
        assert_eq!(differential_sides(-0.7), (0.0, 0.7));
        assert_eq!(differential_sides(0.0), (0.0, 0.0));
    }

    #[test]
    fn tile_scale_of_zero_tile_is_one() {
        assert_eq!(tile_scale(&Matrix::zeros(4, 4)), 1.0);
    }
}
