//! Memory crossbar array (MCA) simulator (NeuroSim+ array-layer stand-in,
//! DESIGN.md S6).
//!
//! An [`Mca`] owns one physical crossbar: its device parameters, its
//! persistent device-to-device fixed-pattern noise, an RNG stream, and an
//! [`EnergyLedger`].  It implements the paper's programming protocols:
//!
//! * `MCAsetWeights`       -> [`Mca::set_weights`]
//! * `adjustableMatWriteandVerify` -> [`Mca::write_verify_matrix`]
//! * `adjustableVecWriteandVerify` -> [`Mca::write_verify_vector`]
//!
//! Values are mapped through [`mapping`] (differential conductance pairs +
//! level quantization) so every encode returns the *value-domain* noisy
//! image `Ã` that the runtime backends multiply with.

pub mod energy;
pub mod mapping;
pub mod write_verify;

use crate::device::materials::Material;
use crate::device::{pulse, DeviceParams};
use crate::linalg::{Matrix, Vector};
use crate::util::rng::Rng;
pub use energy::EnergyLedger;
pub use write_verify::{EncodeStats, WriteVerifyOpts};

/// One simulated memory crossbar array.
pub struct Mca {
    pub material: Material,
    pub params: DeviceParams,
    /// Physical geometry (cells).
    pub rows: usize,
    pub cols: usize,
    /// Persistent device-to-device relative offsets, one per cell
    /// (fixed-pattern noise survives reprogramming).
    d2d: Vec<f64>,
    rng: Rng,
    pub ledger: EnergyLedger,
}

impl Mca {
    /// Build an MCA with a deterministic per-array RNG stream.
    pub fn new(material: Material, rows: usize, cols: usize, seed: u64) -> Mca {
        let params = material.params();
        let mut rng = Rng::new(seed);
        let mut d2d = vec![0.0; rows * cols];
        for v in &mut d2d {
            *v = params.sigma_d2d * rng.normal();
        }
        Mca {
            material,
            params,
            rows,
            cols,
            d2d,
            rng,
            ledger: EnergyLedger::default(),
        }
    }

    #[inline]
    pub fn rng_mut(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Swap in a different RNG stream, returning the previous one.
    ///
    /// The execution plane derives a counter-based stream per (solve,
    /// chunk) so resident-session results are independent of batching and
    /// shard scheduling (see `plane::exec_stream_seed`); the persistent
    /// programming stream is restored afterwards.
    pub fn replace_rng(&mut self, rng: Rng) -> Rng {
        std::mem::replace(&mut self.rng, rng)
    }

    #[inline]
    fn d2d_at(&self, i: usize, j: usize) -> f64 {
        self.d2d[(i % self.rows) * self.cols + j % self.cols]
    }

    /// `MCAsetWeights`: single-shot programming of a value-domain tile.
    ///
    /// Returns the encoded (noisy, quantized) value-domain image.  The tile
    /// may be smaller than the array; larger tiles wrap the fixed-pattern
    /// noise (virtualization reuses the same physical cells).
    pub fn set_weights(&mut self, target: &Matrix) -> Matrix {
        let scale = mapping::tile_scale(target);
        let mut out = Matrix::zeros(target.nrows(), target.ncols());
        // Zero cells stay at G_min (differential pair parked) — they cost no
        // programming pulses, so zero padding and sparsity are free, exactly
        // as on hardware.
        let mut nnz = 0usize;
        let mut rows_touched = 0usize;
        for i in 0..target.nrows() {
            let mut row_dirty = false;
            for j in 0..target.ncols() {
                let w = target.get(i, j);
                if w == 0.0 {
                    continue;
                }
                nnz += 1;
                row_dirty = true;
                let eps = self.params.sigma_prog * self.rng.normal() + self.d2d_at(i, j);
                let enc = mapping::encode_value(w, scale, &self.params, eps);
                out.set(i, j, enc);
            }
            if row_dirty {
                rows_touched += 1;
            }
        }
        self.ledger
            .record_write(pulse::nnz_write_cost(&self.params, nnz, rows_touched));
        out
    }

    /// Vector variant of `MCAsetWeights` (one wordline).
    pub fn set_weights_vec(&mut self, target: &Vector) -> Vector {
        let m = Matrix::from_vec(1, target.len(), target.data().to_vec());
        let enc = self.set_weights(&m);
        Vector::from_vec(enc.row(0).to_vec())
    }

    /// `adjustableMatWriteandVerify` (paper Algorithm 1).
    pub fn write_verify_matrix(
        &mut self,
        target: &Matrix,
        opts: &WriteVerifyOpts,
    ) -> (Matrix, EncodeStats) {
        write_verify::write_verify_matrix(self, target, opts)
    }

    /// `adjustableVecWriteandVerify` (paper Algorithm 2).
    pub fn write_verify_vector(
        &mut self,
        target: &Vector,
        opts: &WriteVerifyOpts,
    ) -> (Vector, EncodeStats) {
        let m = Matrix::from_vec(1, target.len(), target.data().to_vec());
        let (enc, stats) = write_verify::write_verify_matrix(self, &m, opts);
        (Vector::from_vec(enc.row(0).to_vec()), stats)
    }

    /// Multiplicative read-noise multipliers for one measured MVM output.
    pub fn read_noise_vec(&mut self, n: usize) -> Vec<f32> {
        let sigma = self.params.sigma_read;
        (0..n)
            .map(|_| (1.0 + sigma * self.rng.normal()) as f32)
            .collect()
    }

    /// Account the read energy of one tile activation.
    pub fn record_read(&mut self, rows: usize, cols: usize) {
        self.ledger
            .record_read(pulse::read_cost(&self.params, rows, cols));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(material: Material) -> Mca {
        Mca::new(material, 64, 64, 42)
    }

    #[test]
    fn set_weights_error_tracks_sigma_prog() {
        for material in Material::ALL {
            let mut mca = mk(material);
            let a = Matrix::standard_normal(64, 64, 7);
            let enc = mca.set_weights(&a);
            // Median relative error of large-magnitude entries ~ sigma_prog.
            let mut errs: Vec<f64> = (0..64 * 64)
                .filter_map(|k| {
                    let (i, j) = (k / 64, k % 64);
                    let w = a.get(i, j);
                    (w.abs() > 0.5).then(|| ((enc.get(i, j) - w) / w).abs())
                })
                .collect();
            errs.sort_by(|x, y| x.partial_cmp(y).unwrap());
            let med = errs[errs.len() / 2];
            let p = material.params();
            let sigma = (p.sigma_prog.powi(2) + p.sigma_d2d.powi(2)).sqrt();
            let floor = p.level_step() / 2.0;
            let expect = sigma.max(floor * 0.5);
            assert!(
                med > expect * 0.2 && med < (sigma + floor) * 4.0,
                "{material}: median rel err {med:.5}, sigma {sigma:.5}, floor {floor:.5}"
            );
        }
    }

    #[test]
    fn set_weights_preserves_zero() {
        let mut mca = mk(Material::TaOxHfOx);
        let a = Matrix::zeros(8, 8);
        let enc = mca.set_weights(&a);
        assert!(enc.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn set_weights_records_energy() {
        let mut mca = mk(Material::EpiRam);
        let a = Matrix::standard_normal(32, 32, 1);
        mca.set_weights(&a);
        assert!(mca.ledger.write_energy_j > 0.0);
        assert!(mca.ledger.write_latency_s > 0.0);
    }

    #[test]
    fn d2d_noise_is_persistent() {
        // Average many rewrites: C2C noise averages out, the fixed-pattern
        // offset survives, so two independent averages stay correlated.
        let mut mca = mk(Material::AlOxHfO2);
        let a = Matrix::from_fn(16, 16, |_, _| 1.0);
        let avg = |mca: &mut Mca| {
            let mut acc = vec![0.0f64; 16 * 16];
            let reps = 40;
            for _ in 0..reps {
                let e = mca.set_weights(&a);
                for (s, v) in acc.iter_mut().zip(e.data()) {
                    *s += v - 1.0;
                }
            }
            for s in &mut acc {
                *s /= reps as f64;
            }
            acc
        };
        let m1 = avg(&mut mca);
        let m2 = avg(&mut mca);
        let (mut dot, mut n1, mut n2) = (0.0, 0.0, 0.0);
        for k in 0..16 * 16 {
            dot += m1[k] * m2[k];
            n1 += m1[k] * m1[k];
            n2 += m2[k] * m2[k];
        }
        let corr = dot / (n1.sqrt() * n2.sqrt());
        assert!(corr > 0.2, "correlation {corr}");
    }

    #[test]
    fn epiram_more_accurate_than_taox() {
        let rel_err = |material| {
            let mut mca = mk(material);
            let a = Matrix::standard_normal(64, 64, 3);
            let enc = mca.set_weights(&a);
            enc.delta_norm(&a, false) / a.fro_norm()
        };
        assert!(rel_err(Material::EpiRam) * 5.0 < rel_err(Material::TaOxHfOx));
    }

    #[test]
    fn read_noise_vec_near_one() {
        let mut mca = mk(Material::EpiRam);
        let nv = mca.read_noise_vec(1000);
        let mean: f32 = nv.iter().sum::<f32>() / 1000.0;
        assert!((mean - 1.0).abs() < 0.01);
        assert!(nv.iter().all(|v| (*v - 1.0).abs() < 0.05));
    }

    #[test]
    fn vector_encode_roundtrip_shape() {
        let mut mca = mk(Material::AgASi);
        let x = Vector::standard_normal(66, 5);
        let enc = mca.set_weights_vec(&x);
        assert_eq!(enc.len(), 66);
    }
}
