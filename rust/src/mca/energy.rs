//! Energy/latency accounting for one MCA (the paper's `E_w` / `L_w`).

use crate::device::pulse::PassCost;

/// Running totals for one MCA.  Write quantities are what Table 1 and the
/// figures report; read energy is tracked separately (the paper's metrics
/// are write-dominated, but the ablation benches expose reads too).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyLedger {
    pub write_energy_j: f64,
    pub write_latency_s: f64,
    pub read_energy_j: f64,
    pub write_passes: usize,
    pub cells_written: usize,
    pub pulses: f64,
    pub reads: usize,
}

impl EnergyLedger {
    pub fn record_write(&mut self, cost: PassCost) {
        self.write_energy_j += cost.energy_j;
        self.write_latency_s += cost.latency_s;
        self.cells_written += cost.cells;
        self.pulses += cost.pulses;
        self.write_passes += 1;
    }

    pub fn record_read(&mut self, energy_j: f64) {
        self.read_energy_j += energy_j;
        self.reads += 1;
    }

    /// Merge another ledger (gather across MCAs / chunks).
    pub fn merge(&mut self, other: &EnergyLedger) {
        self.write_energy_j += other.write_energy_j;
        self.write_latency_s += other.write_latency_s;
        self.read_energy_j += other.read_energy_j;
        self.write_passes += other.write_passes;
        self.cells_written += other.cells_written;
        self.pulses += other.pulses;
        self.reads += other.reads;
    }

    pub fn reset(&mut self) {
        *self = EnergyLedger::default();
    }

    /// Field-wise difference against an earlier snapshot (the serving layer
    /// uses this for per-solve energy deltas on a long-lived MCA).
    pub fn minus(&self, baseline: &EnergyLedger) -> EnergyLedger {
        EnergyLedger {
            write_energy_j: self.write_energy_j - baseline.write_energy_j,
            write_latency_s: self.write_latency_s - baseline.write_latency_s,
            read_energy_j: self.read_energy_j - baseline.read_energy_j,
            write_passes: self.write_passes.saturating_sub(baseline.write_passes),
            cells_written: self.cells_written.saturating_sub(baseline.cells_written),
            pulses: self.pulses - baseline.pulses,
            reads: self.reads.saturating_sub(baseline.reads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(e: f64, l: f64) -> PassCost {
        PassCost {
            energy_j: e,
            latency_s: l,
            cells: 10,
            pulses: 100.0,
        }
    }

    #[test]
    fn record_accumulates() {
        let mut led = EnergyLedger::default();
        led.record_write(cost(1e-6, 1e-3));
        led.record_write(cost(2e-6, 3e-3));
        assert!((led.write_energy_j - 3e-6).abs() < 1e-18);
        assert!((led.write_latency_s - 4e-3).abs() < 1e-15);
        assert_eq!(led.write_passes, 2);
        assert_eq!(led.cells_written, 20);
    }

    #[test]
    fn merge_combines() {
        let mut a = EnergyLedger::default();
        a.record_write(cost(1.0, 1.0));
        a.record_read(0.5);
        let mut b = EnergyLedger::default();
        b.record_write(cost(2.0, 2.0));
        b.merge(&a);
        assert!((b.write_energy_j - 3.0).abs() < 1e-12);
        assert!((b.read_energy_j - 0.5).abs() < 1e-12);
        assert_eq!(b.reads, 1);
    }

    #[test]
    fn minus_gives_deltas() {
        let mut a = EnergyLedger::default();
        a.record_write(cost(1.0, 1.0));
        let snapshot = a;
        a.record_write(cost(2.0, 3.0));
        a.record_read(0.25);
        let d = a.minus(&snapshot);
        assert!((d.write_energy_j - 2.0).abs() < 1e-12);
        assert!((d.write_latency_s - 3.0).abs() < 1e-12);
        assert!((d.read_energy_j - 0.25).abs() < 1e-12);
        assert_eq!(d.write_passes, 1);
        assert_eq!(d.cells_written, 10);
        assert_eq!(d.reads, 1);
    }

    #[test]
    fn reset_zeroes() {
        let mut a = EnergyLedger::default();
        a.record_write(cost(1.0, 1.0));
        a.reset();
        assert_eq!(a, EnergyLedger::default());
    }
}
