//! Resident crossbar sessions: program an operand once, serve unlimited
//! solves against it.
//!
//! A [`Session`] owns a pool of **long-lived** worker threads.  At open
//! time the leader walks the [`ChunkPlan`] exactly like the one-shot
//! coordinator — extracting zero-padded chunks, skipping certainly-zero
//! blocks — but instead of tearing everything down after one MVM, each
//! worker keeps its [`TileExecutor`]s (persistent fixed-pattern noise and
//! energy ledgers) and the [`ProgrammedTile`]s resident.  Every subsequent
//! [`Session::solve`] / [`Session::solve_batch`] pays only the
//! input-vector encode and the crossbar reads.
//!
//! **Determinism contract.**  Programming consumes each MCA's persistent
//! stream in leader dispatch order (same as the one-shot coordinator), so
//! the resident image is bit-reproducible for a given seed.  Execution
//! noise is drawn from a *counter-based* stream derived from
//! `(master seed, mca, solve index, chunk)` — see [`exec_stream_seed`] —
//! so a batch of N vectors is bit-identical to N sequential solves, and
//! results are independent of worker count and scheduling.

use crate::config::{SolveOptions, SystemConfig};
use crate::coordinator::{self, worker};
use crate::ec::{ProgrammedTile, TileExecutor};
use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::mca::EnergyLedger;
use crate::metrics::serving::{ServingReport, ServingStats};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::virtualization::{ChunkPlan, ChunkSpec};
use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Counter-based execution-stream derivation (Philox-style): the noise for
/// one `(solve, chunk)` pair is a pure function of the master seed and the
/// chunk's coordinates.  This is what makes resident-session results
/// independent of batching, worker count and scheduling order.
pub fn exec_stream_seed(
    master: u64,
    mca_index: usize,
    solve: u64,
    block_row: usize,
    block_col: usize,
) -> u64 {
    let mut h = master ^ 0xA076_1D64_78BD_642F;
    for v in [
        mca_index as u64,
        solve,
        block_row as u64,
        block_col as u64,
    ] {
        h = (h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).rotate_left(23);
        h = (h ^ (h >> 27)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h ^ (h >> 31)
}

/// Backend-agnostic matrix–vector multiply provider for iterative solvers
/// (`crate::iterative`).
///
/// The solvers only ever ask for `y = A·x`; *where* that product runs —
/// a resident crossbar [`Session`] (analog, noisy, write-amortized) or an
/// exact f64 reference (`crate::iterative::ExactOperator`) — is behind
/// this trait.  Implementations also expose how many MVMs they served and
/// how many write–verify programming passes they paid, so a convergence
/// report can state the paper's headline number directly: *one*
/// programming pass, arbitrarily many read-only iterations.
pub trait MvmOperator: Send + Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;

    /// Compute `y = A·x`.
    fn apply(&self, x: &Vector) -> Result<Vector, String>;

    /// MVMs served so far (monotone).
    fn mvm_count(&self) -> u64;

    /// Write–verify programming passes paid for this operator so far.
    fn programming_passes(&self) -> u64;
}

impl MvmOperator for Session {
    fn nrows(&self) -> usize {
        self.source.nrows()
    }

    fn ncols(&self) -> usize {
        self.source.ncols()
    }

    fn apply(&self, x: &Vector) -> Result<Vector, String> {
        self.solve(x).map(|s| s.y)
    }

    fn mvm_count(&self) -> u64 {
        self.report().solves
    }

    /// A session programs its operand exactly once, at
    /// [`open`](Session::open) — every solve afterwards is reads only.
    fn programming_passes(&self) -> u64 {
        1
    }
}

/// One-time programming cost and shape summary of a resident operand.
#[derive(Clone, Debug)]
pub struct ProgramReport {
    pub m: usize,
    pub n: usize,
    pub chunks_total: usize,
    /// Chunks actually written to the grid (non-zero blocks).
    pub chunks_resident: usize,
    pub chunks_skipped: usize,
    pub mcas_used: usize,
    pub normalization_factor: usize,
    pub mean_wv_iters: f64,
    /// Total write energy across MCAs — paid once for the session.
    pub write_energy_j: f64,
    /// Max write latency across MCAs (wall-clock model: rows serial per
    /// MCA, MCAs parallel).
    pub write_latency_s: f64,
    pub wall_seconds: f64,
}

/// Result of one served solve.
#[derive(Clone, Debug)]
pub struct ServeSolve {
    pub y: Vector,
    /// Monotonic per-session solve index (drives the noise counter).
    pub solve_index: u64,
    /// Wall-clock share of this vector (batch wall / batch size).
    pub wall_seconds: f64,
}

enum ServeJob {
    Program { spec: ChunkSpec, a_tile: crate::linalg::Matrix },
    SealProgram,
    Execute { first_solve: u64, xs: Arc<Vec<Vector>> },
}

enum WorkerMsg {
    Programmed {
        block_row: usize,
        block_col: usize,
        outcome: Result<usize, String>,
    },
    ProgramDone {
        ledgers: Vec<(usize, EnergyLedger)>,
    },
    Partial {
        solve: u64,
        block_row: usize,
        block_col: usize,
        outcome: Result<Vector, String>,
    },
    ExecuteDone {
        ledgers: Vec<(usize, EnergyLedger)>,
    },
}

struct SessionInner {
    senders: Vec<mpsc::SyncSender<ServeJob>>,
    results: mpsc::Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<()>>,
    next_solve: u64,
    resident_chunks: usize,
    /// Latest cumulative ledger snapshot per MCA.
    ledgers: Vec<EnergyLedger>,
    last_write_j: f64,
    last_read_j: f64,
    stats: ServingStats,
}

/// A resident crossbar session: one operand programmed onto the MCA grid,
/// serving unlimited solves.  `Sync` — share it behind an `Arc` and call
/// [`solve`](Session::solve) from any thread (solves on one session are
/// serialized, matching an analog array executing one MVM at a time;
/// throughput comes from [`solve_batch`](Session::solve_batch) and from
/// running many sessions).
pub struct Session {
    source: Arc<dyn MatrixSource>,
    config: SystemConfig,
    opts: SolveOptions,
    program: ProgramReport,
    inner: Mutex<SessionInner>,
}

impl Session {
    /// Program `source` onto the grid: spawn the long-lived worker pool,
    /// scatter and write–verify every non-zero chunk, and gather the
    /// one-time programming report.
    pub fn open(
        source: Arc<dyn MatrixSource>,
        config: SystemConfig,
        opts: SolveOptions,
        backend: Backend,
    ) -> Result<Session, String> {
        let start = Instant::now();
        let (m, n) = (source.nrows(), source.ncols());
        let plan = ChunkPlan::new(config.geometry(), m, n);
        let tile = config.geometry().cell_size;
        if !backend.tile_sizes().contains(&tile) {
            return Err(format!(
                "cell size {tile} has no compiled artifact (available: {:?})",
                backend.tile_sizes()
            ));
        }

        let workers = opts.workers.max(1).min(plan.geometry.mcas());
        let (msg_tx, msg_rx) = mpsc::channel::<WorkerMsg>();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::sync_channel::<ServeJob>(coordinator::JOB_QUEUE_DEPTH);
            senders.push(tx);
            let ctx = ServeWorker {
                cell: tile,
                opts: opts.clone(),
                backend: backend.clone(),
                jobs: rx,
                out: msg_tx.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("meliso-serve-{w}"))
                    .spawn(move || run_worker(ctx))
                    .map_err(|e| format!("spawn serving worker {w}: {e}"))?,
            );
        }
        drop(msg_tx);

        // Scatter/program: walk chunks in deterministic order so each
        // MCA's persistent stream sees its chunks in a fixed sequence.
        let mut dispatched = 0usize;
        let mut skipped = 0usize;
        for spec in plan.chunks() {
            if source.block_is_zero(spec.row0, spec.col0, tile, tile) {
                skipped += 1;
                continue;
            }
            let a_tile = source.block(spec.row0, spec.col0, tile, tile);
            senders[spec.mca_index % workers]
                .send(ServeJob::Program { spec, a_tile })
                .map_err(|_| format!("serving worker {} died", spec.mca_index % workers))?;
            dispatched += 1;
        }
        for s in &senders {
            s.send(ServeJob::SealProgram)
                .map_err(|_| "serving worker died".to_string())?;
        }

        // Gather programming acks and baseline ledgers.
        let mut ledgers = vec![EnergyLedger::default(); plan.geometry.mcas()];
        let mut iters_sum = 0.0f64;
        let mut acks = 0usize;
        let mut sealed = 0usize;
        let mut first_err: Option<String> = None;
        while acks < dispatched || sealed < workers {
            match msg_rx.recv() {
                Ok(WorkerMsg::Programmed {
                    block_row,
                    block_col,
                    outcome,
                }) => {
                    acks += 1;
                    match outcome {
                        Ok(iters) => iters_sum += iters as f64,
                        Err(e) => {
                            if first_err.is_none() {
                                first_err =
                                    Some(format!("programming chunk ({block_row},{block_col}): {e}"));
                            }
                        }
                    }
                }
                Ok(WorkerMsg::ProgramDone { ledgers: batch }) => {
                    sealed += 1;
                    for (idx, l) in batch {
                        ledgers[idx] = l;
                    }
                }
                Ok(_) => {}
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("serving workers exited during programming".to_string());
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            drop(senders);
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }

        let used: Vec<&EnergyLedger> = ledgers.iter().filter(|l| l.write_passes > 0).collect();
        let write_energy_j: f64 = used.iter().map(|l| l.write_energy_j).sum();
        let write_latency_s = used.iter().map(|l| l.write_latency_s).fold(0.0, f64::max);
        let program = ProgramReport {
            m,
            n,
            chunks_total: plan.total_chunks(),
            chunks_resident: dispatched,
            chunks_skipped: skipped,
            mcas_used: used.len(),
            normalization_factor: plan.normalization_factor(),
            mean_wv_iters: if dispatched > 0 {
                iters_sum / dispatched as f64
            } else {
                0.0
            },
            write_energy_j,
            write_latency_s,
            wall_seconds: start.elapsed().as_secs_f64(),
        };
        let mut stats = ServingStats::new();
        stats.record_program(write_energy_j, write_latency_s);
        let last_write_j = ledgers.iter().map(|l| l.write_energy_j).sum();
        let last_read_j = ledgers.iter().map(|l| l.read_energy_j).sum();
        crate::log_info!(
            "server",
            "session open {m}x{n}: {} resident chunks ({} skipped) on {} MCAs, \
             E_w {:.3e} J, wall {:.2}s",
            dispatched,
            skipped,
            program.mcas_used,
            write_energy_j,
            program.wall_seconds
        );
        Ok(Session {
            source,
            config,
            opts,
            program,
            inner: Mutex::new(SessionInner {
                senders,
                results: msg_rx,
                handles,
                next_solve: 0,
                resident_chunks: dispatched,
                ledgers,
                last_write_j,
                last_read_j,
                stats,
            }),
        })
    }

    /// Serve one solve against the resident operand.
    pub fn solve(&self, x: &Vector) -> Result<ServeSolve, String> {
        let mut out = self.solve_batch(std::slice::from_ref(x))?;
        out.pop().ok_or_else(|| "empty batch result".to_string())
    }

    /// Serve a batch of solves in one chunk walk: every resident tile is
    /// visited once and all input vectors run against it, amortizing the
    /// dispatch and scheduling overhead across the batch.  Bit-identical
    /// to the same vectors solved sequentially (see module docs).
    pub fn solve_batch(&self, xs: &[Vector]) -> Result<Vec<ServeSolve>, String> {
        let n = self.source.ncols();
        for (k, x) in xs.iter().enumerate() {
            if x.len() != n {
                return Err(format!(
                    "batch vector {k} has length {} but A has {n} columns",
                    x.len()
                ));
            }
        }
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let start = Instant::now();
        let mut guard = self
            .inner
            .lock()
            .map_err(|_| "session poisoned by an earlier panic".to_string())?;
        let inner = &mut *guard;

        let first_solve = inner.next_solve;
        inner.next_solve += xs.len() as u64;
        let shared = Arc::new(xs.to_vec());
        for s in &inner.senders {
            s.send(ServeJob::Execute {
                first_solve,
                xs: shared.clone(),
            })
            .map_err(|_| "serving worker died".to_string())?;
        }

        // Gather: one partial per (resident chunk, vector), then one
        // ledger snapshot per worker.
        let workers = inner.senders.len();
        let expected = inner.resident_chunks * xs.len();
        let mut per_solve: Vec<BTreeMap<(usize, usize), Vector>> =
            (0..xs.len()).map(|_| BTreeMap::new()).collect();
        let mut got = 0usize;
        let mut done = 0usize;
        let mut first_err: Option<String> = None;
        while got < expected || done < workers {
            match inner.results.recv() {
                Ok(WorkerMsg::Partial {
                    solve,
                    block_row,
                    block_col,
                    outcome,
                }) => {
                    got += 1;
                    match outcome {
                        Ok(v) => {
                            per_solve[(solve - first_solve) as usize]
                                .insert((block_row, block_col), v);
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(format!(
                                    "chunk ({block_row},{block_col}) solve {solve}: {e}"
                                ));
                            }
                        }
                    }
                }
                Ok(WorkerMsg::ExecuteDone { ledgers }) => {
                    done += 1;
                    for (idx, l) in ledgers {
                        inner.ledgers[idx] = l;
                    }
                }
                Ok(_) => {}
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("serving workers exited mid-solve".to_string());
                    }
                    break;
                }
            }
        }
        // Energy deltas for the serving stats (write = per-solve vector
        // encodes + broadcast rows; the matrix write was paid at open).
        // Synced even on error, so a failed batch's energy is not
        // attributed to the next successful one.
        let write_j: f64 = inner.ledgers.iter().map(|l| l.write_energy_j).sum();
        let read_j: f64 = inner.ledgers.iter().map(|l| l.read_energy_j).sum();
        let (dw, dr) = (write_j - inner.last_write_j, read_j - inner.last_read_j);
        inner.last_write_j = write_j;
        inner.last_read_j = read_j;
        if let Some(e) = first_err {
            inner.stats.record_error();
            return Err(e);
        }
        let wall = start.elapsed().as_secs_f64();
        inner.stats.record_batch(xs.len(), wall, dw, dr);

        let m = self.source.nrows();
        let tile = self.config.cell_size;
        Ok(per_solve
            .into_iter()
            .enumerate()
            .map(|(k, partials)| ServeSolve {
                y: coordinator::reduce_partials(m, tile, &partials),
                solve_index: first_solve + k as u64,
                wall_seconds: wall / xs.len() as f64,
            })
            .collect())
    }

    /// One-time programming report for the resident operand.
    pub fn program_report(&self) -> &ProgramReport {
        &self.program
    }

    /// Snapshot of the serving statistics (throughput, latency
    /// percentiles, write/read energy split).
    pub fn report(&self) -> ServingReport {
        match self.inner.lock() {
            Ok(g) => g.stats.report(),
            Err(p) => p.into_inner().stats.report(),
        }
    }

    pub fn source(&self) -> &Arc<dyn MatrixSource> {
        &self.source
    }

    pub fn config(&self) -> &SystemConfig {
        &self.config
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        let mut guard = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        // Closing the job channels ends the worker loops.
        guard.senders.clear();
        for h in guard.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct ServeWorker {
    cell: usize,
    opts: SolveOptions,
    backend: Backend,
    jobs: mpsc::Receiver<ServeJob>,
    out: mpsc::Sender<WorkerMsg>,
}

struct ResidentChunk {
    spec: ChunkSpec,
    tile: ProgrammedTile,
}

fn run_worker(ctx: ServeWorker) {
    let ec = ctx.opts.ec_options();
    let mut executors: HashMap<usize, TileExecutor> = HashMap::new();
    let mut resident: Vec<ResidentChunk> = Vec::new();
    while let Ok(job) = ctx.jobs.recv() {
        match job {
            ServeJob::Program { spec, a_tile } => {
                let exec = executors.entry(spec.mca_index).or_insert_with(|| {
                    worker::new_executor(&ctx.opts, ctx.cell, &ctx.backend, spec.mca_index)
                });
                let outcome = match exec.program_tile(&a_tile, &ec) {
                    Ok(tile) => {
                        let iters = tile.encode.iters;
                        resident.push(ResidentChunk { spec, tile });
                        Ok(iters)
                    }
                    Err(e) => Err(e),
                };
                let msg = WorkerMsg::Programmed {
                    block_row: spec.block_row,
                    block_col: spec.block_col,
                    outcome,
                };
                if ctx.out.send(msg).is_err() {
                    return;
                }
            }
            ServeJob::SealProgram => {
                let snapshot = executors.iter().map(|(idx, e)| (*idx, e.mca.ledger)).collect();
                if ctx.out.send(WorkerMsg::ProgramDone { ledgers: snapshot }).is_err() {
                    return;
                }
            }
            ServeJob::Execute { first_solve, xs } => {
                // The leader counts on exactly chunks x vectors partials,
                // so every path below must send — never panic — or the
                // gather would hang (the other workers keep the reply
                // channel open).
                for chunk in &resident {
                    for (k, x) in xs.iter().enumerate() {
                        let solve = first_solve + k as u64;
                        let outcome = match executors.get_mut(&chunk.spec.mca_index) {
                            Some(exec) => {
                                let x_chunk = x.slice_padded(chunk.spec.col0, ctx.cell);
                                let stream = Rng::new(exec_stream_seed(
                                    ctx.opts.seed,
                                    chunk.spec.mca_index,
                                    solve,
                                    chunk.spec.block_row,
                                    chunk.spec.block_col,
                                ));
                                let saved = exec.mca.replace_rng(stream);
                                let out =
                                    exec.execute_tile(&chunk.tile, &x_chunk, &ec).map(|r| r.y);
                                exec.mca.replace_rng(saved);
                                out
                            }
                            None => Err("resident chunk lost its executor".to_string()),
                        };
                        let msg = WorkerMsg::Partial {
                            solve,
                            block_row: chunk.spec.block_row,
                            block_col: chunk.spec.block_col,
                            outcome,
                        };
                        if ctx.out.send(msg).is_err() {
                            return;
                        }
                    }
                }
                let snapshot = executors.iter().map(|(idx, e)| (*idx, e.mca.ledger)).collect();
                if ctx.out.send(WorkerMsg::ExecuteDone { ledgers: snapshot }).is_err() {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::linalg::Matrix;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::runtime::native::NativeBackend;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    fn open(
        a: Matrix,
        config: SystemConfig,
        opts: SolveOptions,
    ) -> Session {
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        Session::open(src, config, opts, native()).unwrap()
    }

    #[test]
    fn resident_session_solves_accurately() {
        let a = Matrix::standard_normal(64, 64, 31);
        let x = Vector::standard_normal(64, 32);
        let b = a.matvec(&x);
        let session = open(
            a,
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        assert_eq!(session.program_report().chunks_resident, 1);
        let out = session.solve(&x).unwrap();
        assert_eq!(out.y.len(), 64);
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn batch_matches_sequential_bit_exact() {
        // Same seed, different worker counts and batching: identical ys.
        let a = Matrix::standard_normal(100, 100, 41);
        let xs: Vec<Vector> = (0..3).map(|k| Vector::standard_normal(100, 50 + k)).collect();
        let config = SystemConfig::new(2, 2, 32);
        let base = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(7);
        let seq_session = open(a.clone(), config, base.clone().with_workers(1));
        let seq: Vec<Vector> = xs
            .iter()
            .map(|x| seq_session.solve(x).unwrap().y)
            .collect();
        let batch_session = open(a, config, base.with_workers(3));
        let batch: Vec<Vector> = batch_session
            .solve_batch(&xs)
            .unwrap()
            .into_iter()
            .map(|r| r.y)
            .collect();
        assert_eq!(seq, batch);
    }

    #[test]
    fn solve_counter_draws_fresh_noise() {
        let a = Matrix::standard_normal(32, 32, 61);
        let x = Vector::standard_normal(32, 62);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        let y0 = session.solve(&x).unwrap();
        let y1 = session.solve(&x).unwrap();
        assert_eq!(y0.solve_index, 0);
        assert_eq!(y1.solve_index, 1);
        assert_ne!(y0.y, y1.y);
    }

    #[test]
    fn per_solve_write_energy_is_amortized() {
        let a = Matrix::standard_normal(64, 64, 71);
        let x = Vector::standard_normal(64, 72);
        let session = open(
            a,
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        for _ in 0..4 {
            session.solve(&x).unwrap();
        }
        let r = session.report();
        assert_eq!(r.solves, 4);
        assert!(r.write_energy_per_solve_j > 0.0);
        assert!(
            r.write_amortization > 10.0,
            "amortization {}",
            r.write_amortization
        );
        assert!(r.read_energy_per_solve_j > 0.0);
        assert!(r.latency_p99_ms >= r.latency_p50_ms);
    }

    #[test]
    fn sparse_operand_skips_zero_chunks() {
        let src: Arc<dyn MatrixSource> = Arc::new(BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3));
        let session = Session::open(
            src.clone(),
            SystemConfig::new(2, 2, 32),
            SolveOptions::default().with_device(Material::EpiRam),
            native(),
        )
        .unwrap();
        let p = session.program_report();
        assert_eq!(p.chunks_total, 64);
        assert!(p.chunks_skipped > 30, "{}", p.chunks_skipped);
        assert_eq!(p.chunks_resident + p.chunks_skipped, p.chunks_total);
        let x = Vector::standard_normal(256, 9);
        let b = src.matvec(&x);
        let out = session.solve(&x).unwrap();
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let a = Matrix::standard_normal(16, 16, 81);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let x = Vector::standard_normal(8, 82);
        assert!(session.solve(&x).is_err());
        // The session survives a rejected input.
        let ok = Vector::standard_normal(16, 83);
        assert!(session.solve(&ok).is_ok());
    }

    #[test]
    fn unsupported_cell_size_is_error() {
        let a = Matrix::standard_normal(16, 16, 91);
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        let err = Session::open(
            src,
            SystemConfig::single_mca(48),
            SolveOptions::default(),
            native(),
        )
        .unwrap_err();
        assert!(err.contains("cell size 48"), "{err}");
    }

    #[test]
    fn empty_batch_is_noop() {
        let a = Matrix::standard_normal(16, 16, 93);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        assert!(session.solve_batch(&[]).unwrap().is_empty());
        assert_eq!(session.report().solves, 0);
    }

    #[test]
    fn exec_stream_seed_separates_coordinates() {
        let base = exec_stream_seed(42, 0, 0, 0, 0);
        assert_ne!(base, exec_stream_seed(43, 0, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 1, 0, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 1, 0, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 1, 0));
        assert_ne!(base, exec_stream_seed(42, 0, 0, 0, 1));
        assert_eq!(base, exec_stream_seed(42, 0, 0, 0, 0));
    }
}
