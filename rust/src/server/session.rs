//! Resident crossbar sessions: program an operand once, serve unlimited
//! solves against it.
//!
//! A [`Session`] is the serving façade over one *residency* of the shared
//! execution plane: at open time the plane programs every non-zero chunk
//! onto its sharded worker pool (write–verify paid once, tiles and
//! [`TileExecutor`](crate::ec::TileExecutor)s stay resident), and every
//! subsequent [`Session::solve`] / [`Session::solve_batch`] pays only the
//! input-vector encode and the crossbar reads.  Since the plane became
//! multi-tenant, **many sessions share one plane**: open them with
//! [`Session::open_on`] (or
//! [`Meliso::open_session_on`](crate::solver::Meliso::open_session_on))
//! against clones of the same [`PlaneHandle`] and their batches run
//! *concurrently* on one shard pool — no plane-wide lock, bit-identical
//! to dedicated planes.  The session itself owns the serving concerns on
//! top: request validation, throughput/latency statistics and the
//! write-once/read-per-solve energy split ([`crate::metrics::serving`]).
//!
//! **Determinism contract.**  Each residency gets its own executor set
//! seeded exactly like a dedicated plane, programmed in leader dispatch
//! order, so the resident image is bit-reproducible for a given seed
//! regardless of which other tenants share the plane.  Execution noise is
//! drawn from a *counter-based* stream derived from
//! `(master seed, mca, solve index, chunk)` — see [`exec_stream_seed`] —
//! so a batch of N vectors is bit-identical to N sequential solves.
//!
//! **Fault tolerance.**  A shard panic surfaces as a typed
//! [`PlaneError::ShardDead`] from the ongoing call (the plane's
//! supervised gather — see [`crate::plane`]) and poisons the plane so
//! later calls fail fast; dropping the session evicts its residency,
//! returning the tile slots to the allocator.

pub use crate::plane::{exec_stream_seed, OperandId, ProgramReport, ServeSolve};

use crate::config::{SolveOptions, SystemConfig};
use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::metrics::serving::{ServingReport, ServingStats};
use crate::obs;
use crate::plane::{PlaneError, PlaneHandle};
use crate::runtime::Backend;
use std::sync::{Arc, Mutex, PoisonError};

/// Mirror an energy delta into the global registry's serve-path split.
fn note_energy(op: &str, kind: &str, joules: f64) {
    if joules > 0.0 {
        obs::global()
            .counter(
                obs::names::ENERGY_JOULES,
                "Serve-path energy split by operand and kind (write|read)",
                &[("operand", op), ("kind", kind)],
            )
            .add(joules);
    }
}

/// Backend-agnostic matrix–vector multiply provider for iterative solvers
/// (`crate::iterative`).
///
/// The solvers only ever ask for `y = A·x`; *where* that product runs —
/// a resident crossbar [`Session`] (analog, noisy, write-amortized), a
/// bare plane residency ([`crate::iterative::PlaneOperator`]) or an exact
/// f64 reference (`crate::iterative::ExactOperator`) — is behind this
/// trait.  Implementations also expose how many MVMs they served and how
/// many write–verify programming passes they paid, so a convergence
/// report can state the paper's headline number directly: *one*
/// programming pass, arbitrarily many read-only iterations.
pub trait MvmOperator: Send + Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;

    /// Compute `y = A·x`.
    fn apply(&self, x: &Vector) -> Result<Vector, String>;

    /// MVMs served so far (monotone).
    fn mvm_count(&self) -> u64;

    /// Write–verify programming passes paid for this operator so far.
    fn programming_passes(&self) -> u64;
}

impl MvmOperator for Session {
    fn nrows(&self) -> usize {
        self.source.nrows()
    }

    fn ncols(&self) -> usize {
        self.source.ncols()
    }

    fn apply(&self, x: &Vector) -> Result<Vector, String> {
        self.solve(x).map(|s| s.y).map_err(String::from)
    }

    fn mvm_count(&self) -> u64 {
        self.report().solves
    }

    /// A session programs its operand exactly once, at
    /// [`open`](Session::open) — every solve afterwards is reads only.
    fn programming_passes(&self) -> u64 {
        1
    }
}

struct SessionInner {
    last_write_j: f64,
    last_read_j: f64,
    stats: ServingStats,
}

/// A resident crossbar session: one operand programmed onto the (possibly
/// shared) MCA grid, serving unlimited solves.  `Sync` — share it behind
/// an `Arc` and call [`solve`](Session::solve) from any thread (solves on
/// one session are serialized, matching an analog array executing one MVM
/// at a time; throughput comes from [`solve_batch`](Session::solve_batch)
/// and from running many sessions — sessions on different operands of a
/// shared plane execute concurrently).
pub struct Session {
    source: Arc<dyn MatrixSource>,
    config: SystemConfig,
    opts: SolveOptions,
    program: ProgramReport,
    id: OperandId,
    plane: PlaneHandle,
    inner: Mutex<SessionInner>,
}

impl Session {
    /// Program `source` onto a fresh dedicated plane: build the sharded
    /// pool, scatter and write–verify every non-zero chunk (per-shard
    /// programming runs in parallel), and record the one-time programming
    /// report.
    pub fn open(
        source: Arc<dyn MatrixSource>,
        config: SystemConfig,
        opts: SolveOptions,
        backend: Backend,
    ) -> Result<Session, PlaneError> {
        let plane = PlaneHandle::build(source.as_ref(), &config, &opts, backend)?;
        Session::open_on(plane, source)
    }

    /// Program `source` as a residency on an existing (shared) plane.
    /// Many sessions opened on clones of one handle serve concurrent
    /// batches from one shard pool, bit-identical to dedicated planes.
    /// The source is already shared, so programming goes through the
    /// descriptor path ([`PlaneHandle::program_shared`]): shards extract
    /// their own tiles fused into the encode, instead of the leader
    /// extracting serially.
    pub fn open_on(
        plane: PlaneHandle,
        source: Arc<dyn MatrixSource>,
    ) -> Result<Session, PlaneError> {
        let config = plane.system_config();
        let opts = plane.options().clone();
        let (id, program) = plane.program_shared(source.clone())?;
        let (write_j, read_j) = plane.operand_energy_totals(id).unwrap_or((0.0, 0.0));
        let mut stats = ServingStats::new();
        stats.record_program(program.write_energy_j, program.write_latency_s);
        if obs::metrics_on() {
            note_energy(&id.to_string(), "write", program.write_energy_j);
        }
        crate::log_info!(
            "server",
            "session open {id} ({}x{}): {} resident chunks ({} skipped) on {} MCAs, \
             E_w {:.3e} J, wall {:.2}s",
            program.m,
            program.n,
            program.chunks_resident,
            program.chunks_skipped,
            program.mcas_used,
            program.write_energy_j,
            program.wall_seconds
        );
        Ok(Session {
            source,
            config,
            opts,
            program,
            id,
            plane,
            inner: Mutex::new(SessionInner {
                last_write_j: write_j,
                last_read_j: read_j,
                stats,
            }),
        })
    }

    /// Serve one solve against the resident operand.
    pub fn solve(&self, x: &Vector) -> Result<ServeSolve, PlaneError> {
        let mut out = self.solve_batch(std::slice::from_ref(x))?;
        out.pop()
            .ok_or_else(|| PlaneError::InvalidInput("empty batch result".to_string()))
    }

    /// Serve a batch of solves in one chunk walk: every resident tile is
    /// visited once and all input vectors run against it, amortizing the
    /// dispatch and scheduling overhead across the batch.  Bit-identical
    /// to the same vectors solved sequentially (see module docs).
    pub fn solve_batch(&self, xs: &[Vector]) -> Result<Vec<ServeSolve>, PlaneError> {
        let n = self.source.ncols();
        for (k, x) in xs.iter().enumerate() {
            if x.len() != n {
                return Err(PlaneError::InvalidInput(format!(
                    "batch vector {k} has length {} but A has {n} columns",
                    x.len()
                )));
            }
        }
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        let mut guard = self
            .inner
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let inner = &mut *guard;
        let outcome = self.plane.execute_batch(self.id, xs);
        // This residency's energy totals, synced even on error, so a
        // failed batch's energy is not attributed to the next successful
        // one.
        let (write_j, read_j) = self
            .plane
            .operand_energy_totals(self.id)
            .unwrap_or((inner.last_write_j, inner.last_read_j));
        let (dw, dr) = (write_j - inner.last_write_j, read_j - inner.last_read_j);
        inner.last_write_j = write_j;
        inner.last_read_j = read_j;
        match outcome {
            Ok(batch) => {
                inner.stats.record_batch(xs.len(), batch.wall_seconds, dw, dr);
                if obs::metrics_on() {
                    self.publish_batch_metrics(xs.len(), batch.wall_seconds, dw, dr);
                }
                Ok(batch.solves)
            }
            Err(e) => {
                inner.stats.record_error();
                if obs::metrics_on() {
                    let op = self.id.to_string();
                    obs::global()
                        .counter(
                            obs::names::SOLVE_ERRORS,
                            "Failed served batches",
                            &[("operand", &op)],
                        )
                        .inc();
                }
                Err(e)
            }
        }
    }

    /// Mirror one served batch into the global metrics registry: batch and
    /// per-vector latency histograms plus the energy deltas.
    fn publish_batch_metrics(&self, batch: usize, wall_s: f64, write_j: f64, read_j: f64) {
        let op = self.id.to_string();
        let labels: &[(&str, &str)] = &[("operand", &op)];
        let g = obs::global();
        g.histogram(
            obs::names::BATCH_LATENCY,
            "Whole-batch served solve latency in seconds",
            labels,
            obs::LATENCY_BUCKETS,
        )
        .observe(wall_s);
        let per_vector = g.histogram(
            obs::names::SOLVE_LATENCY,
            "Per-vector served solve latency in seconds",
            labels,
            obs::LATENCY_BUCKETS,
        );
        let share = wall_s / batch as f64;
        for _ in 0..batch {
            per_vector.observe(share);
        }
        note_energy(&op, "write", write_j);
        note_energy(&op, "read", read_j);
    }

    /// One-time programming report for the resident operand.
    pub fn program_report(&self) -> &ProgramReport {
        &self.program
    }

    /// Snapshot of the serving statistics (throughput, latency
    /// percentiles, write/read energy split).
    pub fn report(&self) -> ServingReport {
        self.inner
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .stats
            .report()
    }

    /// This session's residency handle on its plane.
    pub fn operand_id(&self) -> OperandId {
        self.id
    }

    /// The (possibly shared) execution plane hosting this session's
    /// residency.
    pub fn plane(&self) -> &PlaneHandle {
        &self.plane
    }

    pub fn source(&self) -> &Arc<dyn MatrixSource> {
        &self.source
    }

    pub fn config(&self) -> &SystemConfig {
        &self.config
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Release the residency so a shared plane reclaims its tile slots;
        // on a dedicated plane the whole pool is about to join anyway.
        let _ = self.plane.evict(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::linalg::Matrix;
    use crate::matrices::{BandedSource, DenseSource};
    use crate::runtime::native::NativeBackend;

    fn native() -> Backend {
        Arc::new(NativeBackend::new())
    }

    fn open(a: Matrix, config: SystemConfig, opts: SolveOptions) -> Session {
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        Session::open(src, config, opts, native()).unwrap()
    }

    #[test]
    fn resident_session_solves_accurately() {
        let a = Matrix::standard_normal(64, 64, 31);
        let x = Vector::standard_normal(64, 32);
        let b = a.matvec(&x);
        let session = open(
            a,
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        assert_eq!(session.program_report().chunks_resident, 1);
        let out = session.solve(&x).unwrap();
        assert_eq!(out.y.len(), 64);
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn batch_matches_sequential_bit_exact() {
        // Same seed, different worker counts and batching: identical ys.
        let a = Matrix::standard_normal(100, 100, 41);
        let xs: Vec<Vector> = (0..3).map(|k| Vector::standard_normal(100, 50 + k)).collect();
        let config = SystemConfig::new(2, 2, 32);
        let base = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(7);
        let seq_session = open(a.clone(), config, base.clone().with_workers(1));
        let seq: Vec<Vector> = xs
            .iter()
            .map(|x| seq_session.solve(x).unwrap().y)
            .collect();
        let batch_session = open(a, config, base.with_workers(3));
        let batch: Vec<Vector> = batch_session
            .solve_batch(&xs)
            .unwrap()
            .into_iter()
            .map(|r| r.y)
            .collect();
        assert_eq!(seq, batch);
    }

    #[test]
    fn two_sessions_share_one_plane() {
        // Two tenants on one plane serve interleaved solves bit-identical
        // to two dedicated planes with the same seeds.
        let a = Matrix::standard_normal(48, 48, 91);
        let c = Matrix::standard_normal(48, 48, 92);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(17)
            .with_workers(2);
        let xa = Vector::standard_normal(48, 93);
        let xc = Vector::standard_normal(48, 94);

        let ded_a = open(a.clone(), config, opts.clone()).solve(&xa).unwrap().y;
        let ded_c = open(c.clone(), config, opts.clone()).solve(&xc).unwrap().y;

        let src_a: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        let src_c: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(c));
        let plane = PlaneHandle::build(src_a.as_ref(), &config, &opts, native()).unwrap();
        let sa = Session::open_on(plane.clone(), src_a).unwrap();
        let sc = Session::open_on(plane.clone(), src_c).unwrap();
        assert!(PlaneHandle::ptr_eq(sa.plane(), sc.plane()));
        assert_ne!(sa.operand_id(), sc.operand_id());
        assert_eq!(plane.resident_operands(), 2);
        // Interleaved order: C first, then A — counter-based noise makes
        // order irrelevant.
        let shared_c = sc.solve(&xc).unwrap().y;
        let shared_a = sa.solve(&xa).unwrap().y;
        assert_eq!(ded_a, shared_a);
        assert_eq!(ded_c, shared_c);
        // Dropping one session frees its residency, the other keeps
        // serving.
        drop(sc);
        assert_eq!(plane.resident_operands(), 1);
        assert!(sa.solve(&xa).is_ok());
    }

    #[test]
    fn solve_counter_draws_fresh_noise() {
        let a = Matrix::standard_normal(32, 32, 61);
        let x = Vector::standard_normal(32, 62);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        let y0 = session.solve(&x).unwrap();
        let y1 = session.solve(&x).unwrap();
        assert_eq!(y0.solve_index, 0);
        assert_eq!(y1.solve_index, 1);
        assert_ne!(y0.y, y1.y);
    }

    #[test]
    fn per_solve_write_energy_is_amortized() {
        let a = Matrix::standard_normal(64, 64, 71);
        let x = Vector::standard_normal(64, 72);
        let session = open(
            a,
            SystemConfig::single_mca(64),
            SolveOptions::default().with_device(Material::TaOxHfOx),
        );
        for _ in 0..4 {
            session.solve(&x).unwrap();
        }
        let r = session.report();
        assert_eq!(r.solves, 4);
        assert!(r.write_energy_per_solve_j > 0.0);
        assert!(
            r.write_amortization > 10.0,
            "amortization {}",
            r.write_amortization
        );
        assert!(r.read_energy_per_solve_j > 0.0);
        assert!(r.latency_p99_ms >= r.latency_p50_ms);
    }

    #[test]
    fn tail_tile_session_matches_exact() {
        // m % tile != 0: the resident path must drop the padded tail rows
        // exactly like the one-shot path.
        let a = Matrix::standard_normal(40, 40, 77);
        let x = Vector::standard_normal(40, 78);
        let b = a.matvec(&x);
        let session = open(
            a,
            SystemConfig::new(2, 2, 32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let p = session.program_report();
        assert_eq!(p.chunks_total, 4);
        let out = session.solve(&x).unwrap();
        assert_eq!(out.y.len(), 40);
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn sparse_operand_skips_zero_chunks() {
        let src: Arc<dyn MatrixSource> = Arc::new(BandedSource::new(256, 4, 1.0, 10.0, 0.2, 3));
        let session = Session::open(
            src.clone(),
            SystemConfig::new(2, 2, 32),
            SolveOptions::default().with_device(Material::EpiRam),
            native(),
        )
        .unwrap();
        let p = session.program_report();
        assert_eq!(p.chunks_total, 64);
        assert!(p.chunks_skipped > 30, "{}", p.chunks_skipped);
        assert_eq!(p.chunks_resident + p.chunks_skipped, p.chunks_total);
        let x = Vector::standard_normal(256, 9);
        let b = src.matvec(&x);
        let out = session.solve(&x).unwrap();
        let err = out.y.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 0.1, "{err}");
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let a = Matrix::standard_normal(16, 16, 81);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        let x = Vector::standard_normal(8, 82);
        let err = session.solve(&x).unwrap_err();
        assert!(matches!(err, PlaneError::InvalidInput(_)), "{err:?}");
        // The session survives a rejected input.
        let ok = Vector::standard_normal(16, 83);
        assert!(session.solve(&ok).is_ok());
    }

    #[test]
    fn unsupported_cell_size_is_error() {
        let a = Matrix::standard_normal(16, 16, 91);
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        let err = Session::open(
            src,
            SystemConfig::single_mca(48),
            SolveOptions::default(),
            native(),
        )
        .unwrap_err();
        assert!(
            matches!(err, PlaneError::UnsupportedCell { cell: 48, .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("cell size 48"), "{err}");
    }

    #[test]
    fn empty_batch_is_noop() {
        let a = Matrix::standard_normal(16, 16, 93);
        let session = open(
            a,
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
        );
        assert!(session.solve_batch(&[]).unwrap().is_empty());
        assert_eq!(session.report().solves, 0);
    }

    #[test]
    fn concurrent_sessions_solve_in_parallel_bit_exact() {
        // Two sessions on one plane, solving from two threads at once:
        // results must match the dedicated-plane references bit for bit.
        let a = Matrix::standard_normal(48, 48, 95);
        let c = Matrix::standard_normal(48, 48, 96);
        let config = SystemConfig::new(2, 2, 32);
        let opts = SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_seed(23)
            .with_workers(2);
        let xa = Vector::standard_normal(48, 97);
        let xc = Vector::standard_normal(48, 98);
        let ded_a = open(a.clone(), config, opts.clone()).solve(&xa).unwrap().y;
        let ded_c = open(c.clone(), config, opts.clone()).solve(&xc).unwrap().y;

        let src_a: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(a));
        let src_c: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(c));
        let plane = PlaneHandle::build(src_a.as_ref(), &config, &opts, native()).unwrap();
        let sa = Session::open_on(plane.clone(), src_a).unwrap();
        let sc = Session::open_on(plane.clone(), src_c).unwrap();
        let (ya, yc) = std::thread::scope(|scope| {
            let ha = scope.spawn(|| sa.solve(&xa).unwrap().y);
            let hc = scope.spawn(|| sc.solve(&xc).unwrap().y);
            (ha.join().unwrap(), hc.join().unwrap())
        });
        assert_eq!(ya, ded_a);
        assert_eq!(yc, ded_c);
    }
}
