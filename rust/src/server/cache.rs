//! Multi-tenant operand residency: an LRU cache of resident [`Session`]s
//! keyed by matrix fingerprint, sharing **one** execution plane.
//!
//! A serving deployment holds many operands but only so much crossbar
//! real estate.  [`OperandCache`] keeps the `capacity` most-recently-used
//! sessions resident *as residencies on a single shared plane* (a
//! [`PlaneHandle`]) — one shard pool serves every tenant, instead of
//! one thread pool per operand.  A repeated solve against a cached
//! operand skips the whole write–verify programming pass (the expensive
//! part); evicting the least-recently-used session returns its tile slots
//! to the plane's allocator for the next tenant.  If the shared plane
//! fails (a shard panicked), the cache transparently rebuilds a fresh
//! plane on the next miss.
//!
//! Keys combine a content [`fingerprint`] of the operand with every option
//! that shapes the resident state (material, geometry, seed, EC settings),
//! so two tenants only share a session when they would get bit-identical
//! results from it.

use super::session::Session;
use crate::config::{SolveOptions, SystemConfig};
use crate::ec::DenoiseMode;
use crate::matrices::MatrixSource;
use crate::obs;
use crate::plane::PlaneHandle;
use crate::solver::{Meliso, MelisoError};
use std::sync::Arc;

/// Mirror one cache event into the global metrics registry.
fn note_cache(name: &'static str, help: &'static str, n: u64) {
    if n > 0 && obs::metrics_on() {
        obs::global().counter(name, help, &[]).add(n as f64);
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Independent offset for the second hash lane (collision probability of
/// the pair is ~2⁻¹²⁸ for accidental collisions).
const FNV_OFFSET_2: u64 = FNV_OFFSET ^ 0x9E37_79B9_7F4A_7C15;

#[inline]
fn mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// A 128-bit content hash pair, advanced together over the same stream.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct HashPair(u64, u64);

impl HashPair {
    fn new(offset: u64) -> HashPair {
        HashPair(offset, FNV_OFFSET_2 ^ offset)
    }

    #[inline]
    fn mix(&mut self, v: u64) {
        self.0 = mix(self.0, v);
        self.1 = mix(self.1, v.rotate_left(31) ^ 0xA076_1D64_78BD_642F);
    }
}

/// Entry budget above which the fingerprint samples a deterministic probe
/// grid instead of hashing every entry (procedural 65k² operands would
/// otherwise cost a full O(mn) sweep per lookup).
const EXACT_FINGERPRINT_LIMIT: usize = 1 << 22;

/// Hash the operand content: dims plus entries.  Returns the pair and
/// whether every entry was covered (`false` = probe-sampled, so equal
/// hashes do not prove equal content).
fn content_hash(source: &dyn MatrixSource) -> (HashPair, bool) {
    let (m, n) = (source.nrows(), source.ncols());
    let mut h = HashPair::new(FNV_OFFSET);
    h.mix(m as u64);
    h.mix(n as u64);
    let exact = m.saturating_mul(n) <= EXACT_FINGERPRINT_LIMIT;
    if exact {
        let rows_per = (EXACT_FINGERPRINT_LIMIT / n.max(1)).clamp(1, 256);
        let mut r0 = 0;
        while r0 < m {
            let rows = rows_per.min(m - r0);
            let block = source.block(r0, 0, rows, n);
            for &v in block.data() {
                h.mix(v.to_bits());
            }
            r0 += rows;
        }
    } else {
        h.mix(source.max_abs().to_bits());
        let step_r = (m / 16).max(1);
        let step_c = (n / 16).max(1);
        let mut r0 = 0;
        while r0 < m {
            let mut c0 = 0;
            while c0 < n {
                let block = source.block(r0, c0, 8.min(m - r0), 8.min(n - c0));
                for &v in block.data() {
                    h.mix(v.to_bits());
                }
                c0 += step_c;
            }
            r0 += step_r;
        }
    }
    (h, exact)
}

/// Content fingerprint of an operand (primary hash lane): dimensions plus
/// entries — exact for small operands, a deterministic probe grid for
/// large ones.
pub fn fingerprint(source: &dyn MatrixSource) -> u64 {
    content_hash(source).0 .0
}

/// Cache key: operand content hash folded with everything that shapes the
/// resident state.  Worker count is deliberately excluded — session
/// results are worker-count independent, so those lookups may share.
/// For probe-sampled (large) operands `exact` is `false` and the cache
/// additionally requires source *identity* to share a session — equal
/// probes cannot prove equal content.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct SessionKey {
    hash: HashPair,
    exact: bool,
}

pub fn session_key(
    source: &dyn MatrixSource,
    config: &SystemConfig,
    opts: &SolveOptions,
) -> SessionKey {
    session_key_with_fp(source, config, opts).0
}

/// [`session_key`] plus the content [`fingerprint`] from the same hash
/// pass — the serve front door needs both (the key for residency, the
/// fingerprint as the client-visible operand handle) without hashing the
/// operand twice.
fn session_key_with_fp(
    source: &dyn MatrixSource,
    config: &SystemConfig,
    opts: &SolveOptions,
) -> (SessionKey, u64) {
    let (mut h, exact) = content_hash(source);
    let fp = h.0;
    h.mix(config.tile_rows as u64);
    h.mix(config.tile_cols as u64);
    h.mix(config.cell_size as u64);
    let material = crate::device::materials::Material::ALL
        .iter()
        .position(|m| *m == opts.material)
        .unwrap_or(0) as u64;
    h.mix(material);
    h.mix(opts.seed);
    h.mix(opts.ec as u64);
    let denoise = match opts.denoise {
        DenoiseMode::InMemory => 0u64,
        DenoiseMode::Digital => 1,
        DenoiseMode::Off => 2,
    };
    h.mix(denoise);
    h.mix(opts.lambda.to_bits());
    h.mix(opts.h.to_bits());
    h.mix(opts.wv_iters as u64);
    h.mix(opts.wv_rel_tol.to_bits());
    h.mix(opts.wv_norm_inf as u64);
    // Extended non-idealities shape both the resident image (drift and IR
    // drop bake in at program time) and every read-out (ADC), so they must
    // split keys too.
    h.mix(opts.nonideal.adc.bits as u64);
    h.mix(opts.nonideal.drift.nu.to_bits());
    h.mix(opts.nonideal.drift.elapsed.to_bits());
    h.mix(opts.nonideal.ir_drop.alpha.to_bits());
    (SessionKey { hash: h, exact }, fp)
}

struct CacheEntry {
    key: SessionKey,
    /// Content fingerprint (pre-option hash lane) — the serve front
    /// door's residency handle.
    fp: u64,
    source: Arc<dyn MatrixSource>,
    last_used: u64,
    session: Arc<Session>,
}

impl CacheEntry {
    /// Content-hash equality, plus source identity when the hash was
    /// probe-sampled (a sampled hash cannot prove equal content).
    fn matches(&self, key: &SessionKey, source: &Arc<dyn MatrixSource>) -> bool {
        self.key == *key && (key.exact || Arc::ptr_eq(&self.source, source))
    }
}

/// LRU cache of resident sessions (multi-tenant serving) sharing one
/// execution plane.
pub struct OperandCache {
    capacity: usize,
    entries: Vec<CacheEntry>,
    /// The shared plane hosting every cached residency; built lazily from
    /// the first tenant, rebuilt if it fails.
    plane: Option<PlaneHandle>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Times the shared plane was (re)built after a failure.
    pub rebuilds: u64,
}

impl OperandCache {
    /// A cache keeping at most `capacity` operands resident.
    pub fn new(capacity: usize) -> OperandCache {
        assert!(capacity > 0, "cache capacity must be positive");
        OperandCache {
            capacity,
            entries: Vec::new(),
            plane: None,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            rebuilds: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The shared plane hosting the cached residencies (None until the
    /// first tenant is programmed).
    pub fn plane(&self) -> Option<&PlaneHandle> {
        self.plane.as_ref()
    }

    /// Drop the shared plane and every session bound to it if the pool
    /// has failed (a shard panicked), so neither the hit nor the miss
    /// path can ever hand out a session wired to a dead pool.
    fn invalidate_failed_plane(&mut self) {
        let dead = self
            .plane
            .as_ref()
            .map(|p| p.failure().is_some())
            .unwrap_or(false);
        if dead {
            self.evictions += self.entries.len() as u64;
            note_cache(
                obs::names::CACHE_EVICTIONS,
                "Operand-cache evictions",
                self.entries.len() as u64,
            );
            self.entries.clear();
            self.plane = None;
            self.rebuilds += 1;
            note_cache(
                obs::names::CACHE_REBUILDS,
                "Operand-cache plane rebuilds after failure",
                1,
            );
        }
    }

    /// The shared plane, building it on first use (and after a failure
    /// cleared it).
    fn live_plane(
        &mut self,
        solver: &Meliso,
        source: &Arc<dyn MatrixSource>,
    ) -> Result<PlaneHandle, MelisoError> {
        if let Some(plane) = &self.plane {
            return Ok(plane.clone());
        }
        let plane = solver.build_plane(source.as_ref())?;
        self.plane = Some(plane.clone());
        Ok(plane)
    }

    /// Return the resident session for `source` under the solver's
    /// configuration, programming it onto the shared plane (and evicting
    /// the LRU tenant) on miss.
    ///
    /// Eviction is transactional: the LRU entry is *displaced* but held
    /// through the first open attempt, so a failed open restores it
    /// instead of losing a programmed tenant.  If the open fails while a
    /// displaced tenant exists (e.g. "out of tile slots" under a
    /// `SystemConfig::tile_slots` cap), the displaced residency is
    /// dropped for real and the open retried once.  Note the residency's
    /// tile slots return to the allocator only when the **last**
    /// `Arc<Session>` drops — callers that hold sessions past their use
    /// keep those slots pinned.
    pub fn get_or_open(
        &mut self,
        solver: &Meliso,
        source: &Arc<dyn MatrixSource>,
    ) -> Result<Arc<Session>, MelisoError> {
        self.invalidate_failed_plane();
        let (key, fp) = session_key_with_fp(source.as_ref(), solver.config(), solver.options());
        self.clock += 1;
        if let Some(entry) = self.entries.iter_mut().find(|e| e.matches(&key, source)) {
            entry.last_used = self.clock;
            self.hits += 1;
            note_cache(obs::names::CACHE_HITS, "Operand-cache session reuses", 1);
            return Ok(entry.session.clone());
        }
        self.misses += 1;
        note_cache(
            obs::names::CACHE_MISSES,
            "Operand-cache programming misses",
            1,
        );
        let plane = self.live_plane(solver, source)?;
        let mut displaced: Option<CacheEntry> = None;
        if self.entries.len() >= self.capacity {
            // `min_by_key` is `None` only for an empty entry list (a
            // zero-capacity cache): nothing to displace, every miss
            // programs fresh.
            if let Some(lru) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
            {
                displaced = Some(self.entries.swap_remove(lru));
            }
        }
        let session = match Session::open_on(plane.clone(), source.clone()) {
            Ok(session) => session,
            Err(first_err) => match displaced.take() {
                // Nothing was displaced: fail with nothing lost.
                None => return Err(first_err.into()),
                // Drop the displaced residency for real (freeing its tile
                // slots, unless an outside handle pins them) and retry.
                Some(entry) => {
                    drop(entry);
                    self.evictions += 1;
                    note_cache(obs::names::CACHE_EVICTIONS, "Operand-cache evictions", 1);
                    Session::open_on(plane, source.clone())?
                }
            },
        };
        if displaced.take().is_some() {
            self.evictions += 1;
            note_cache(obs::names::CACHE_EVICTIONS, "Operand-cache evictions", 1);
        }
        let session = Arc::new(session);
        self.entries.push(CacheEntry {
            key,
            fp,
            source: source.clone(),
            last_used: self.clock,
            session: session.clone(),
        });
        Ok(session)
    }

    /// Whether an operand is currently resident (does not touch LRU order).
    pub fn contains(&self, solver: &Meliso, source: &Arc<dyn MatrixSource>) -> bool {
        let key = session_key(source.as_ref(), solver.config(), solver.options());
        self.entries.iter().any(|e| e.matches(&key, source))
    }

    /// Fast-path lookup by content [`fingerprint`] — the serve front
    /// door's residency handle.  Bumps LRU recency and counts a hit; a
    /// `None` return is *not* counted as a miss (the caller falls back to
    /// [`get_or_open`](Self::get_or_open), which counts it).  A failed
    /// plane is invalidated first, so this can never hand out a session
    /// wired to a dead pool.
    pub fn find_by_fingerprint(&mut self, fp: u64) -> Option<Arc<Session>> {
        self.invalidate_failed_plane();
        self.clock += 1;
        let clock = self.clock;
        let session = {
            let entry = self.entries.iter_mut().find(|e| e.fp == fp)?;
            entry.last_used = clock;
            entry.session.clone()
        };
        self.hits += 1;
        note_cache(obs::names::CACHE_HITS, "Operand-cache session reuses", 1);
        Some(session)
    }

    /// Evict the residency with content fingerprint `fp` (serve front
    /// door `DELETE`).  Returns whether anything was resident.  Tile
    /// slots return to the plane allocator when the last outstanding
    /// `Arc<Session>` drops.
    pub fn evict_by_fingerprint(&mut self, fp: u64) -> bool {
        match self.entries.iter().position(|e| e.fp == fp) {
            Some(i) => {
                self.entries.swap_remove(i);
                self.evictions += 1;
                note_cache(obs::names::CACHE_EVICTIONS, "Operand-cache evictions", 1);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::materials::Material;
    use crate::linalg::{Matrix, Vector};
    use crate::matrices::DenseSource;
    use crate::runtime::native::NativeBackend;

    fn solver() -> Meliso {
        Meliso::with_backend(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
            Arc::new(NativeBackend::new()),
        )
    }

    fn operand(seed: u64) -> Arc<dyn MatrixSource> {
        Arc::new(DenseSource::new(Matrix::standard_normal(16, 16, seed)))
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = operand(1);
        let same = operand(1);
        let other = operand(2);
        assert_eq!(fingerprint(a.as_ref()), fingerprint(same.as_ref()));
        assert_ne!(fingerprint(a.as_ref()), fingerprint(other.as_ref()));
    }

    #[test]
    fn session_key_tracks_options() {
        let a = operand(3);
        let cfg = SystemConfig::single_mca(32);
        let base = SolveOptions::default();
        let k = session_key(a.as_ref(), &cfg, &base);
        assert_eq!(k, session_key(a.as_ref(), &cfg, &base.clone()));
        assert_ne!(k, session_key(a.as_ref(), &cfg, &base.clone().with_seed(9)));
        assert_ne!(
            k,
            session_key(a.as_ref(), &cfg, &base.clone().with_ec(false))
        );
        assert_ne!(
            k,
            session_key(a.as_ref(), &cfg, &base.clone().with_device(Material::AgASi))
        );
        // Non-idealities shape the resident image and read-outs.
        use crate::device::nonideal::{AdcModel, NonIdealExt};
        let quantized = base.clone().with_nonideal(NonIdealExt {
            adc: AdcModel::new(4),
            ..NonIdealExt::default()
        });
        assert_ne!(k, session_key(a.as_ref(), &cfg, &quantized));
        // Worker count does not change results, so it must not split keys.
        assert_eq!(k, session_key(a.as_ref(), &cfg, &base.with_workers(9)));
    }

    #[test]
    fn sampled_fingerprints_require_identity() {
        use crate::matrices::BandedSource;
        let cfg = SystemConfig::single_mca(32);
        let opts = SolveOptions::default();
        // Small operands hash every entry: content equality is proven.
        assert!(session_key(operand(1).as_ref(), &cfg, &opts).exact);
        // Large operands are probe-sampled: hashes agree but `exact` is
        // false, so CacheEntry::matches additionally demands identity.
        let big_a: Arc<dyn MatrixSource> =
            Arc::new(BandedSource::new(4096, 4, 1.0, 10.0, 0.2, 3));
        let big_b: Arc<dyn MatrixSource> =
            Arc::new(BandedSource::new(4096, 4, 1.0, 10.0, 0.2, 3));
        let ka = session_key(big_a.as_ref(), &cfg, &opts);
        let kb = session_key(big_b.as_ref(), &cfg, &opts);
        assert_eq!(ka, kb);
        assert!(!ka.exact);
        let entry = CacheEntry {
            key: ka,
            fp: fingerprint(big_a.as_ref()),
            source: big_a.clone(),
            last_used: 0,
            session: Arc::new(
                solver()
                    .open_session(operand(1))
                    .expect("session for entry"),
            ),
        };
        assert!(entry.matches(&ka, &big_a));
        assert!(!entry.matches(&kb, &big_b));
    }

    #[test]
    fn cache_hits_and_reuses_sessions() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let a = operand(5);
        let s1 = cache.get_or_open(&solver, &a).unwrap();
        let s2 = cache.get_or_open(&solver, &a).unwrap();
        assert!(Arc::ptr_eq(&s1, &s2));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        // The cached session actually serves.
        let x = Vector::standard_normal(16, 6);
        assert!(s2.solve(&x).is_ok());
    }

    #[test]
    fn fingerprint_lookup_finds_and_evicts_residencies() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let a = operand(21);
        let fp = fingerprint(a.as_ref());
        assert!(cache.find_by_fingerprint(fp).is_none());
        let s1 = cache.get_or_open(&solver, &a).unwrap();
        let s2 = cache.find_by_fingerprint(fp).expect("resident after open");
        assert!(Arc::ptr_eq(&s1, &s2));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert!(cache.evict_by_fingerprint(fp));
        assert!(!cache.evict_by_fingerprint(fp));
        assert!(cache.find_by_fingerprint(fp).is_none());
        assert_eq!(cache.evictions, 1);
    }

    #[test]
    fn fingerprint_lookup_bumps_lru_recency() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let (a, b, c) = (operand(31), operand(32), operand(33));
        cache.get_or_open(&solver, &a).unwrap();
        cache.get_or_open(&solver, &b).unwrap();
        // Touch `a` through the fingerprint path, then insert a third
        // tenant: `b` (now LRU) must be the one displaced.
        cache
            .find_by_fingerprint(fingerprint(a.as_ref()))
            .expect("a resident");
        cache.get_or_open(&solver, &c).unwrap();
        assert!(cache.contains(&solver, &a));
        assert!(!cache.contains(&solver, &b));
        assert!(cache.contains(&solver, &c));
    }

    #[test]
    fn fingerprint_stable_across_identical_builds() {
        // The same registry operand built twice is a different allocation
        // with identical content: fingerprints and full session keys must
        // agree, so a re-built tenant hits the cache.
        use crate::matrices::registry;
        let a1 = registry::build("bcsstk02").unwrap();
        let a2 = registry::build("bcsstk02").unwrap();
        assert!(!Arc::ptr_eq(&a1, &a2));
        assert_eq!(fingerprint(a1.as_ref()), fingerprint(a2.as_ref()));
        let cfg = SystemConfig::single_mca(128);
        let opts = SolveOptions::default();
        let k1 = session_key(a1.as_ref(), &cfg, &opts);
        let k2 = session_key(a2.as_ref(), &cfg, &opts);
        assert_eq!(k1, k2);
        assert!(k1.exact, "66² operands hash every entry");
        // A different operand keeps a different fingerprint.
        let other = registry::build("iperturb66").unwrap();
        assert_ne!(fingerprint(a1.as_ref()), fingerprint(other.as_ref()));
    }

    #[test]
    fn rebuilt_operand_hits_the_cache() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let s1 = cache.get_or_open(&solver, &operand(21)).unwrap();
        let s2 = cache.get_or_open(&solver, &operand(21)).unwrap();
        assert!(Arc::ptr_eq(&s1, &s2));
        assert_eq!((cache.hits, cache.misses), (1, 1));
    }

    #[test]
    fn eviction_follows_lru_order_under_pressure() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let (a, b, c, d) = (operand(31), operand(32), operand(33), operand(34));
        cache.get_or_open(&solver, &a).unwrap();
        cache.get_or_open(&solver, &b).unwrap();
        // Full at capacity 2: inserting c evicts a (the LRU).
        cache.get_or_open(&solver, &c).unwrap();
        assert_eq!(cache.evictions, 1);
        assert!(!cache.contains(&solver, &a));
        assert!(cache.contains(&solver, &b));
        assert!(cache.contains(&solver, &c));
        // Touch b so c becomes LRU; inserting d must evict c, not b.
        cache.get_or_open(&solver, &b).unwrap();
        cache.get_or_open(&solver, &d).unwrap();
        assert_eq!(cache.evictions, 2);
        assert!(cache.contains(&solver, &b));
        assert!(!cache.contains(&solver, &c));
        assert!(cache.contains(&solver, &d));
        assert_eq!(cache.len(), 2);
        // Re-opening an evicted tenant is a miss that programs again.
        let misses = cache.misses;
        cache.get_or_open(&solver, &a).unwrap();
        assert_eq!(cache.misses, misses + 1);
        assert_eq!(cache.evictions, 3);
    }

    #[test]
    fn cached_sessions_share_one_plane() {
        let solver = solver();
        let mut cache = OperandCache::new(4);
        let s1 = cache.get_or_open(&solver, &operand(61)).unwrap();
        let s2 = cache.get_or_open(&solver, &operand(62)).unwrap();
        assert!(
            PlaneHandle::ptr_eq(s1.plane(), s2.plane()),
            "cache tenants must be residencies of one plane"
        );
        let plane = cache.plane().expect("plane built on first miss").clone();
        assert_eq!(plane.resident_operands(), 2);
        // Evicting a tenant (capacity pressure elsewhere) frees its
        // residency once the last session handle drops.
        drop(s1);
        cache.entries.remove(0);
        assert_eq!(plane.resident_operands(), 1);
        assert!(s2.solve(&Vector::standard_normal(16, 63)).is_ok());
    }

    #[test]
    fn cache_rebuilds_plane_after_shard_failure() {
        use crate::testing::faults::FaultBackend;
        let backend = FaultBackend::panicking(NativeBackend::new());
        let handle = backend.handle();
        let solver = Meliso::with_backend(
            SystemConfig::single_mca(32),
            SolveOptions::default().with_device(Material::EpiRam),
            Arc::new(backend),
        );
        let mut cache = OperandCache::new(2);
        let a = operand(71);
        let s = cache.get_or_open(&solver, &a).unwrap();
        // Kill the shared pool with an injected shard panic.
        handle.fail_next_reads(true);
        let err = s.solve(&Vector::standard_normal(16, 72)).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        handle.fail_next_reads(false);
        drop(s);
        // Looking the SAME (cached) operand up again must not hand back a
        // session wired to the dead pool: the hit path invalidates first,
        // rebuilds, and programs afresh.
        let s2 = cache.get_or_open(&solver, &a).unwrap();
        assert_eq!(cache.rebuilds, 1);
        assert!(s2.solve(&Vector::standard_normal(16, 74)).is_ok());
        // And other tenants land on the same fresh plane.
        let b = operand(73);
        let s3 = cache.get_or_open(&solver, &b).unwrap();
        assert_eq!(cache.rebuilds, 1);
        assert!(PlaneHandle::ptr_eq(s2.plane(), s3.plane()));
        assert!(s3.solve(&Vector::standard_normal(16, 75)).is_ok());
    }

    #[test]
    fn cache_evicts_least_recently_used() {
        let solver = solver();
        let mut cache = OperandCache::new(2);
        let (a, b, c) = (operand(7), operand(8), operand(9));
        cache.get_or_open(&solver, &a).unwrap();
        cache.get_or_open(&solver, &b).unwrap();
        // Touch `a` so `b` becomes LRU, then insert `c`.
        cache.get_or_open(&solver, &a).unwrap();
        cache.get_or_open(&solver, &c).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions, 1);
        assert!(cache.contains(&solver, &a));
        assert!(!cache.contains(&solver, &b));
        assert!(cache.contains(&solver, &c));
    }
}
