//! Serving layer: program-once / solve-many resident crossbar sessions.
//!
//! Writing conductances is the expensive operation on RRAM; reading them
//! is nearly free.  The one-shot [`crate::coordinator`] re-programs the
//! whole operand on every call — correct for benchmarking a single MVM,
//! but orders of magnitude wasteful for the dominant serving pattern of
//! many solves against the same operand.  This module keeps operands
//! *resident*:
//!
//! * [`Session`] — one operand programmed through a single write–verify
//!   pass as a *residency* on a sharded
//!   [`crate::plane::ExecutionPlane`] (the same scatter/gather machinery
//!   the one-shot coordinator uses) whose [`crate::ec::TileExecutor`]s
//!   (fixed-pattern noise, energy ledgers) persist across calls;
//!   [`Session::solve`] and [`Session::solve_batch`] then pay only
//!   input-vector encodes and crossbar reads.  Planes are multi-tenant:
//!   [`Session::open_on`] /
//!   [`crate::solver::Meliso::open_session_on`] program additional
//!   operands onto an existing plane, so N tenants share one shard pool
//!   instead of spinning up N.
//! * [`OperandCache`] — multi-tenant residency: an LRU cache of sessions
//!   keyed by operand [`fingerprint`] + programming options, all hosted
//!   on one shared plane whose tile slots recycle across evictions (and
//!   which is transparently rebuilt if a shard panic poisons it).
//! * Serving metrics — throughput, p50/p99 latency, and the
//!   write-once/read-per-solve energy split, in
//!   [`crate::metrics::serving`].
//!
//! Entry points: [`crate::solver::Meliso::open_session`] (dedicated
//! plane) and [`crate::solver::Meliso::open_session_on`] (shared plane).
//! The CLI exposes `meliso serve-bench` (multi-operand via `--operands`),
//! and `benches/serving_throughput.rs` quantifies the amortization
//! against repeated one-shot solves.
//!
//! ```
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("iperturb66").unwrap();
//! let opts = SolveOptions::default().with_backend(BackendKind::Native);
//! let solver = Meliso::new(SystemConfig::single_mca(128), opts).unwrap();
//! let session = solver.open_session(a.clone()).unwrap(); // write-verify once
//! let xs: Vec<Vector> = (0..4).map(|s| Vector::standard_normal(66, s)).collect();
//! let outs = session.solve_batch(&xs).unwrap();          // reads only
//! assert_eq!(outs.len(), 4);
//! assert_eq!(session.report().solves, 4);
//! ```

pub mod cache;
pub mod session;

pub use cache::{fingerprint, session_key, OperandCache, SessionKey};
pub use session::{
    exec_stream_seed, MvmOperator, OperandId, ProgramReport, ServeSolve, Session,
};
