//! Serving layer: program-once / solve-many resident crossbar sessions.
//!
//! Writing conductances is the expensive operation on RRAM; reading them
//! is nearly free.  The one-shot [`crate::coordinator`] re-programs the
//! whole operand on every call — correct for benchmarking a single MVM,
//! but orders of magnitude wasteful for the dominant serving pattern of
//! many solves against the same operand.  This module keeps operands
//! *resident*:
//!
//! * [`Session`] — one operand programmed onto the MCA grid through a
//!   single write–verify pass, held resident by the shared sharded
//!   [`crate::plane::ExecutionPlane`] (the same scatter/gather machinery
//!   the one-shot coordinator uses) whose [`crate::ec::TileExecutor`]s
//!   (fixed-pattern noise, energy ledgers) persist across calls;
//!   [`Session::solve`] and [`Session::solve_batch`] then pay only
//!   input-vector encodes and crossbar reads.
//! * [`OperandCache`] — multi-tenant residency: an LRU cache of sessions
//!   keyed by operand [`fingerprint`] + programming options.
//! * Serving metrics — throughput, p50/p99 latency, and the
//!   write-once/read-per-solve energy split, in
//!   [`crate::metrics::serving`].
//!
//! Entry point: [`crate::solver::Meliso::open_session`].  The CLI exposes
//! `meliso serve-bench`, and `benches/serving_throughput.rs` quantifies
//! the amortization against repeated one-shot solves.

pub mod cache;
pub mod session;

pub use cache::{fingerprint, session_key, OperandCache, SessionKey};
pub use session::{exec_stream_seed, MvmOperator, ProgramReport, ServeSolve, Session};
