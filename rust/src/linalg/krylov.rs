//! Krylov-subspace workspace: the Arnoldi process with on-the-fly Givens
//! QR of the Hessenberg matrix, as used by restarted GMRES.
//!
//! The workspace is backend-agnostic pure `f64` host math — the caller
//! supplies `w = A·v` for the newest basis vector, whether `A` is an exact
//! matrix or a resident crossbar session.  After each [`expand`] the
//! least-squares residual `min‖βe₁ − H̄y‖` is available without forming a
//! solution, so an iterative solver can stop the moment the estimate drops
//! under tolerance and only then pay the back substitution in
//! [`solution`].
//!
//! [`expand`]: KrylovWorkspace::expand
//! [`solution`]: KrylovWorkspace::solution

use crate::linalg::Vector;

/// Relative threshold under which the Arnoldi normalization step declares
/// a (lucky) breakdown: the Krylov space is exhausted and the current
/// least-squares solution is exact.
const BREAKDOWN_RTOL: f64 = 1e-12;

/// Givens rotation `(c, s)` annihilating `b` against `a`.
fn givens(a: f64, b: f64) -> (f64, f64) {
    if b == 0.0 {
        (1.0, 0.0)
    } else {
        let t = a.hypot(b);
        (a / t, b / t)
    }
}

/// Arnoldi basis + rotated Hessenberg factors for one GMRES cycle.
pub struct KrylovWorkspace {
    max_dim: usize,
    /// Orthonormal basis `v₀ … v_k` (modified Gram–Schmidt).
    basis: Vec<Vector>,
    /// Columns of the upper-triangular `R` (column `j` holds `j+1` rows).
    r_cols: Vec<Vec<f64>>,
    /// Accumulated Givens rotations.
    cs: Vec<f64>,
    sn: Vec<f64>,
    /// Rotated right-hand side `βe₁`.
    g: Vec<f64>,
    happy: bool,
}

impl KrylovWorkspace {
    /// A workspace for at most `max_dim` Arnoldi steps per cycle.
    pub fn new(max_dim: usize) -> KrylovWorkspace {
        assert!(max_dim >= 1, "krylov dimension must be at least 1");
        KrylovWorkspace {
            max_dim,
            basis: Vec::new(),
            r_cols: Vec::new(),
            cs: Vec::new(),
            sn: Vec::new(),
            g: Vec::new(),
            happy: false,
        }
    }

    /// Start a cycle from residual `r0`; returns `β = ‖r0‖` (zero means
    /// the residual is already exact and the workspace stays empty).
    pub fn reset(&mut self, r0: &Vector) -> f64 {
        self.basis.clear();
        self.r_cols.clear();
        self.cs.clear();
        self.sn.clear();
        self.g.clear();
        self.happy = false;
        let beta = r0.norm_l2();
        if beta > 0.0 {
            let mut v = r0.clone();
            v.scale(1.0 / beta);
            self.basis.push(v);
            self.g.push(beta);
        }
        beta
    }

    /// Completed Arnoldi steps this cycle.
    pub fn size(&self) -> usize {
        self.r_cols.len()
    }

    /// Whether another [`expand`](Self::expand) is admissible.
    pub fn can_expand(&self) -> bool {
        !self.happy && !self.basis.is_empty() && self.r_cols.len() < self.max_dim
    }

    /// The newest basis vector — multiply it by `A` and feed the product
    /// to [`expand`](Self::expand).
    pub fn last(&self) -> &Vector {
        self.basis.last().expect("reset with a nonzero residual first")
    }

    /// Lucky breakdown: the span is invariant and the least-squares
    /// solution solves the system exactly (up to the products' accuracy).
    pub fn breakdown(&self) -> bool {
        self.happy
    }

    /// One Arnoldi step with `w = A · last()`: modified Gram–Schmidt
    /// orthogonalization, Givens update of the new Hessenberg column, and
    /// the updated least-squares residual norm as return value.
    pub fn expand(&mut self, mut w: Vector) -> f64 {
        assert!(self.can_expand(), "workspace cannot expand");
        let j = self.r_cols.len();
        let mut h = vec![0.0; j + 2];
        for (i, hi) in h.iter_mut().enumerate().take(j + 1) {
            let hij = w.dot(&self.basis[i]);
            *hi = hij;
            w.axpy(-hij, &self.basis[i]);
        }
        let hnorm = w.norm_l2();
        h[j + 1] = hnorm;
        // Previously accumulated rotations on the new column.
        for i in 0..j {
            let (c, s) = (self.cs[i], self.sn[i]);
            let t = c * h[i] + s * h[i + 1];
            h[i + 1] = -s * h[i] + c * h[i + 1];
            h[i] = t;
        }
        let col_scale = h.iter().take(j + 1).fold(hnorm, |m, v| m.max(v.abs()));
        // New rotation annihilating the subdiagonal.
        let (c, s) = givens(h[j], h[j + 1]);
        let rjj = c * h[j] + s * h[j + 1];
        self.cs.push(c);
        self.sn.push(s);
        let gj = self.g[j];
        self.g[j] = c * gj;
        self.g.push(-s * gj);
        let mut col = h[..j].to_vec();
        col.push(rjj);
        self.r_cols.push(col);
        if hnorm <= col_scale * BREAKDOWN_RTOL {
            self.happy = true;
        } else {
            w.scale(1.0 / hnorm);
            self.basis.push(w);
        }
        self.g[j + 1].abs()
    }

    /// Back-substitute `Ry = g` and assemble the update `Σ yⱼ vⱼ`.
    /// Requires at least one completed step ([`size`](Self::size) > 0).
    pub fn solution(&self) -> Vector {
        let k = self.r_cols.len();
        assert!(k > 0, "no Arnoldi steps completed");
        let mut y: Vec<f64> = self.g[..k].to_vec();
        for j in (0..k).rev() {
            let rjj = self.r_cols[j][j];
            if rjj == 0.0 {
                y[j] = 0.0;
            } else {
                y[j] /= rjj;
            }
            for i in 0..j {
                y[i] -= self.r_cols[j][i] * y[j];
            }
        }
        let mut x = Vector::zeros(self.basis[0].len());
        for (j, yj) in y.iter().enumerate() {
            x.axpy(*yj, &self.basis[j]);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu::Lu;
    use crate::linalg::Matrix;

    /// Full (unrestarted) GMRES on an exact matrix via the workspace.
    fn gmres_exact(a: &Matrix, b: &Vector, steps: usize) -> (Vector, f64) {
        let mut ws = KrylovWorkspace::new(steps);
        ws.reset(b);
        let mut est = b.norm_l2();
        while ws.can_expand() {
            let w = a.matvec(ws.last());
            est = ws.expand(w);
        }
        (ws.solution(), est)
    }

    #[test]
    fn identity_breaks_down_immediately() {
        let a = Matrix::identity(6);
        let b = Vector::standard_normal(6, 3);
        let mut ws = KrylovWorkspace::new(6);
        let beta = ws.reset(&b);
        assert!(beta > 0.0);
        let est = ws.expand(a.matvec(ws.last()));
        assert!(ws.breakdown());
        assert!(est < 1e-12 * beta, "{est}");
        // x = b solves Ix = b.
        let x = ws.solution();
        let err = x.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 1e-12, "{err}");
    }

    #[test]
    fn full_cycle_matches_lu_solve() {
        let n = 24;
        let a = crate::matrices::generators::dense_spd_with_condition(n, 3.0, 50.0, 6, 11);
        let x_star = Vector::standard_normal(n, 12);
        let b = a.matvec(&x_star);
        let (x, est) = gmres_exact(&a, &b, n);
        let exact = Lu::factor(&a).unwrap().solve(&b);
        let err = x.sub(&exact).norm_l2() / exact.norm_l2();
        assert!(err < 1e-8, "err {err}, estimate {est}");
    }

    #[test]
    fn residual_estimate_is_monotone_nonincreasing() {
        let n = 16;
        let a = Matrix::standard_normal(n, n, 21);
        let b = Vector::standard_normal(n, 22);
        let mut ws = KrylovWorkspace::new(n);
        let mut prev = ws.reset(&b);
        while ws.can_expand() {
            let est = ws.expand(a.matvec(ws.last()));
            assert!(est <= prev + 1e-12, "{est} > {prev}");
            prev = est;
        }
    }

    #[test]
    fn zero_residual_stays_empty() {
        let mut ws = KrylovWorkspace::new(4);
        let beta = ws.reset(&Vector::zeros(5));
        assert_eq!(beta, 0.0);
        assert_eq!(ws.size(), 0);
        assert!(!ws.can_expand());
    }

    #[test]
    fn basis_stays_orthonormal() {
        let n = 20;
        let a = Matrix::standard_normal(n, n, 31);
        let b = Vector::standard_normal(n, 32);
        let mut ws = KrylovWorkspace::new(8);
        ws.reset(&b);
        while ws.can_expand() {
            ws.expand(a.matvec(ws.last()));
        }
        for i in 0..ws.basis.len() {
            for j in 0..ws.basis.len() {
                let d = ws.basis[i].dot(&ws.basis[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-10, "({i},{j}): {d}");
            }
        }
    }
}
