//! Thomas-algorithm solver for the denoiser's SPD tridiagonal systems.
//!
//! The second-order error-correction operator is `(I + λLᵀL)⁻¹` with `L`
//! bidiagonal (diag 1, superdiag h).  `LᵀL` is tridiagonal, so both the
//! digital denoise path and the construction of the explicit inverse (for
//! the paper's in-memory denoise, which encodes the inverse onto a crossbar)
//! reduce to O(n) tridiagonal solves.

use crate::linalg::{Matrix, Vector};

/// A symmetric tridiagonal system `T = diag(d) + offdiag(e)`.
#[derive(Clone, Debug)]
pub struct Tridiag {
    /// Main diagonal, length n.
    pub d: Vec<f64>,
    /// Off diagonal (sub == super by symmetry), length n-1.
    pub e: Vec<f64>,
}

impl Tridiag {
    /// Build `I + λ LᵀL` for the paper's first-order difference matrix
    /// (Eq. 9): `L = I + h·superdiag`, default `h = -1`.
    ///
    /// `LᵀL` has diagonal `[1, 1+h², ..., 1+h²]` and off-diagonal `h`.
    pub fn denoise_operator(n: usize, lambda: f64, h: f64) -> Tridiag {
        assert!(n > 0);
        let mut d = vec![1.0 + lambda * (1.0 + h * h); n];
        d[0] = 1.0 + lambda; // first column of L has no h above it
        let e = vec![lambda * h; n.saturating_sub(1)];
        Tridiag { d, e }
    }

    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// Multiply `T x` (used by tests to verify solves).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(x.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = self.d[i] * x[i];
            if i > 0 {
                acc += self.e[i - 1] * x[i - 1];
            }
            if i + 1 < n {
                acc += self.e[i] * x[i + 1];
            }
            y[i] = acc;
        }
        y
    }

    /// Solve `T y = b` with the Thomas algorithm (no pivoting; valid for the
    /// strictly diagonally dominant SPD operators we build).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        if n == 1 {
            return vec![b[0] / self.d[0]];
        }
        let mut c = vec![0.0; n - 1]; // modified superdiagonal
        let mut y = vec![0.0; n]; // modified rhs
        c[0] = self.e[0] / self.d[0];
        y[0] = b[0] / self.d[0];
        for i in 1..n {
            let m = self.d[i] - self.e[i - 1] * c[i - 1];
            if i < n - 1 {
                c[i] = self.e[i] / m;
            }
            y[i] = (b[i] - self.e[i - 1] * y[i - 1]) / m;
        }
        for i in (0..n - 1).rev() {
            y[i] -= c[i] * y[i + 1];
        }
        y
    }

    /// Materialize the explicit inverse `T⁻¹` column by column (O(n²) total).
    ///
    /// This is the matrix the paper *encodes onto the crossbar* for the
    /// in-memory second-order correction; it is cached per tile size.
    pub fn inverse(&self) -> Matrix {
        let n = self.n();
        let mut inv = Matrix::zeros(n, n);
        let mut unit = vec![0.0; n];
        for j in 0..n {
            unit[j] = 1.0;
            let col = self.solve(&unit);
            unit[j] = 0.0;
            for (i, v) in col.iter().enumerate() {
                inv.set(i, j, *v);
            }
        }
        inv
    }

    /// Digital denoise: `y = T⁻¹ p` without materializing the inverse.
    pub fn denoise(&self, p: &Vector) -> Vector {
        Vector::from_vec(self.solve(p.data()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn solve_inverts_matvec() {
        let t = Tridiag::denoise_operator(64, 0.25, -1.0);
        let x: Vec<f64> = (0..64).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = t.matvec(&x);
        let got = t.solve(&b);
        assert!(max_abs_diff(&got, &x) < 1e-10);
    }

    #[test]
    fn tiny_lambda_is_near_identity() {
        let t = Tridiag::denoise_operator(16, 1e-12, -1.0);
        let b: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let y = t.solve(&b);
        assert!(max_abs_diff(&y, &b) < 1e-9);
    }

    #[test]
    fn inverse_matches_solve() {
        let t = Tridiag::denoise_operator(12, 0.1, -1.0);
        let inv = t.inverse();
        let b = Vector::standard_normal(12, 4);
        let via_solve = t.solve(b.data());
        let via_inv = inv.matvec(&b);
        assert!(max_abs_diff(&via_solve, via_inv.data()) < 1e-10);
    }

    #[test]
    fn inverse_times_operator_is_identity() {
        let n = 10;
        let t = Tridiag::denoise_operator(n, 0.3, -1.0);
        let inv = t.inverse();
        // T_dense from matvec on unit vectors.
        let mut unit = vec![0.0; n];
        for j in 0..n {
            unit[j] = 1.0;
            let t_col = t.matvec(&unit);
            unit[j] = 0.0;
            let e_j = inv.matvec(&Vector::from_vec(t_col));
            for (i, v) in e_j.data().iter().enumerate() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((v - want).abs() < 1e-10, "({i},{j}) -> {v}");
            }
        }
    }

    #[test]
    fn n1_edge_case() {
        let t = Tridiag::denoise_operator(1, 0.5, -1.0);
        let y = t.solve(&[3.0]);
        assert!((y[0] - 3.0 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn denoise_operator_spd() {
        // Gershgorin: diag > |offdiag sum| for every row when |h| = 1, λ>0.
        let t = Tridiag::denoise_operator(32, 0.7, -1.0);
        for i in 0..32 {
            let mut off = 0.0;
            if i > 0 {
                off += t.e[i - 1].abs();
            }
            if i + 1 < 32 {
                off += t.e[i].abs();
            }
            assert!(t.d[i] > off - 1e-12, "row {i} not dominant");
        }
    }
}
