//! Spectral-norm and condition-number estimation.
//!
//! Power iteration on `AᵀA` gives `σ_max = ‖A‖₂`; inverse iteration through
//! an LU solve gives `σ_min`; their ratio is `κ(A)`.  Used to validate that
//! the synthetic SuiteSparse stand-ins hit the paper's Table 2 targets.

use crate::linalg::lu::Lu;
use crate::linalg::{Matrix, Vector};
use crate::util::rng::Rng;

/// Estimate the spectral norm `‖A‖₂` via power iteration on `AᵀA`.
pub fn spectral_norm(a: &Matrix, iters: usize, seed: u64) -> f64 {
    let n = a.ncols();
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let mut v = Vector::from_vec(v);
    normalize(&mut v);
    let at = a.transpose();
    let mut sigma = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        sigma = av.norm_l2();
        let mut w = at.matvec(&av);
        if w.norm_l2() == 0.0 {
            return 0.0;
        }
        normalize(&mut w);
        v = w;
    }
    sigma
}

/// Estimate the smallest singular value via inverse power iteration
/// (`(AᵀA)⁻¹` applied through two LU solves per step).
pub fn smallest_singular(a: &Matrix, iters: usize, seed: u64) -> Option<f64> {
    let n = a.ncols();
    assert_eq!(a.nrows(), n, "σ_min estimation expects square A");
    let lu = Lu::factor(a).ok()?;
    let at = a.transpose();
    let lut = Lu::factor(&at).ok()?;
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let mut v = Vector::from_vec(v);
    normalize(&mut v);
    let mut sigma_inv = 0.0;
    for _ in 0..iters {
        // w = A⁻ᵀ A⁻¹ v  (inverse of AᵀA applied to v)
        let w1 = lu.solve(&v);
        let mut w = lut.solve(&w1);
        sigma_inv = w.norm_l2().sqrt();
        if w.norm_l2() == 0.0 {
            return None;
        }
        normalize(&mut w);
        v = w;
    }
    // After convergence ‖(AᵀA)⁻¹ v‖ ≈ 1/σ_min².
    Some(1.0 / sigma_inv)
}

/// Estimate the 2-norm condition number κ(A) = σ_max / σ_min.
pub fn condition_number(a: &Matrix, iters: usize, seed: u64) -> Option<f64> {
    let smax = spectral_norm(a, iters, seed);
    let smin = smallest_singular(a, iters, seed.wrapping_add(1))?;
    if smin == 0.0 {
        return None;
    }
    Some(smax / smin)
}

fn normalize(v: &mut Vector) {
    let n = v.norm_l2();
    if n > 0.0 {
        for x in v.data_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Matrix::zeros(4, 4);
        for (i, s) in [3.0, 1.0, 0.5, 2.0].iter().enumerate() {
            a.set(i, i, *s);
        }
        let got = spectral_norm(&a, 200, 1);
        assert!((got - 3.0).abs() < 1e-6, "{got}");
    }

    #[test]
    fn smallest_singular_of_diagonal() {
        let mut a = Matrix::zeros(4, 4);
        for (i, s) in [3.0, 1.0, 0.5, 2.0].iter().enumerate() {
            a.set(i, i, *s);
        }
        let got = smallest_singular(&a, 200, 2).unwrap();
        assert!((got - 0.5).abs() < 1e-6, "{got}");
    }

    #[test]
    fn condition_number_of_identity() {
        let a = Matrix::identity(8);
        let k = condition_number(&a, 100, 3).unwrap();
        assert!((k - 1.0).abs() < 1e-6, "{k}");
    }

    #[test]
    fn condition_number_scales() {
        let mut a = Matrix::identity(6);
        a.set(0, 0, 100.0);
        let k = condition_number(&a, 300, 4).unwrap();
        assert!((k - 100.0).abs() / 100.0 < 1e-4, "{k}");
    }

    #[test]
    fn singular_matrix_returns_none() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(smallest_singular(&a, 10, 5).is_none());
    }
}
