//! Row-major dense matrix/vector types.

use crate::util::rng::Rng;

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(
            data.len(),
            rows * cols,
            "data length {} != {rows}x{cols}",
            data.len()
        );
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// i.i.d. standard-normal entries (deterministic in `seed`).
    pub fn standard_normal(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; rows * cols];
        rng.fill_normal(&mut data);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Matrix–vector product (exact, f64).
    pub fn matvec(&self, x: &Vector) -> Vector {
        assert_eq!(self.cols, x.len(), "matvec dim mismatch");
        let mut out = vec![0.0; self.rows];
        for (i, o) in out.iter_mut().enumerate() {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.data()) {
                acc += a * b;
            }
            *o = acc;
        }
        Vector::from_vec(out)
    }

    /// Matrix–matrix product (exact, f64; O(n^3), for small/setup use only).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let orow = other.row(k).to_vec();
                let out_row = out.row_mut(i);
                for (j, &okj) in orow.iter().enumerate() {
                    out_row[j] += aik * okj;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Extract the sub-block `[r0..r0+h, c0..c0+w)`, zero-padded if it
    /// overruns the matrix bounds (virtualization's dimension matching).
    pub fn block_padded(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        let mut out = Matrix::zeros(h, w);
        if r0 >= self.rows || c0 >= self.cols {
            return out;
        }
        let hh = h.min(self.rows - r0);
        let ww = w.min(self.cols - c0);
        for i in 0..hh {
            let src = &self.row(r0 + i)[c0..c0 + ww];
            out.row_mut(i)[..ww].copy_from_slice(src);
        }
        out
    }

    /// Max |a_ij| (the per-tile conductance scale).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Entry-wise p-norm distance used by the write–verify tolerance test
    /// (`p ∈ {2, ∞}`, paper Algorithms 1–2).
    pub fn delta_norm(&self, other: &Matrix, p_inf: bool) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        if p_inf {
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max)
        } else {
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
        }
    }

    /// Fraction of exactly-zero entries (Table 2's `nzeros`).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|v| **v == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    /// Round-trip through f32 (what the PJRT boundary does).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }
}

/// Dense `f64` vector.
#[derive(Clone, Debug, PartialEq)]
pub struct Vector {
    data: Vec<f64>,
}

impl Vector {
    pub fn zeros(n: usize) -> Vector {
        Vector { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f64>) -> Vector {
        Vector { data }
    }

    /// Single observation from N(0, I_n) — the paper's input construction.
    pub fn standard_normal(n: usize, seed: u64) -> Vector {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data);
        Vector { data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.data[i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: f64) {
        self.data[i] = v;
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn slice_padded(&self, start: usize, len: usize) -> Vector {
        let mut out = vec![0.0; len];
        if start < self.data.len() {
            let take = len.min(self.data.len() - start);
            out[..take].copy_from_slice(&self.data[start..start + take]);
        }
        Vector::from_vec(out)
    }

    pub fn add_assign(&mut self, other: &Vector) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Dot product `selfᵀ · other` (f64 host-side — the iterative solvers'
    /// scalar bookkeeping never rounds through the device).
    pub fn dot(&self, other: &Vector) -> f64 {
        assert_eq!(self.len(), other.len(), "dot dim mismatch");
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// `self += alpha * x` (the BLAS axpy).
    pub fn axpy(&mut self, alpha: f64, x: &Vector) {
        assert_eq!(self.len(), x.len(), "axpy dim mismatch");
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    pub fn sub(&self, other: &Vector) -> Vector {
        assert_eq!(self.len(), other.len());
        Vector::from_vec(
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        )
    }

    pub fn norm_l2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn norm_inf(&self) -> f64 {
        self.data.iter().map(|v| v.abs()).fold(0.0, f64::max)
    }

    pub fn max_abs(&self) -> f64 {
        self.norm_inf()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn from_f32(data: &[f32]) -> Vector {
        Vector {
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let a = Matrix::identity(5);
        let x = Vector::standard_normal(5, 3);
        let y = a.matvec(&x);
        assert_eq!(y, x);
    }

    #[test]
    fn matvec_known() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = Vector::from_vec(vec![1.0, 0.0, -1.0]);
        let y = a.matvec(&x);
        assert_eq!(y.data(), &[-2.0, -2.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::standard_normal(4, 4, 1);
        let i = Matrix::identity(4);
        let prod = a.matmul(&i);
        for (x, y) in prod.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::standard_normal(3, 5, 2);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn block_padded_interior_and_edge() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let b = a.block_padded(1, 1, 2, 2);
        assert_eq!(b.data(), &[5.0, 6.0, 9.0, 10.0]);
        // Overhanging block zero-pads.
        let e = a.block_padded(3, 3, 2, 2);
        assert_eq!(e.data(), &[15.0, 0.0, 0.0, 0.0]);
        // Fully out of range.
        let z = a.block_padded(10, 10, 2, 2);
        assert!(z.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn norms() {
        let v = Vector::from_vec(vec![3.0, -4.0]);
        assert!((v.norm_l2() - 5.0).abs() < 1e-12);
        assert_eq!(v.norm_inf(), 4.0);
    }

    #[test]
    fn delta_norm_l2_and_inf() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![4.0, 6.0]);
        assert!((a.delta_norm(&b, false) - 5.0).abs() < 1e-12);
        assert_eq!(a.delta_norm(&b, true), 4.0);
    }

    #[test]
    fn zero_fraction_counts() {
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 0.0, 2.0]);
        assert!((a.zero_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f32_roundtrip_precision() {
        let a = Matrix::standard_normal(8, 8, 5);
        let b = Matrix::from_f32(8, 8, &a.to_f32());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6 * x.abs().max(1.0));
        }
    }

    #[test]
    fn dot_axpy_scale() {
        let mut y = Vector::from_vec(vec![1.0, 2.0, 3.0]);
        let x = Vector::from_vec(vec![4.0, 5.0, 6.0]);
        assert!((y.dot(&x) - 32.0).abs() < 1e-12);
        y.axpy(2.0, &x);
        assert_eq!(y.data(), &[9.0, 12.0, 15.0]);
        y.scale(-1.0);
        assert_eq!(y.data(), &[-9.0, -12.0, -15.0]);
    }

    #[test]
    fn vector_slice_padded() {
        let v = Vector::from_vec(vec![1.0, 2.0, 3.0]);
        let s = v.slice_padded(2, 3);
        assert_eq!(s.data(), &[3.0, 0.0, 0.0]);
        let o = v.slice_padded(5, 2);
        assert_eq!(o.data(), &[0.0, 0.0]);
    }

    #[test]
    fn standard_normal_deterministic() {
        assert_eq!(
            Matrix::standard_normal(3, 3, 9).data(),
            Matrix::standard_normal(3, 3, 9).data()
        );
        assert_ne!(
            Matrix::standard_normal(3, 3, 9).data(),
            Matrix::standard_normal(3, 3, 10).data()
        );
    }
}
