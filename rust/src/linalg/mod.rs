//! Dense linear-algebra substrate (BLAS/LAPACK stand-in, DESIGN.md S3).
//!
//! * [`Matrix`] / [`Vector`] — row-major `f64` dense containers used for
//!   operands, ground truth and encoded images.  Device simulation rounds
//!   through `f32` at the PJRT boundary (the artifacts are f32), while all
//!   error norms are evaluated in `f64` against an exact `f64` product.
//! * [`tridiag`] — Thomas solver for the SPD tridiagonal `(I + λLᵀL)`
//!   systems behind the second-order denoiser.
//! * [`lu`] — dense partial-pivot LU (ground-truth solves, κ estimation).
//! * [`cond`] — power/inverse iteration spectral-norm and condition-number
//!   estimators used to validate the synthetic matrix generators.
//! * [`krylov`] — the Arnoldi/Givens workspace behind restarted GMRES
//!   (`crate::iterative::gmres`); pure f64 host math, backend-agnostic.

pub mod cond;
pub mod krylov;
pub mod lu;
pub mod tridiag;

mod dense;

pub use dense::{Matrix, Vector};
