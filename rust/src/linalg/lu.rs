//! Dense LU decomposition with partial pivoting.
//!
//! Used for ground-truth solves on the small benchmark matrices and for
//! exact smallest-singular-value estimation (via inverse iteration) when
//! validating the synthetic generators' condition numbers.

use crate::linalg::{Matrix, Vector};

/// LU factors of a square matrix (PA = LU, stored packed).
#[derive(Debug)]
pub struct Lu {
    lu: Matrix,
    perm: Vec<usize>,
    /// Sign of the permutation (determinant bookkeeping).
    sign: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SingularError;

impl std::fmt::Display for SingularError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix is numerically singular")
    }
}

impl std::error::Error for SingularError {}

impl Lu {
    /// Factor `a` (must be square).
    pub fn factor(a: &Matrix) -> Result<Lu, SingularError> {
        let n = a.nrows();
        assert_eq!(n, a.ncols(), "LU requires a square matrix");
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;

        for k in 0..n {
            // Pivot: largest |entry| in column k at/below the diagonal.
            let mut p = k;
            let mut pmax = lu.get(k, k).abs();
            for i in k + 1..n {
                let v = lu.get(i, k).abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax == 0.0 {
                return Err(SingularError);
            }
            if p != k {
                perm.swap(p, k);
                sign = -sign;
                for j in 0..n {
                    let tmp = lu.get(k, j);
                    lu.set(k, j, lu.get(p, j));
                    lu.set(p, j, tmp);
                }
            }
            let pivot = lu.get(k, k);
            for i in k + 1..n {
                let factor = lu.get(i, k) / pivot;
                lu.set(i, k, factor);
                if factor == 0.0 {
                    continue;
                }
                for j in k + 1..n {
                    let v = lu.get(i, j) - factor * lu.get(k, j);
                    lu.set(i, j, v);
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &Vector) -> Vector {
        let n = self.lu.nrows();
        assert_eq!(b.len(), n);
        // Apply permutation, forward substitution (L has unit diagonal).
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b.get(self.perm[i]);
            for j in 0..i {
                acc -= self.lu.get(i, j) * y[j];
            }
            y[i] = acc;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= self.lu.get(i, j) * y[j];
            }
            y[i] = acc / self.lu.get(i, i);
        }
        Vector::from_vec(y)
    }

    /// log10(|det A|) — overflow-safe determinant magnitude.
    pub fn log10_abs_det(&self) -> f64 {
        (0..self.lu.nrows())
            .map(|i| self.lu.get(i, i).abs().log10())
            .sum()
    }

    pub fn det_sign(&self) -> f64 {
        let diag_sign: f64 = (0..self.lu.nrows())
            .map(|i| self.lu.get(i, i).signum())
            .product();
        self.sign * diag_sign
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        let a = Matrix::from_vec(3, 3, vec![2.0, 1.0, 1.0, 1.0, 3.0, 2.0, 1.0, 0.0, 0.0]);
        let b = Vector::from_vec(vec![4.0, 5.0, 6.0]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&b);
        let back = a.matvec(&x);
        for (g, w) in back.data().iter().zip(b.data()) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn random_solve_residual() {
        let n = 40;
        let a = Matrix::standard_normal(n, n, 17);
        let x_true = Vector::standard_normal(n, 18);
        let b = a.matvec(&x_true);
        let x = Lu::factor(&a).unwrap().solve(&b);
        let err = x.sub(&x_true).norm_l2() / x_true.norm_l2();
        assert!(err < 1e-8, "relative error {err}");
    }

    #[test]
    fn detects_singular() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert_eq!(Lu::factor(&a).unwrap_err(), SingularError);
    }

    #[test]
    fn determinant_of_identity() {
        let lu = Lu::factor(&Matrix::identity(6)).unwrap();
        assert!((lu.log10_abs_det()).abs() < 1e-12);
        assert_eq!(lu.det_sign(), 1.0);
    }

    #[test]
    fn determinant_sign_of_swap() {
        // Permutation matrix with one swap has det -1.
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::factor(&a).unwrap();
        assert_eq!(lu.det_sign(), -1.0);
    }
}
