//! Configuration system: system geometry + solve options, loadable from a
//! minimal-TOML file with CLI overrides (DESIGN.md S15).

use crate::device::materials::Material;
use crate::device::nonideal::{AdcModel, DriftModel, IrDropModel, NonIdealExt};
use crate::ec::{DenoiseMode, EcOptions};
use crate::mca::WriteVerifyOpts;
use crate::plane::Placement;
use crate::util::toml::TomlDoc;
use crate::virtualization::SystemGeometry;

/// Which execution backend runs the tile MVMs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts through the PJRT CPU client (production path).
    Pjrt,
    /// Pure-Rust reference (digital baseline / artifact-free fallback).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            "native" | "rust" => Some(BackendKind::Native),
            _ => None,
        }
    }
}

/// Physical system configuration (the paper's R×C tile of r×c-cell MCAs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SystemConfig {
    pub tile_rows: usize,
    pub tile_cols: usize,
    pub cell_size: usize,
    /// Residency tile slots per MCA for the multi-tenant execution plane's
    /// allocator (`0` = unbounded).  Each resident chunk of each operand
    /// occupies one slot on its assigned MCA; eviction frees slots for
    /// reuse.  Does not affect results, only admission.
    pub tile_slots: usize,
}

impl SystemConfig {
    pub fn new(tile_rows: usize, tile_cols: usize, cell_size: usize) -> SystemConfig {
        SystemConfig {
            tile_rows,
            tile_cols,
            cell_size,
            tile_slots: 0,
        }
    }

    /// Cap the residency tile slots per MCA (`0` = unbounded).
    pub fn with_tile_slots(mut self, slots: usize) -> SystemConfig {
        self.tile_slots = slots;
        self
    }

    /// A single MCA (the Table 1 / Fig 2–3 setting).
    pub fn single_mca(cell_size: usize) -> SystemConfig {
        SystemConfig::new(1, 1, cell_size)
    }

    /// The paper's scaling testbed: 8×8 tiles.
    pub fn tiles_8x8(cell_size: usize) -> SystemConfig {
        SystemConfig::new(8, 8, cell_size)
    }

    pub fn geometry(&self) -> SystemGeometry {
        SystemGeometry::new(self.tile_rows, self.tile_cols, self.cell_size)
    }
}

/// Per-solve options.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    pub material: Material,
    /// Two-tier error correction on/off.
    pub ec: bool,
    pub denoise: DenoiseMode,
    /// Regularization λ for the second-order stage.
    pub lambda: f64,
    /// Difference-matrix superdiagonal h.
    pub h: f64,
    /// Write–verify iteration budget `N` (k in the figures).
    pub wv_iters: usize,
    /// Relative tolerance ε of the write–verify loop.
    pub wv_rel_tol: f64,
    /// Use ℓ∞ for the verify norm (`p = ∞`), else ℓ2.
    pub wv_norm_inf: bool,
    /// Master seed (chunk/MCA streams fork from it).
    pub seed: u64,
    /// Worker threads / shards (capped at the MCA count).
    pub workers: usize,
    /// How MCAs are grouped into shards (cannot change results — see
    /// [`crate::plane::placement`]).
    pub placement: Placement,
    /// Compute the exact f64 ground-truth matvec and report `rel_err_*`.
    /// O(m·n) host work per solve — dominant at scale and infeasible for
    /// 65k² operands, so large runs switch it off
    /// ([`with_ground_truth`](Self::with_ground_truth), CLI `--no-truth`);
    /// `rel_err_*` are then NaN (serialized as JSON `null`).
    pub ground_truth: bool,
    pub backend: BackendKind,
    /// Extended non-idealities (disabled by default).
    pub nonideal: NonIdealExt,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            material: Material::TaOxHfOx,
            ec: true,
            denoise: DenoiseMode::InMemory,
            lambda: 1e-12,
            h: -1.0,
            wv_iters: 0,
            wv_rel_tol: 1e-4,
            wv_norm_inf: false,
            seed: 42,
            workers: 4,
            placement: Placement::RoundRobin,
            ground_truth: true,
            backend: BackendKind::Pjrt,
            nonideal: NonIdealExt::default(),
        }
    }
}

impl SolveOptions {
    pub fn with_device(mut self, m: Material) -> Self {
        self.material = m;
        self
    }

    pub fn with_ec(mut self, ec: bool) -> Self {
        self.ec = ec;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }

    /// Enable/disable the exact ground-truth matvec (`rel_err_*`
    /// reporting).  On by default; switch off for at-scale runs where the
    /// O(m·n) host-side reference would dominate wall time.
    pub fn with_ground_truth(mut self, gt: bool) -> Self {
        self.ground_truth = gt;
        self
    }

    pub fn with_wv_iters(mut self, k: usize) -> Self {
        self.wv_iters = k;
        self
    }

    pub fn with_backend(mut self, b: BackendKind) -> Self {
        self.backend = b;
        self
    }

    pub fn with_denoise(mut self, d: DenoiseMode) -> Self {
        self.denoise = d;
        self
    }

    /// Assemble the per-tile EC options.
    pub fn ec_options(&self) -> EcOptions {
        EcOptions {
            ec: self.ec,
            lambda: self.lambda,
            h: self.h,
            denoise: self.denoise,
            wv: WriteVerifyOpts {
                max_iters: self.wv_iters,
                rel_tol: self.wv_rel_tol,
                norm_inf: self.wv_norm_inf,
            },
            nonideal: self.nonideal,
        }
    }

    /// Enable extended non-idealities (ablations / robustness studies).
    pub fn with_nonideal(mut self, ext: NonIdealExt) -> Self {
        self.nonideal = ext;
        self
    }
}

/// Parse a config file into `(SystemConfig, SolveOptions)`, starting from
/// defaults; unknown keys are rejected so typos fail fast.
pub fn from_toml(text: &str) -> Result<(SystemConfig, SolveOptions), String> {
    let doc = TomlDoc::parse(text)?;
    let mut system = SystemConfig::tiles_8x8(1024);
    let mut opts = SolveOptions::default();
    for (key, value) in &doc.entries {
        match key.as_str() {
            "system.tile_rows" => {
                system.tile_rows = value.as_usize().ok_or("tile_rows must be integer")?
            }
            "system.tile_cols" => {
                system.tile_cols = value.as_usize().ok_or("tile_cols must be integer")?
            }
            "system.cell_size" => {
                system.cell_size = value.as_usize().ok_or("cell_size must be integer")?
            }
            "system.tile_slots" => {
                system.tile_slots = value.as_usize().ok_or("tile_slots must be integer")?
            }
            "solve.device" => {
                let name = value.as_str().ok_or("device must be a string")?;
                opts.material = Material::parse(name)
                    .ok_or_else(|| format!("unknown device {name:?}"))?;
            }
            "solve.ec" => opts.ec = value.as_bool().ok_or("ec must be bool")?,
            "solve.denoise" => {
                let name = value.as_str().ok_or("denoise must be a string")?;
                opts.denoise = match name {
                    "in-memory" | "inmemory" => DenoiseMode::InMemory,
                    "digital" => DenoiseMode::Digital,
                    "off" => DenoiseMode::Off,
                    _ => return Err(format!("unknown denoise mode {name:?}")),
                };
            }
            "solve.lambda" => opts.lambda = value.as_f64().ok_or("lambda must be a number")?,
            "solve.h" => opts.h = value.as_f64().ok_or("h must be a number")?,
            "solve.wv_iters" => {
                opts.wv_iters = value.as_usize().ok_or("wv_iters must be integer")?
            }
            "solve.wv_rel_tol" => {
                opts.wv_rel_tol = value.as_f64().ok_or("wv_rel_tol must be a number")?
            }
            "solve.wv_norm_inf" => {
                opts.wv_norm_inf = value.as_bool().ok_or("wv_norm_inf must be bool")?
            }
            "solve.seed" => opts.seed = value.as_i64().ok_or("seed must be integer")? as u64,
            "solve.workers" => {
                opts.workers = value.as_usize().ok_or("workers must be integer")?
            }
            "solve.placement" => {
                let name = value.as_str().ok_or("placement must be a string")?;
                opts.placement = Placement::parse(name)
                    .ok_or_else(|| format!("unknown placement {name:?}"))?;
            }
            "solve.ground_truth" => {
                opts.ground_truth = value.as_bool().ok_or("ground_truth must be bool")?
            }
            "solve.adc_bits" => {
                opts.nonideal.adc =
                    AdcModel::new(value.as_usize().ok_or("adc_bits must be integer")? as u32)
            }
            "solve.drift_nu" => {
                opts.nonideal.drift = DriftModel::new(
                    value.as_f64().ok_or("drift_nu must be a number")?,
                    opts.nonideal.drift.elapsed.max(1.0),
                )
            }
            "solve.drift_elapsed" => {
                opts.nonideal.drift = DriftModel::new(
                    opts.nonideal.drift.nu,
                    value.as_f64().ok_or("drift_elapsed must be a number")?,
                )
            }
            "solve.irdrop_alpha" => {
                opts.nonideal.ir_drop =
                    IrDropModel::new(value.as_f64().ok_or("irdrop_alpha must be a number")?)
            }
            "solve.backend" => {
                let name = value.as_str().ok_or("backend must be a string")?;
                opts.backend = BackendKind::parse(name)
                    .ok_or_else(|| format!("unknown backend {name:?}"))?;
            }
            other => return Err(format!("unknown config key {other:?}")),
        }
    }
    Ok((system, opts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let o = SolveOptions::default();
        assert!(o.ec);
        assert_eq!(o.lambda, 1e-12);
        assert_eq!(o.placement, Placement::RoundRobin);
        assert!(o.ground_truth);
        let ec = o.ec_options();
        assert_eq!(ec.wv.max_iters, 0);
    }

    #[test]
    fn parses_full_config() {
        let (sys, opts) = from_toml(
            r#"
            [system]
            tile_rows = 4
            tile_cols = 2
            cell_size = 256
            tile_slots = 8

            [solve]
            device = "epiram"
            ec = false
            denoise = "digital"
            lambda = 0.5
            wv_iters = 7
            seed = 123
            workers = 2
            placement = "sparsity-aware"
            ground_truth = false
            backend = "native"
            "#,
        )
        .unwrap();
        assert_eq!(sys, SystemConfig::new(4, 2, 256).with_tile_slots(8));
        assert_eq!(opts.material, Material::EpiRam);
        assert!(!opts.ec);
        assert_eq!(opts.denoise, DenoiseMode::Digital);
        assert_eq!(opts.wv_iters, 7);
        assert_eq!(opts.seed, 123);
        assert_eq!(opts.placement, Placement::SparsityAware);
        assert!(!opts.ground_truth);
        assert_eq!(opts.backend, BackendKind::Native);
    }

    #[test]
    fn parses_timing_aware_placement() {
        let (_, opts) = from_toml("[solve]\nplacement = \"timing-aware\"\n").unwrap();
        assert_eq!(opts.placement, Placement::TimingAware);
        let (_, opts) = from_toml("[solve]\nplacement = \"timing\"\n").unwrap();
        assert_eq!(opts.placement, Placement::TimingAware);
    }

    #[test]
    fn rejects_unknown_keys() {
        let err = from_toml("[solve]\nfoo = 1\n").unwrap_err();
        assert!(err.contains("solve.foo"), "{err}");
    }

    #[test]
    fn rejects_unknown_device() {
        let err = from_toml("[solve]\ndevice = \"unobtanium\"\n").unwrap_err();
        assert!(err.contains("unknown device"));
    }

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("PJRT"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("gpu"), None);
    }
}
