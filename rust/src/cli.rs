//! Hand-rolled CLI (clap stand-in, DESIGN.md S15).
//!
//! ```text
//! meliso run --matrix add32 --device taox-hfox --ec --k 5 --tiles 8x8 --cell 1024
//! meliso run --matrix data/suitesparse/bcsstk02.mtx   # any Matrix-Market file
//! meliso solve-system --matrix arrow1k --method cg    # irregular sparse operand
//! meliso matrices          # Table 2 stand-in summary
//! meliso devices           # device parameter sheet
//! meliso artifacts         # loaded-artifact inventory
//! ```

use crate::config::{from_toml, BackendKind, SolveOptions, SystemConfig};
use crate::device::materials::Material;
use crate::ec::DenoiseMode;
use crate::iterative::{IterOptions, Method};
use crate::plane::Placement;

#[derive(Debug)]
pub enum Command {
    Run(RunArgs),
    Serve(ServeArgs),
    ServeBench(ServeBenchArgs),
    SolveSystem(SolveSystemArgs),
    Status(StatusArgs),
    Matrices,
    Devices,
    Artifacts,
    Help,
}

/// Observability sinks shared by `run` / `solve-system` / `serve-bench`.
/// Either flag arms the metrics registry; `--trace-out` also arms the
/// flight recorder.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ObsArgs {
    /// `--metrics-out PATH`: write a metrics snapshot (`.json` = JSON
    /// document, anything else = Prometheus exposition text).
    pub metrics_out: Option<String>,
    /// `--trace-out PATH`: write the flight-recorder ring as Chrome
    /// trace-event JSON.
    pub trace_out: Option<String>,
}

impl ObsArgs {
    /// The observability level these flags imply.
    pub fn level(&self) -> crate::obs::ObsLevel {
        if self.trace_out.is_some() {
            crate::obs::ObsLevel::Trace
        } else if self.metrics_out.is_some() {
            crate::obs::ObsLevel::Metrics
        } else {
            crate::obs::ObsLevel::Off
        }
    }
}

/// `meliso status`: render a previously written metrics snapshot.
#[derive(Debug)]
pub struct StatusArgs {
    /// Snapshot path written by `--metrics-out` (default
    /// `meliso-metrics.json`).
    pub file: String,
    pub json: bool,
}

#[derive(Debug)]
pub struct RunArgs {
    pub matrix: String,
    pub system: SystemConfig,
    pub opts: SolveOptions,
    pub reps: usize,
    pub json: bool,
    pub obs: ObsArgs,
}

#[derive(Debug)]
pub struct SolveSystemArgs {
    pub matrix: String,
    pub system: SystemConfig,
    pub opts: SolveOptions,
    pub iter: IterOptions,
    pub json: bool,
    pub obs: ObsArgs,
}

#[derive(Debug)]
pub struct ServeBenchArgs {
    pub matrix: String,
    /// Operands to program resident on ONE shared execution plane
    /// (`--operands a,b,c`); empty means just `matrix`.
    pub operands: Vec<String>,
    pub system: SystemConfig,
    pub opts: SolveOptions,
    /// Solves served against each resident session.
    pub solves: usize,
    /// Batch size for `solve_batch` (1 = sequential).
    pub batch: usize,
    /// One-shot reference solves (0 = auto: min(solves, 5)).
    pub baseline: usize,
    pub json: bool,
    pub obs: ObsArgs,
}

/// `meliso serve`: the network serving front door
/// ([`crate::serve::Server`]) over one shared execution plane.
#[derive(Debug)]
pub struct ServeArgs {
    /// Bind address (`host:port`; port 0 = ephemeral).
    pub addr: String,
    pub system: SystemConfig,
    pub opts: SolveOptions,
    /// Operands kept resident (LRU beyond this).
    pub cache_capacity: usize,
    /// Coalescing gather window in milliseconds.
    pub window_ms: u64,
    /// Max solves folded into one coalesced window.
    pub max_batch: usize,
    /// Global in-flight request budget.
    pub max_inflight: usize,
    /// Per-client in-flight request budget.
    pub max_inflight_per_client: usize,
    /// Connection-handler threads.
    pub http_threads: usize,
    pub obs: ObsArgs,
}

impl ServeArgs {
    /// Assemble the [`crate::serve::ServeConfig`] these flags describe.
    pub fn serve_config(&self) -> crate::serve::ServeConfig {
        crate::serve::ServeConfig {
            addr: self.addr.clone(),
            cache_capacity: self.cache_capacity,
            window: std::time::Duration::from_millis(self.window_ms),
            max_batch: self.max_batch,
            max_inflight: self.max_inflight,
            max_inflight_per_client: self.max_inflight_per_client,
            http_threads: self.http_threads,
            ..crate::serve::ServeConfig::default()
        }
    }
}

impl ServeBenchArgs {
    /// The operand list to serve: `--operands` when given, else the single
    /// `--matrix`.
    pub fn operand_names(&self) -> Vec<String> {
        if self.operands.is_empty() {
            vec![self.matrix.clone()]
        } else {
            self.operands.clone()
        }
    }
}

pub fn usage() -> &'static str {
    "MELISO+ — distributed RRAM in-memory linear solver with two-tier error correction

USAGE:
    meliso <COMMAND> [OPTIONS]

COMMANDS:
    run          execute a distributed in-memory MVM benchmark
    serve        start the HTTP serving front door over one shared plane
    solve-system solve Ax=b iteratively on a resident crossbar session
    serve-bench  compare resident-session serving vs repeated one-shot solves
    status       render a metrics snapshot written by --metrics-out
    matrices     list the benchmark operands (paper Table 2 stand-ins)
    devices      list the RRAM material parameter sets
    artifacts    show the AOT artifact inventory
    help         show this message

STATUS OPTIONS:
    --file PATH        metrics snapshot to read (default meliso-metrics.json)
    --json             emit the raw snapshot document instead of the table

SOLVE-SYSTEM OPTIONS (plus the applicable RUN options below):
    --method M         jacobi | richardson | cg | gmres (default cg)
    --tol T            target relative residual (default 1e-6)
    --maxiter N        MVM budget per inner solve (default 200)
    --restart M        GMRES restart length (default 32)
    --omega W          Richardson relaxation (default 1.0)
    --refinements N    outer refinement steps, 0 = off (default 40)
    --inner-tol T      inner-solve tolerance under refinement (default 1e-2)

SERVE OPTIONS (plus the applicable RUN options below):
    --addr HOST:PORT   bind address (default 127.0.0.1:7737; port 0 = ephemeral)
    --cache N          operands kept resident, LRU beyond (default 8)
    --window-ms N      coalescing gather window in ms (default 2)
    --max-batch N      max solves folded into one coalesced window (default 32)
    --max-inflight N   global in-flight request budget, excess 503 (default 64)
    --per-client N     per-client in-flight budget, excess 429 (default 16)
    --threads N        connection-handler threads (default 8)

SERVE-BENCH OPTIONS (plus the applicable RUN options below):
    --operands A,B,C   program several operands resident on ONE shared
                       execution plane and serve them interleaved
                       (default: just --matrix)
    --solves N         solves to serve against each resident session (default 32)
    --batch B          solve_batch size, 1 = sequential (default 8)
    --baseline N       one-shot reference solves per operand (default min(solves, 5))

RUN OPTIONS:
    --matrix NAME      operand from the registry (default iperturb66), or a
                       Matrix-Market file: any path ending in .mtx, or mtx:PATH
                       (loaded as a CSR sparse operand, O(nnz) memory)
    --config FILE      load [system]/[solve] sections from a TOML file
    --device NAME      ag-asi | alox-hfo2 | epiram | taox-hfox
    --ec / --no-ec     two-tier error correction (default on)
    --denoise MODE     in-memory | digital | off
    --k N              write-verify iterations (default 0)
    --lambda V         second-order regularization (default 1e-12)
    --tiles RxC        MCA tile grid (default 8x8)
    --cell N           cells per MCA edge: 32..1024 (default 1024)
    --tile-slots N     residency tile slots per MCA, 0 = unbounded (default 0)
    --workers N        shard worker threads (default 4)
    --placement P      round-robin | load-balanced | sparsity-aware | timing-aware
                       (default round-robin; timing-aware re-splits batches by
                       measured per-MCA wall time)
    --truth / --no-truth
                       exact f64 ground-truth reference for rel_err_* (default on;
                       switch off at scale — O(m·n) host work, rel_err_* become null)
    --reps N           replications to average (default 1)
    --seed S           master seed (default 42)
    --backend B        pjrt | native (default pjrt)
    --json             emit a JSON report instead of text
    --metrics-out PATH write a metrics snapshot on exit (.json = JSON document,
                       else Prometheus text); also enables metrics collection
    --trace-out PATH   write a Chrome trace (load in Perfetto / chrome://tracing);
                       also enables span recording
    -v / -vv           log verbosity
"
}

/// Parse argv (without the program name).
pub fn parse(args: &[String]) -> Result<Command, String> {
    let mut it = args.iter().peekable();
    match it.next().map(|s| s.as_str()) {
        None | Some("help") | Some("--help") | Some("-h") => Ok(Command::Help),
        Some("matrices") => Ok(Command::Matrices),
        Some("devices") => Ok(Command::Devices),
        Some("artifacts") => Ok(Command::Artifacts),
        Some("run") => parse_run(&mut it),
        Some("serve") => parse_serve(&mut it),
        Some("solve-system") => parse_solve_system(&mut it),
        Some("serve-bench") => parse_serve_bench(&mut it),
        Some("status") => parse_status(&mut it),
        Some(other) => Err(format!("unknown command {other:?}; try `meliso help`")),
    }
}

type ArgIter<'a> = std::iter::Peekable<std::slice::Iter<'a, String>>;

fn next_value(it: &mut ArgIter<'_>, flag: &str) -> Result<String, String> {
    it.next()
        .map(|s| s.to_string())
        .ok_or_else(|| format!("{flag} requires a value"))
}

/// Handle one flag shared by `run` and `serve-bench`.  Returns `Ok(true)`
/// when the flag was consumed, `Ok(false)` when the caller should try its
/// command-specific flags.
fn parse_common_flag(
    arg: &str,
    it: &mut ArgIter<'_>,
    matrix: &mut String,
    system: &mut SystemConfig,
    opts: &mut SolveOptions,
    json: &mut bool,
    obs: &mut ObsArgs,
) -> Result<bool, String> {
    match arg {
        "--metrics-out" => obs.metrics_out = Some(next_value(it, "--metrics-out")?),
        "--trace-out" => obs.trace_out = Some(next_value(it, "--trace-out")?),
        "--matrix" => *matrix = next_value(it, "--matrix")?,
        "--config" => {
            let path = next_value(it, "--config")?;
            let text =
                std::fs::read_to_string(&path).map_err(|e| format!("cannot read {path}: {e}"))?;
            let (sys, o) = from_toml(&text)?;
            *system = sys;
            *opts = o;
        }
        "--device" => {
            let name = next_value(it, "--device")?;
            opts.material =
                Material::parse(&name).ok_or_else(|| format!("unknown device {name:?}"))?;
        }
        "--ec" => opts.ec = true,
        "--no-ec" => opts.ec = false,
        "--denoise" => {
            let mode = next_value(it, "--denoise")?;
            opts.denoise = match mode.as_str() {
                "in-memory" | "inmemory" => DenoiseMode::InMemory,
                "digital" => DenoiseMode::Digital,
                "off" => DenoiseMode::Off,
                other => return Err(format!("unknown denoise mode {other:?}")),
            };
        }
        "--k" => {
            opts.wv_iters = next_value(it, "--k")?
                .parse()
                .map_err(|e| format!("--k: {e}"))?
        }
        "--lambda" => {
            opts.lambda = next_value(it, "--lambda")?
                .parse()
                .map_err(|e| format!("--lambda: {e}"))?
        }
        "--tiles" => {
            let spec = next_value(it, "--tiles")?;
            let (r, c) = spec
                .split_once('x')
                .ok_or_else(|| format!("--tiles expects RxC, got {spec:?}"))?;
            system.tile_rows = r.parse().map_err(|e| format!("--tiles rows: {e}"))?;
            system.tile_cols = c.parse().map_err(|e| format!("--tiles cols: {e}"))?;
        }
        "--cell" => {
            system.cell_size = next_value(it, "--cell")?
                .parse()
                .map_err(|e| format!("--cell: {e}"))?
        }
        "--tile-slots" => {
            system.tile_slots = next_value(it, "--tile-slots")?
                .parse()
                .map_err(|e| format!("--tile-slots: {e}"))?
        }
        "--workers" => {
            opts.workers = next_value(it, "--workers")?
                .parse()
                .map_err(|e| format!("--workers: {e}"))?
        }
        "--placement" => {
            let name = next_value(it, "--placement")?;
            opts.placement = Placement::parse(&name)
                .ok_or_else(|| format!("unknown placement {name:?}"))?;
        }
        "--truth" => opts.ground_truth = true,
        "--no-truth" => opts.ground_truth = false,
        "--seed" => {
            opts.seed = next_value(it, "--seed")?
                .parse()
                .map_err(|e| format!("--seed: {e}"))?
        }
        "--backend" => {
            let name = next_value(it, "--backend")?;
            opts.backend =
                BackendKind::parse(&name).ok_or_else(|| format!("unknown backend {name:?}"))?;
        }
        "--json" => *json = true,
        "-v" => crate::util::log::set_level(crate::util::log::Level::Info),
        "-vv" => crate::util::log::set_level(crate::util::log::Level::Debug),
        _ => return Ok(false),
    }
    Ok(true)
}

fn parse_run(it: &mut ArgIter<'_>) -> Result<Command, String> {
    let mut matrix = "iperturb66".to_string();
    let mut system = SystemConfig::tiles_8x8(1024);
    let mut opts = SolveOptions::default();
    let mut reps = 1usize;
    let mut json = false;
    let mut obs = ObsArgs::default();

    while let Some(arg) = it.next() {
        if parse_common_flag(
            arg.as_str(),
            it,
            &mut matrix,
            &mut system,
            &mut opts,
            &mut json,
            &mut obs,
        )? {
            continue;
        }
        match arg.as_str() {
            "--reps" => {
                reps = next_value(it, "--reps")?
                    .parse()
                    .map_err(|e| format!("--reps: {e}"))?
            }
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }

    Ok(Command::Run(RunArgs {
        matrix,
        system,
        opts,
        reps,
        json,
        obs,
    }))
}

fn parse_solve_system(it: &mut ArgIter<'_>) -> Result<Command, String> {
    let mut matrix = "spd64".to_string();
    let mut system = SystemConfig::single_mca(128);
    let mut opts = SolveOptions::default();
    let mut iter = IterOptions::default();
    let mut json = false;
    let mut obs = ObsArgs::default();

    while let Some(arg) = it.next() {
        if parse_common_flag(
            arg.as_str(),
            it,
            &mut matrix,
            &mut system,
            &mut opts,
            &mut json,
            &mut obs,
        )? {
            continue;
        }
        match arg.as_str() {
            "--method" => {
                let name = next_value(it, "--method")?;
                iter.method = Method::parse(&name)
                    .ok_or_else(|| format!("unknown method {name:?}"))?;
            }
            "--tol" => {
                iter.tol = next_value(it, "--tol")?
                    .parse()
                    .map_err(|e| format!("--tol: {e}"))?
            }
            "--maxiter" => {
                iter.max_iters = next_value(it, "--maxiter")?
                    .parse()
                    .map_err(|e| format!("--maxiter: {e}"))?
            }
            "--restart" => {
                iter.restart = next_value(it, "--restart")?
                    .parse()
                    .map_err(|e| format!("--restart: {e}"))?
            }
            "--omega" => {
                iter.omega = next_value(it, "--omega")?
                    .parse()
                    .map_err(|e| format!("--omega: {e}"))?
            }
            "--refinements" => {
                iter.max_refinements = next_value(it, "--refinements")?
                    .parse()
                    .map_err(|e| format!("--refinements: {e}"))?
            }
            "--inner-tol" => {
                iter.inner_tol = next_value(it, "--inner-tol")?
                    .parse()
                    .map_err(|e| format!("--inner-tol: {e}"))?
            }
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }
    if iter.tol <= 0.0 || !iter.tol.is_finite() {
        return Err("--tol must be a positive number".to_string());
    }
    if iter.inner_tol <= 0.0 || !iter.inner_tol.is_finite() {
        return Err("--inner-tol must be a positive number".to_string());
    }
    if iter.max_iters == 0 {
        return Err("--maxiter must be at least 1".to_string());
    }
    Ok(Command::SolveSystem(SolveSystemArgs {
        matrix,
        system,
        opts,
        iter,
        json,
        obs,
    }))
}

fn parse_serve(it: &mut ArgIter<'_>) -> Result<Command, String> {
    let defaults = crate::serve::ServeConfig::default();
    let mut addr = defaults.addr.clone();
    // The front door has no fixed operand — clients upload them — but the
    // shared RUN flags (device, tiles, workers, seed, ...) shape the one
    // solver every residency is programmed under.
    let mut matrix = String::new();
    let mut system = SystemConfig::tiles_8x8(1024);
    let mut opts = SolveOptions::default();
    let mut cache_capacity = defaults.cache_capacity;
    let mut window_ms = 2u64;
    let mut max_batch = defaults.max_batch;
    let mut max_inflight = defaults.max_inflight;
    let mut max_inflight_per_client = defaults.max_inflight_per_client;
    let mut http_threads = defaults.http_threads;
    let mut json = false;
    let mut obs = ObsArgs::default();

    while let Some(arg) = it.next() {
        if parse_common_flag(
            arg.as_str(),
            it,
            &mut matrix,
            &mut system,
            &mut opts,
            &mut json,
            &mut obs,
        )? {
            continue;
        }
        match arg.as_str() {
            "--addr" => addr = next_value(it, "--addr")?,
            "--cache" => {
                cache_capacity = next_value(it, "--cache")?
                    .parse()
                    .map_err(|e| format!("--cache: {e}"))?
            }
            "--window-ms" => {
                window_ms = next_value(it, "--window-ms")?
                    .parse()
                    .map_err(|e| format!("--window-ms: {e}"))?
            }
            "--max-batch" => {
                max_batch = next_value(it, "--max-batch")?
                    .parse()
                    .map_err(|e| format!("--max-batch: {e}"))?
            }
            "--max-inflight" => {
                max_inflight = next_value(it, "--max-inflight")?
                    .parse()
                    .map_err(|e| format!("--max-inflight: {e}"))?
            }
            "--per-client" => {
                max_inflight_per_client = next_value(it, "--per-client")?
                    .parse()
                    .map_err(|e| format!("--per-client: {e}"))?
            }
            "--threads" => {
                http_threads = next_value(it, "--threads")?
                    .parse()
                    .map_err(|e| format!("--threads: {e}"))?
            }
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }
    if cache_capacity == 0 {
        return Err("--cache must be at least 1".to_string());
    }
    if max_batch == 0 {
        return Err("--max-batch must be at least 1".to_string());
    }
    if max_inflight == 0 || max_inflight_per_client == 0 {
        return Err("--max-inflight and --per-client must be at least 1".to_string());
    }
    if http_threads == 0 {
        return Err("--threads must be at least 1".to_string());
    }
    Ok(Command::Serve(ServeArgs {
        addr,
        system,
        opts,
        cache_capacity,
        window_ms,
        max_batch,
        max_inflight,
        max_inflight_per_client,
        http_threads,
        obs,
    }))
}

fn parse_serve_bench(it: &mut ArgIter<'_>) -> Result<Command, String> {
    let mut matrix = "iperturb66".to_string();
    let mut operands: Vec<String> = Vec::new();
    let mut system = SystemConfig::single_mca(128);
    let mut opts = SolveOptions::default();
    let mut solves = 32usize;
    let mut batch = 8usize;
    let mut baseline = 0usize;
    let mut json = false;
    let mut obs = ObsArgs::default();

    while let Some(arg) = it.next() {
        if parse_common_flag(
            arg.as_str(),
            it,
            &mut matrix,
            &mut system,
            &mut opts,
            &mut json,
            &mut obs,
        )? {
            continue;
        }
        match arg.as_str() {
            "--operands" => {
                let spec = next_value(it, "--operands")?;
                operands = spec
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                if operands.is_empty() {
                    return Err("--operands expects a comma-separated list".to_string());
                }
            }
            "--solves" => {
                solves = next_value(it, "--solves")?
                    .parse()
                    .map_err(|e| format!("--solves: {e}"))?
            }
            "--batch" => {
                batch = next_value(it, "--batch")?
                    .parse()
                    .map_err(|e| format!("--batch: {e}"))?
            }
            "--baseline" => {
                baseline = next_value(it, "--baseline")?
                    .parse()
                    .map_err(|e| format!("--baseline: {e}"))?
            }
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }
    if solves == 0 {
        return Err("--solves must be at least 1".to_string());
    }
    Ok(Command::ServeBench(ServeBenchArgs {
        matrix,
        operands,
        system,
        opts,
        solves,
        batch: batch.max(1),
        baseline,
        json,
        obs,
    }))
}

fn parse_status(it: &mut ArgIter<'_>) -> Result<Command, String> {
    let mut file = "meliso-metrics.json".to_string();
    let mut json = false;
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--file" => file = next_value(it, "--file")?,
            "--json" => json = true,
            "-v" => crate::util::log::set_level(crate::util::log::Level::Info),
            "-vv" => crate::util::log::set_level(crate::util::log::Level::Debug),
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }
    Ok(Command::Status(StatusArgs { file, json }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_help_variants() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
        assert!(matches!(parse(&argv("help")).unwrap(), Command::Help));
        assert!(matches!(parse(&argv("--help")).unwrap(), Command::Help));
    }

    #[test]
    fn parses_run_with_options() {
        let cmd = parse(&argv(
            "run --matrix add32 --device epiram --no-ec --k 5 --tiles 4x2 --cell 256 \
             --reps 3 --seed 7 --backend native --placement sparsity-aware --no-truth --json",
        ))
        .unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.matrix, "add32");
                assert_eq!(r.opts.material, Material::EpiRam);
                assert!(!r.opts.ec);
                assert_eq!(r.opts.wv_iters, 5);
                assert_eq!(r.system, SystemConfig::new(4, 2, 256));
                assert_eq!(r.reps, 3);
                assert_eq!(r.opts.seed, 7);
                assert_eq!(r.opts.backend, BackendKind::Native);
                assert_eq!(r.opts.placement, Placement::SparsityAware);
                assert!(!r.opts.ground_truth);
                assert!(r.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn run_defaults_keep_ground_truth_on() {
        match parse(&argv("run")).unwrap() {
            Command::Run(r) => {
                assert!(r.opts.ground_truth);
                assert_eq!(r.opts.placement, Placement::RoundRobin);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_placement() {
        assert!(parse(&argv("run --placement diagonal")).is_err());
    }

    #[test]
    fn parses_solve_system_with_options() {
        let cmd = parse(&argv(
            "solve-system --matrix nonsym64 --method gmres --tol 1e-8 --maxiter 120 \
             --restart 16 --refinements 12 --inner-tol 5e-3 --device epiram --cell 64 \
             --backend native --json",
        ))
        .unwrap();
        match cmd {
            Command::SolveSystem(s) => {
                assert_eq!(s.matrix, "nonsym64");
                assert_eq!(s.iter.method, Method::Gmres);
                assert_eq!(s.iter.tol, 1e-8);
                assert_eq!(s.iter.max_iters, 120);
                assert_eq!(s.iter.restart, 16);
                assert_eq!(s.iter.max_refinements, 12);
                assert_eq!(s.iter.inner_tol, 5e-3);
                assert_eq!(s.opts.material, Material::EpiRam);
                assert_eq!(s.system, SystemConfig::single_mca(64));
                assert_eq!(s.opts.backend, BackendKind::Native);
                assert!(s.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn solve_system_defaults() {
        match parse(&argv("solve-system")).unwrap() {
            Command::SolveSystem(s) => {
                assert_eq!(s.matrix, "spd64");
                assert_eq!(s.iter.method, Method::Cg);
                assert_eq!(s.iter.tol, 1e-6);
                assert!(!s.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn solve_system_rejects_bad_inputs() {
        assert!(parse(&argv("solve-system --method sor")).is_err());
        assert!(parse(&argv("solve-system --tol 0")).is_err());
        assert!(parse(&argv("solve-system --inner-tol 0")).is_err());
        assert!(parse(&argv("solve-system --maxiter 0")).is_err());
        assert!(parse(&argv("solve-system --frobnicate")).is_err());
    }

    #[test]
    fn parses_serve_with_options() {
        let cmd = parse(&argv(
            "serve --addr 127.0.0.1:0 --cache 4 --window-ms 5 --max-batch 16 \
             --max-inflight 32 --per-client 8 --threads 3 --device epiram --cell 64 \
             --tiles 2x2 --workers 2 --seed 11 --backend native",
        ))
        .unwrap();
        match cmd {
            Command::Serve(s) => {
                assert_eq!(s.addr, "127.0.0.1:0");
                assert_eq!(s.cache_capacity, 4);
                assert_eq!(s.window_ms, 5);
                assert_eq!(s.max_batch, 16);
                assert_eq!(s.max_inflight, 32);
                assert_eq!(s.max_inflight_per_client, 8);
                assert_eq!(s.http_threads, 3);
                assert_eq!(s.opts.material, Material::EpiRam);
                assert_eq!(s.system, SystemConfig::new(2, 2, 64));
                assert_eq!(s.opts.workers, 2);
                assert_eq!(s.opts.seed, 11);
                assert_eq!(s.opts.backend, BackendKind::Native);
                let cfg = s.serve_config();
                assert_eq!(cfg.window, std::time::Duration::from_millis(5));
                assert_eq!(cfg.max_batch, 16);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn serve_defaults_and_rejections() {
        match parse(&argv("serve")).unwrap() {
            Command::Serve(s) => {
                assert_eq!(s.addr, "127.0.0.1:7737");
                assert_eq!(s.cache_capacity, 8);
                assert_eq!(s.window_ms, 2);
                assert_eq!(s.http_threads, 8);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("serve --cache 0")).is_err());
        assert!(parse(&argv("serve --max-batch 0")).is_err());
        assert!(parse(&argv("serve --max-inflight 0")).is_err());
        assert!(parse(&argv("serve --threads 0")).is_err());
        assert!(parse(&argv("serve --frobnicate")).is_err());
    }

    #[test]
    fn parses_serve_bench_with_options() {
        let cmd = parse(&argv(
            "serve-bench --matrix add32 --device epiram --solves 64 --batch 16 \
             --baseline 3 --cell 256 --tiles 2x2 --seed 11 --backend native --json",
        ))
        .unwrap();
        match cmd {
            Command::ServeBench(s) => {
                assert_eq!(s.matrix, "add32");
                assert_eq!(s.opts.material, Material::EpiRam);
                assert_eq!(s.solves, 64);
                assert_eq!(s.batch, 16);
                assert_eq!(s.baseline, 3);
                assert_eq!(s.system, SystemConfig::new(2, 2, 256));
                assert_eq!(s.opts.seed, 11);
                assert_eq!(s.opts.backend, BackendKind::Native);
                assert!(s.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn serve_bench_defaults() {
        match parse(&argv("serve-bench")).unwrap() {
            Command::ServeBench(s) => {
                assert_eq!(s.matrix, "iperturb66");
                assert!(s.operands.is_empty());
                assert_eq!(s.operand_names(), vec!["iperturb66".to_string()]);
                assert_eq!(s.solves, 32);
                assert_eq!(s.batch, 8);
                assert_eq!(s.baseline, 0);
                assert_eq!(s.system.tile_slots, 0);
                assert!(!s.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn serve_bench_parses_operand_list_and_tile_slots() {
        match parse(&argv(
            "serve-bench --operands iperturb66,add32,bcsstk02 --tile-slots 16 --cell 128",
        ))
        .unwrap()
        {
            Command::ServeBench(s) => {
                assert_eq!(
                    s.operand_names(),
                    vec![
                        "iperturb66".to_string(),
                        "add32".to_string(),
                        "bcsstk02".to_string()
                    ]
                );
                assert_eq!(s.system.tile_slots, 16);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("serve-bench --operands ,")).is_err());
        assert!(parse(&argv("serve-bench --tile-slots many")).is_err());
    }

    #[test]
    fn serve_bench_rejects_zero_solves() {
        assert!(parse(&argv("serve-bench --solves 0")).is_err());
        assert!(parse(&argv("serve-bench --frobnicate")).is_err());
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(parse(&argv("run --frobnicate")).is_err());
    }

    #[test]
    fn parses_obs_sinks_on_every_solve_command() {
        for cmdline in [
            "run --metrics-out m.prom --trace-out t.json",
            "solve-system --metrics-out m.prom --trace-out t.json",
            "serve-bench --metrics-out m.prom --trace-out t.json",
        ] {
            let obs = match parse(&argv(cmdline)).unwrap() {
                Command::Run(r) => r.obs,
                Command::SolveSystem(s) => s.obs,
                Command::ServeBench(s) => s.obs,
                other => panic!("{other:?}"),
            };
            assert_eq!(obs.metrics_out.as_deref(), Some("m.prom"), "{cmdline}");
            assert_eq!(obs.trace_out.as_deref(), Some("t.json"), "{cmdline}");
            assert_eq!(obs.level(), crate::obs::ObsLevel::Trace, "{cmdline}");
        }
    }

    #[test]
    fn obs_level_tracks_the_armed_sinks() {
        assert_eq!(ObsArgs::default().level(), crate::obs::ObsLevel::Off);
        match parse(&argv("run --metrics-out m.json")).unwrap() {
            Command::Run(r) => {
                assert_eq!(r.obs.level(), crate::obs::ObsLevel::Metrics);
                assert!(r.obs.trace_out.is_none());
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("run --metrics-out")).is_err());
        assert!(parse(&argv("run --trace-out")).is_err());
    }

    #[test]
    fn parses_status_command() {
        match parse(&argv("status")).unwrap() {
            Command::Status(s) => {
                assert_eq!(s.file, "meliso-metrics.json");
                assert!(!s.json);
            }
            other => panic!("{other:?}"),
        }
        match parse(&argv("status --file /tmp/snap.json --json")).unwrap() {
            Command::Status(s) => {
                assert_eq!(s.file, "/tmp/snap.json");
                assert!(s.json);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("status --frobnicate")).is_err());
    }

    #[test]
    fn rejects_bad_tiles() {
        assert!(parse(&argv("run --tiles 8by8")).is_err());
    }

    #[test]
    fn subcommands() {
        assert!(matches!(parse(&argv("matrices")).unwrap(), Command::Matrices));
        assert!(matches!(parse(&argv("devices")).unwrap(), Command::Devices));
        assert!(matches!(
            parse(&argv("artifacts")).unwrap(),
            Command::Artifacts
        ));
    }
}
