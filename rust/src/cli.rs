//! Hand-rolled CLI (clap stand-in, DESIGN.md S15).
//!
//! ```text
//! meliso run --matrix add32 --device taox-hfox --ec --k 5 --tiles 8x8 --cell 1024
//! meliso matrices          # Table 2 stand-in summary
//! meliso devices           # device parameter sheet
//! meliso artifacts         # loaded-artifact inventory
//! ```

use crate::config::{from_toml, BackendKind, SolveOptions, SystemConfig};
use crate::device::materials::Material;
use crate::ec::DenoiseMode;

#[derive(Debug)]
pub enum Command {
    Run(RunArgs),
    Matrices,
    Devices,
    Artifacts,
    Help,
}

#[derive(Debug)]
pub struct RunArgs {
    pub matrix: String,
    pub system: SystemConfig,
    pub opts: SolveOptions,
    pub reps: usize,
    pub json: bool,
}

pub fn usage() -> &'static str {
    "MELISO+ — distributed RRAM in-memory linear solver with two-tier error correction

USAGE:
    meliso <COMMAND> [OPTIONS]

COMMANDS:
    run         execute a distributed in-memory MVM benchmark
    matrices    list the benchmark operands (paper Table 2 stand-ins)
    devices     list the RRAM material parameter sets
    artifacts   show the AOT artifact inventory
    help        show this message

RUN OPTIONS:
    --matrix NAME      operand from the registry (default iperturb66)
    --config FILE      load [system]/[solve] sections from a TOML file
    --device NAME      ag-asi | alox-hfo2 | epiram | taox-hfox
    --ec / --no-ec     two-tier error correction (default on)
    --denoise MODE     in-memory | digital | off
    --k N              write-verify iterations (default 0)
    --lambda V         second-order regularization (default 1e-12)
    --tiles RxC        MCA tile grid (default 8x8)
    --cell N           cells per MCA edge: 32..1024 (default 1024)
    --workers N        worker threads (default 4)
    --reps N           replications to average (default 1)
    --seed S           master seed (default 42)
    --backend B        pjrt | native (default pjrt)
    --json             emit a JSON report instead of text
    -v / -vv           log verbosity
"
}

/// Parse argv (without the program name).
pub fn parse(args: &[String]) -> Result<Command, String> {
    let mut it = args.iter().peekable();
    let cmd = match it.next().map(|s| s.as_str()) {
        None | Some("help") | Some("--help") | Some("-h") => return Ok(Command::Help),
        Some("matrices") => return Ok(Command::Matrices),
        Some("devices") => return Ok(Command::Devices),
        Some("artifacts") => return Ok(Command::Artifacts),
        Some("run") => "run",
        Some(other) => return Err(format!("unknown command {other:?}; try `meliso help`")),
    };
    debug_assert_eq!(cmd, "run");

    let mut matrix = "iperturb66".to_string();
    let mut system = SystemConfig::tiles_8x8(1024);
    let mut opts = SolveOptions::default();
    let mut reps = 1usize;
    let mut json = false;

    let next_value = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                          flag: &str|
     -> Result<String, String> {
        it.next()
            .map(|s| s.to_string())
            .ok_or_else(|| format!("{flag} requires a value"))
    };

    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--matrix" => matrix = next_value(&mut it, "--matrix")?,
            "--config" => {
                let path = next_value(&mut it, "--config")?;
                let text = std::fs::read_to_string(&path)
                    .map_err(|e| format!("cannot read {path}: {e}"))?;
                let (sys, o) = from_toml(&text)?;
                system = sys;
                opts = o;
            }
            "--device" => {
                let name = next_value(&mut it, "--device")?;
                opts.material = Material::parse(&name)
                    .ok_or_else(|| format!("unknown device {name:?}"))?;
            }
            "--ec" => opts.ec = true,
            "--no-ec" => opts.ec = false,
            "--denoise" => {
                let mode = next_value(&mut it, "--denoise")?;
                opts.denoise = match mode.as_str() {
                    "in-memory" | "inmemory" => DenoiseMode::InMemory,
                    "digital" => DenoiseMode::Digital,
                    "off" => DenoiseMode::Off,
                    other => return Err(format!("unknown denoise mode {other:?}")),
                };
            }
            "--k" => {
                opts.wv_iters = next_value(&mut it, "--k")?
                    .parse()
                    .map_err(|e| format!("--k: {e}"))?
            }
            "--lambda" => {
                opts.lambda = next_value(&mut it, "--lambda")?
                    .parse()
                    .map_err(|e| format!("--lambda: {e}"))?
            }
            "--tiles" => {
                let spec = next_value(&mut it, "--tiles")?;
                let (r, c) = spec
                    .split_once('x')
                    .ok_or_else(|| format!("--tiles expects RxC, got {spec:?}"))?;
                system.tile_rows = r.parse().map_err(|e| format!("--tiles rows: {e}"))?;
                system.tile_cols = c.parse().map_err(|e| format!("--tiles cols: {e}"))?;
            }
            "--cell" => {
                system.cell_size = next_value(&mut it, "--cell")?
                    .parse()
                    .map_err(|e| format!("--cell: {e}"))?
            }
            "--workers" => {
                opts.workers = next_value(&mut it, "--workers")?
                    .parse()
                    .map_err(|e| format!("--workers: {e}"))?
            }
            "--reps" => {
                reps = next_value(&mut it, "--reps")?
                    .parse()
                    .map_err(|e| format!("--reps: {e}"))?
            }
            "--seed" => {
                opts.seed = next_value(&mut it, "--seed")?
                    .parse()
                    .map_err(|e| format!("--seed: {e}"))?
            }
            "--backend" => {
                let name = next_value(&mut it, "--backend")?;
                opts.backend = BackendKind::parse(&name)
                    .ok_or_else(|| format!("unknown backend {name:?}"))?;
            }
            "--json" => json = true,
            "-v" => crate::util::log::set_level(crate::util::log::Level::Info),
            "-vv" => crate::util::log::set_level(crate::util::log::Level::Debug),
            other => return Err(format!("unknown option {other:?}; try `meliso help`")),
        }
    }

    Ok(Command::Run(RunArgs {
        matrix,
        system,
        opts,
        reps,
        json,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_help_variants() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
        assert!(matches!(parse(&argv("help")).unwrap(), Command::Help));
        assert!(matches!(parse(&argv("--help")).unwrap(), Command::Help));
    }

    #[test]
    fn parses_run_with_options() {
        let cmd = parse(&argv(
            "run --matrix add32 --device epiram --no-ec --k 5 --tiles 4x2 --cell 256 \
             --reps 3 --seed 7 --backend native --json",
        ))
        .unwrap();
        match cmd {
            Command::Run(r) => {
                assert_eq!(r.matrix, "add32");
                assert_eq!(r.opts.material, Material::EpiRam);
                assert!(!r.opts.ec);
                assert_eq!(r.opts.wv_iters, 5);
                assert_eq!(r.system, SystemConfig::new(4, 2, 256));
                assert_eq!(r.reps, 3);
                assert_eq!(r.opts.seed, 7);
                assert_eq!(r.opts.backend, BackendKind::Native);
                assert!(r.json);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(parse(&argv("run --frobnicate")).is_err());
    }

    #[test]
    fn rejects_bad_tiles() {
        assert!(parse(&argv("run --tiles 8by8")).is_err());
    }

    #[test]
    fn subcommands() {
        assert!(matches!(parse(&argv("matrices")).unwrap(), Command::Matrices));
        assert!(matches!(parse(&argv("devices")).unwrap(), Command::Devices));
        assert!(matches!(
            parse(&argv("artifacts")).unwrap(),
            Command::Artifacts
        ));
    }
}
