//! Minimal TOML substrate for run configuration files.
//!
//! Supports the subset MELISO+ configs need: `[section]` / `[a.b]` headers,
//! `key = value` with strings, integers, floats, booleans, and flat arrays.
//! Keys are flattened to `section.key` dotted paths.

use std::collections::BTreeMap;

/// A TOML scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
}

/// A parsed TOML document: dotted-path -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    /// Parse a document; returns dotted-path entries.
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let header = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if header.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                prefix = header.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let path = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            doc.entries.insert(path, value);
        }
        Ok(doc)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = t.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(
            inner.replace("\\\"", "\"").replace("\\\\", "\\"),
        ));
    }
    if let Some(rest) = t.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                if part.trim().is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match t {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(v) = t.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = t.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("unrecognized value {t:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
            # MELISO+ run config
            seed = 42
            device = "taox-hfox"   # material
            ec = true
            lambda = 1e-12

            [system]
            tile_rows = 8
            tile_cols = 8
            cell_size = 1024
            sizes = [32, 64, 128]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("device").unwrap().as_str(), Some("taox-hfox"));
        assert_eq!(doc.get("ec").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("lambda").unwrap().as_f64(), Some(1e-12));
        assert_eq!(doc.get("system.cell_size").unwrap().as_usize(), Some(1024));
        let arr = match doc.get("system.sizes").unwrap() {
            TomlValue::Arr(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.5\n").unwrap();
        assert_eq!(doc.get("a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("b").unwrap(), &TomlValue::Float(3.5));
        assert_eq!(doc.get("a").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        let err = TomlDoc::parse("x = 1\noops\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_and_comment_only() {
        let doc = TomlDoc::parse("\n# nothing\n\n").unwrap();
        assert!(doc.entries.is_empty());
    }
}
