//! Tiny leveled logger (stderr), controlled by `MELISO_LOG` (error|warn|info|debug|trace).
//!
//! Replaces the unvendored `log`/`tracing` stacks; the coordinator's event
//! loop and runtime service use it for operational visibility without ever
//! touching the hot path when the level is disabled.

use std::sync::atomic::{AtomicU8, Ordering};
// meliso-lint: allow(clock) -- log-line timestamps are human-facing metadata, never numerics
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        // SAFETY-free decode: values are only ever stored from Level.
        return match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        };
    }
    let lv = std::env::var("MELISO_LOG")
        .map(|s| Level::from_env(&s))
        .unwrap_or(Level::Warn);
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

/// Override the level programmatically (CLI `-v` flags).
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn enabled(lv: Level) -> bool {
    lv <= level()
}

pub fn log(lv: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(lv) {
        return;
    }
    // meliso-lint: allow(clock) -- wall-clock stamp on an emitted log line
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    eprintln!(
        "[{:>10}.{:03} {} {}] {}",
        t.as_secs(),
        t.subsec_millis(),
        lv.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Warn);
    }

    #[test]
    fn from_env_parses() {
        assert_eq!(Level::from_env("TRACE"), Level::Trace);
        assert_eq!(Level::from_env("warning"), Level::Warn);
        assert_eq!(Level::from_env("bogus"), Level::Info);
    }
}
