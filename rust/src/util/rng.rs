//! Deterministic pseudo-random number generation substrate.
//!
//! Replaces the (unvendored) `rand`/`rand_distr` crates: SplitMix64 for
//! seeding, Xoshiro256++ as the workhorse generator, Box–Muller normal and
//! exp-normal (lognormal) sampling.  Every stochastic component of the
//! simulator threads an explicit [`Rng`] so that chunk-level work is
//! reproducible regardless of worker scheduling order (each chunk derives
//! its own stream via [`Rng::fork`]).

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ PRNG with normal/lognormal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot emit four
        // consecutive zeros, but guard anyway.
        if s.iter().all(|&w| w == 0) {
            s[0] = 0x1;
        }
        Self {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent stream (used per chunk / per MCA).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free bound for our (non-crypto) use.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal such that the *multiplicative* spread is exp(sigma).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out {
            *v = self.normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_reproducible_across_runs() {
        let seq = |seed: u64, tag: u64| {
            let mut root = Rng::new(seed);
            let mut f = root.fork(tag);
            (0..8).map(|_| f.next_u64()).collect::<Vec<_>>()
        };
        assert_eq!(seq(5, 10), seq(5, 10));
        assert_ne!(seq(5, 10), seq(5, 11));
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.lognormal(0.3) > 0.0);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
