//! In-house substrate utilities.
//!
//! The deployment image vendors only the `xla` crate closure, so the usual
//! ecosystem crates (`rand`, `serde_json`, `toml`, `log`) are reimplemented
//! here as small, well-tested substrates (DESIGN.md §3, S1/S2).

pub mod json;
pub mod log;
pub mod rng;
pub mod toml;

/// Format a float in short scientific notation, matching the paper's tables
/// (e.g. `5.36E-08`).
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if (1e-3..1e4).contains(&a) {
        format!("{v:.4}")
    } else {
        format!("{v:.2E}")
    }
}

/// Integer ceil-div.
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Round `n` up to the next multiple of `m`.
pub fn round_up(n: usize, m: usize) -> usize {
    ceil_div(n, m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(66, 128), 128);
        assert_eq!(round_up(128, 128), 128);
        assert_eq!(round_up(129, 128), 256);
        assert_eq!(round_up(0, 32), 0);
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(0.0223), "0.0223");
        assert_eq!(sci(5.36e-8), "5.36E-8");
    }
}
