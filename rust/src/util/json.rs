//! Minimal JSON substrate: a writer (for reports/CSV-adjacent output) and a
//! recursive-descent parser (for `artifacts/manifest.json`).
//!
//! Intentionally small: objects, arrays, strings, numbers, bools, null —
//! enough for the artifact manifest and machine-readable run reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(v) => {
                if !v.is_finite() {
                    // JSON has no NaN/Inf literal; `null` keeps emitted
                    // reports parseable (e.g. skipped-ground-truth
                    // `rel_err_*` fields).
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(value)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|e| format!("bad number {s:?}: {e}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // consume '['
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {}
            _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // consume '{'
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key string at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos}", pos = *pos));
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
          "schema": 1,
          "dtype": "f32",
          "tile_sizes": [32, 64],
          "artifacts": {
            "mvm_32": {"file": "mvm_32.hlo.txt", "tile": 32, "outputs": ["y_raw"]}
          }
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(v.get("tile_sizes").unwrap().as_arr().unwrap().len(), 2);
        let art = v.get("artifacts").unwrap().get("mvm_32").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("mvm_32.hlo.txt"));
    }

    #[test]
    fn roundtrip_serialize_parse() {
        let mut obj = Json::obj();
        obj.set("name", Json::Str("taox \"hfox\"\n".into()))
            .set("e_w", Json::Num(5.36e-8))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]));
        let text = obj.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, obj);
        let back2 = Json::parse(&obj.compact()).unwrap();
        assert_eq!(back2, obj);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).compact(), "null");
        let mut obj = Json::obj();
        obj.set("rel_err_l2", Json::Num(f64::NAN));
        let back = Json::parse(&obj.pretty()).unwrap();
        assert_eq!(back.get("rel_err_l2"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err() || Json::parse("[1,]").is_ok());
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1, 2], [3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap().len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""a\u0041b""#).unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }
}
