//! `meliso` — leader entrypoint / CLI for the MELISO+ framework.

use meliso::cli::{parse, usage, Command, RunArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::metrics::table::TableBuilder;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;
use meliso::util::sci;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match parse(&args) {
        Ok(Command::Help) => {
            print!("{}", usage());
            0
        }
        Ok(Command::Matrices) => cmd_matrices(),
        Ok(Command::Devices) => cmd_devices(),
        Ok(Command::Artifacts) => cmd_artifacts(),
        Ok(Command::Run(run)) => match cmd_run(run) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_matrices() -> i32 {
    let mut t = TableBuilder::new(
        "Benchmark operands (synthetic SuiteSparse stand-ins, paper Table 2)",
        &["dim", "kappa", "||A||2", "used in"],
    );
    for m in registry::CATALOG {
        t.row(
            m.name,
            vec![
                format!("{}", m.dim),
                sci(m.kappa),
                sci(m.norm2),
                m.used_in.to_string(),
            ],
        );
    }
    print!("{}", t.render());
    0
}

fn cmd_devices() -> i32 {
    let mut t = TableBuilder::new(
        "RRAM material systems (DESIGN.md §5 calibration)",
        &[
            "levels", "σ_prog", "σ_floor", "σ_read", "α_p/α_d", "pulses", "E_pulse(J)",
            "t_pulse(s)",
        ],
    );
    for m in Material::ALL {
        let p = m.params();
        t.row(
            p.name,
            vec![
                format!("{}", p.levels),
                format!("{}", p.sigma_prog),
                format!("{}", p.sigma_floor),
                format!("{}", p.sigma_read),
                format!("{}/{}", p.alpha_ltp, p.alpha_ltd),
                format!("{}", p.pulses_write),
                sci(p.e_pulse),
                sci(p.t_pulse),
            ],
        );
    }
    print!("{}", t.render());
    0
}

fn cmd_artifacts() -> i32 {
    let dir = meliso::runtime::pjrt::default_artifact_dir();
    let manifest = dir.join("manifest.json");
    match std::fs::read_to_string(&manifest) {
        Ok(text) => match meliso::util::json::Json::parse(&text) {
            Ok(j) => {
                println!("artifact dir: {}", dir.display());
                if let Some(arts) = j.get("artifacts").and_then(|a| a.as_obj()) {
                    for (name, meta) in arts {
                        println!(
                            "  {name:<14} {:>9} bytes  sha256 {}…",
                            meta.get("bytes").and_then(|b| b.as_usize()).unwrap_or(0),
                            meta.get("sha256")
                                .and_then(|s| s.as_str())
                                .map(|s| &s[..12])
                                .unwrap_or("?")
                        );
                    }
                }
                0
            }
            Err(e) => {
                eprintln!("bad manifest: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!(
                "no artifacts at {} ({e}); run `make artifacts`",
                manifest.display()
            );
            1
        }
    }
}

fn cmd_run(run: RunArgs) -> Result<(), String> {
    let source = registry::build(&run.matrix)?;
    let x = Vector::standard_normal(source.ncols(), run.opts.seed ^ 0x5eed);
    let solver = Meliso::new(run.system, run.opts.clone())?;
    eprintln!(
        "# {} ({}x{}), device {}, EC {}, k={}, system {}x{} tiles of {}², backend {}",
        run.matrix,
        source.nrows(),
        source.ncols(),
        run.opts.material,
        if run.opts.ec { "on" } else { "off" },
        run.opts.wv_iters,
        run.system.tile_rows,
        run.system.tile_cols,
        run.system.cell_size,
        solver.backend_name(),
    );
    let reports = solver.replicate(source.as_ref(), &x, run.reps.max(1))?;
    if run.json {
        let mut arr = Vec::new();
        for r in &reports {
            arr.push(r.to_json());
        }
        println!("{}", meliso::util::json::Json::Arr(arr).pretty());
    } else {
        let s = ReplicationSummary::from_reports(&reports);
        let last = reports.last().unwrap();
        let mut t = TableBuilder::new(
            &format!("{} x {} reps", run.matrix, s.reps),
            &["value"],
        );
        t.row("rel l2 error", vec![sci(s.rel_err_l2)]);
        t.row("rel linf error", vec![sci(s.rel_err_inf)]);
        t.row("E_w mean (J)", vec![sci(s.ew_mean)]);
        t.row("L_w mean (s)", vec![sci(s.lw_mean)]);
        t.row("chunks", vec![format!("{}", last.chunks_total)]);
        t.row("chunks skipped", vec![format!("{}", last.chunks_skipped)]);
        t.row("MCAs used", vec![format!("{}", last.mcas_used)]);
        t.row(
            "norm. factor",
            vec![format!("{}", last.row_reassignments)],
        );
        t.row("wall (s)", vec![format!("{:.3}", last.wall_seconds)]);
        print!("{}", t.render());
    }
    Ok(())
}
