//! `meliso` — leader entrypoint / CLI for the MELISO+ framework.

use meliso::cli::{
    parse, usage, Command, ObsArgs, RunArgs, ServeArgs, ServeBenchArgs, SolveSystemArgs, StatusArgs,
};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::metrics::table::TableBuilder;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;
use meliso::util::json::Json;
use meliso::util::sci;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match parse(&args) {
        Ok(Command::Help) => {
            print!("{}", usage());
            0
        }
        Ok(Command::Matrices) => cmd_matrices(),
        Ok(Command::Devices) => cmd_devices(),
        Ok(Command::Artifacts) => cmd_artifacts(),
        Ok(Command::Run(run)) => match cmd_run(run) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Ok(Command::Serve(sv)) => match cmd_serve(sv) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Ok(Command::ServeBench(sb)) => match cmd_serve_bench(sb) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Ok(Command::SolveSystem(ss)) => match cmd_solve_system(ss) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Ok(Command::Status(st)) => match cmd_status(st) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_matrices() -> i32 {
    let mut t = TableBuilder::new(
        "Benchmark operands (synthetic SuiteSparse stand-ins, paper Table 2)",
        &["dim", "kappa", "||A||2", "used in"],
    );
    for m in registry::CATALOG {
        t.row(
            m.name,
            vec![
                format!("{}", m.dim),
                sci(m.kappa),
                sci(m.norm2),
                m.used_in.to_string(),
            ],
        );
    }
    print!("{}", t.render());
    0
}

fn cmd_devices() -> i32 {
    let mut t = TableBuilder::new(
        "RRAM material systems (DESIGN.md §5 calibration)",
        &[
            "levels", "σ_prog", "σ_floor", "σ_read", "α_p/α_d", "pulses", "E_pulse(J)",
            "t_pulse(s)",
        ],
    );
    for m in Material::ALL {
        let p = m.params();
        t.row(
            p.name,
            vec![
                format!("{}", p.levels),
                format!("{}", p.sigma_prog),
                format!("{}", p.sigma_floor),
                format!("{}", p.sigma_read),
                format!("{}/{}", p.alpha_ltp, p.alpha_ltd),
                format!("{}", p.pulses_write),
                sci(p.e_pulse),
                sci(p.t_pulse),
            ],
        );
    }
    print!("{}", t.render());
    0
}

fn cmd_artifacts() -> i32 {
    let dir = meliso::runtime::pjrt::default_artifact_dir();
    let manifest = dir.join("manifest.json");
    match std::fs::read_to_string(&manifest) {
        Ok(text) => match meliso::util::json::Json::parse(&text) {
            Ok(j) => {
                println!("artifact dir: {}", dir.display());
                if let Some(arts) = j.get("artifacts").and_then(|a| a.as_obj()) {
                    for (name, meta) in arts {
                        println!(
                            "  {name:<14} {:>9} bytes  sha256 {}…",
                            meta.get("bytes").and_then(|b| b.as_usize()).unwrap_or(0),
                            meta.get("sha256")
                                .and_then(|s| s.as_str())
                                .map(|s| &s[..12])
                                .unwrap_or("?")
                        );
                    }
                }
                0
            }
            Err(e) => {
                eprintln!("bad manifest: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!(
                "no artifacts at {} ({e}); run `make artifacts`",
                manifest.display()
            );
            1
        }
    }
}

/// Table cell for a possibly-skipped accuracy metric: with the ground
/// truth off (`--no-truth`), `rel_err_*` are NaN and render as `n/a`.
fn metric_cell(v: f64) -> String {
    if v.is_finite() {
        sci(v)
    } else {
        "n/a (truth off)".to_string()
    }
}

/// Arm the observability level the CLI sinks imply.  Runs without
/// `--metrics-out`/`--trace-out` leave the level alone so the
/// `MELISO_OBS` environment variable still governs collection.
fn arm_obs(obs: &ObsArgs) {
    let level = obs.level();
    if level > meliso::obs::ObsLevel::Off {
        meliso::obs::set_level(level);
    }
}

/// Flush the armed observability sinks at command exit.
fn write_obs_sinks(obs: &ObsArgs) -> Result<(), String> {
    if let Some(path) = &obs.metrics_out {
        meliso::obs::export::write_metrics_file(path)?;
        eprintln!("# metrics snapshot -> {path}");
    }
    if let Some(path) = &obs.trace_out {
        meliso::obs::export::write_trace_file(path)?;
        eprintln!("# chrome trace -> {path}");
    }
    Ok(())
}

fn cmd_status(args: StatusArgs) -> Result<(), String> {
    let text = std::fs::read_to_string(&args.file).map_err(|e| {
        format!(
            "cannot read {}: {e} (write one with `--metrics-out {}`)",
            args.file, args.file
        )
    })?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: not a JSON snapshot: {e}", args.file))?;
    let report = meliso::obs::StatusReport::from_json(&doc)?;
    if args.json {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

/// Build the configured solver, falling back to the native backend with a
/// note when the PJRT artifacts are unavailable.
fn solver_or_native(system: SystemConfig, opts: SolveOptions) -> Meliso {
    match Meliso::new(system, opts.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("note: {e}\nfalling back to the native backend");
            Meliso::with_backend(
                system,
                opts.with_backend(BackendKind::Native),
                std::sync::Arc::new(meliso::runtime::native::NativeBackend::new()),
            )
        }
    }
}

/// `meliso serve`: run the network front door until a `POST /shutdown`
/// begins the graceful drain (or the process is killed).
fn cmd_serve(args: ServeArgs) -> Result<(), String> {
    arm_obs(&args.obs);
    let solver = solver_or_native(args.system, args.opts.clone());
    let backend = solver.backend_name().to_string();
    let cfg = args.serve_config();
    let server = meliso::serve::Server::start(solver, cfg.clone())?;
    eprintln!(
        "# meliso serve on http://{} — device {}, system {}x{} tiles of {}², backend {}; \
         cache {} operands, window {:?}, max batch {}, {} global / {} per-client \
         in-flight, {} http threads",
        server.addr(),
        args.opts.material,
        args.system.tile_rows,
        args.system.tile_cols,
        args.system.cell_size,
        backend,
        cfg.cache_capacity,
        cfg.window,
        cfg.max_batch,
        cfg.max_inflight,
        cfg.max_inflight_per_client,
        cfg.http_threads,
    );
    eprintln!("# POST /operands, /operands/{{id}}/solve, /operands/{{id}}/solve-system; GET /status, /metrics; POST /shutdown to drain");
    server.wait();
    eprintln!("# drained; goodbye");
    write_obs_sinks(&args.obs)?;
    Ok(())
}

fn cmd_serve_bench(args: ServeBenchArgs) -> Result<(), String> {
    arm_obs(&args.obs);
    let names = args.operand_names();
    let mut sources = Vec::with_capacity(names.len());
    for name in &names {
        sources.push(registry::build(name)?);
    }
    let solver = solver_or_native(args.system, args.opts.clone());
    eprintln!(
        "# serve-bench [{}] on one shared plane, device {}, EC {}, system {}x{} tiles of \
         {}² ({} tile slots/MCA), backend {}",
        names.join(","),
        args.opts.material,
        if args.opts.ec { "on" } else { "off" },
        args.system.tile_rows,
        args.system.tile_cols,
        args.system.cell_size,
        if args.system.tile_slots == 0 {
            "∞".to_string()
        } else {
            args.system.tile_slots.to_string()
        },
        solver.backend_name(),
    );

    struct Tenant {
        name: String,
        xs: Vec<Vector>,
        session: meliso::server::Session,
        oneshot_solves: usize,
        oneshot_s: f64,
        oneshot_j: f64,
    }

    // ONE shared execution plane hosts every tenant (the multi-tenant
    // serving layout); sessions below are residencies on it.
    let plane = solver.build_plane(sources[0].as_ref())?;
    let mut tenants: Vec<Tenant> = Vec::with_capacity(names.len());
    for (t, (name, source)) in names.iter().zip(&sources).enumerate() {
        let n = source.ncols();
        // Fold the tenant index into the seed so same-dimension tenants
        // are served distinct input streams.
        let tenant_seed = args.opts.seed ^ ((t as u64) << 32);
        let xs: Vec<Vector> = (0..args.solves)
            .map(|i| Vector::standard_normal(n, tenant_seed ^ (0xB0B0 + i as u64)))
            .collect();
        // One-shot reference: every solve re-programs the operand.
        let baseline = if args.baseline > 0 {
            args.baseline.min(args.solves)
        } else {
            args.solves.min(5)
        };
        // meliso-lint: allow(clock) -- CLI baseline timing printed to the user
        let t = Instant::now();
        let mut oneshot_write_j = 0.0;
        for x in xs.iter().take(baseline) {
            let r = solver.solve_source(source.as_ref(), x)?;
            oneshot_write_j += r.ew_total;
        }
        let oneshot_s = t.elapsed().as_secs_f64() / baseline as f64;
        let oneshot_j = oneshot_write_j / baseline as f64;
        let session = solver.open_session_on(&plane, source.clone())?;
        tenants.push(Tenant {
            name: name.clone(),
            xs,
            session,
            oneshot_solves: baseline,
            oneshot_s,
            oneshot_j,
        });
    }

    // Serve the tenants' batches interleaved round-robin: one shard pool,
    // many operands, exactly the serving pattern the allocator exists for.
    let rounds = args.solves.div_ceil(args.batch);
    for round in 0..rounds {
        for tenant in &tenants {
            let lo = round * args.batch;
            if lo >= tenant.xs.len() {
                continue;
            }
            let hi = (lo + args.batch).min(tenant.xs.len());
            tenant.session.solve_batch(&tenant.xs[lo..hi])?;
        }
        // Refresh the snapshot each round (atomic rename), so a concurrent
        // `meliso status` watches occupancy and latency move live.
        if let Some(path) = &args.obs.metrics_out {
            meliso::obs::export::write_metrics_file(path)?;
        }
    }

    let (residents, slots_in_use, slot_high_water, shards) = (
        plane.resident_operands(),
        plane.slots_in_use(),
        plane.slot_high_water(),
        plane.shards(),
    );

    // Derive every reported metric once, so the JSON and table branches
    // cannot drift.
    struct TenantMetrics {
        program: meliso::server::ProgramReport,
        serving: meliso::metrics::serving::ServingReport,
        speedup: f64,
        energy_ratio: f64,
    }
    let metrics: Vec<TenantMetrics> = tenants
        .iter()
        .map(|tenant| {
            let program = tenant.session.program_report().clone();
            let serving = tenant.session.report();
            let speedup = tenant.oneshot_s / (serving.latency_mean_ms / 1e3).max(1e-12);
            let energy_ratio =
                tenant.oneshot_j / serving.write_energy_per_solve_j.max(f64::MIN_POSITIVE);
            TenantMetrics {
                program,
                serving,
                speedup,
                energy_ratio,
            }
        })
        .collect();

    if args.json {
        let mut per_op = Vec::new();
        for (tenant, m) in tenants.iter().zip(&metrics) {
            let mut j = Json::obj();
            j.set("matrix", Json::Str(tenant.name.clone()))
                .set("oneshot_solves", Json::Num(tenant.oneshot_solves as f64))
                .set("oneshot_per_solve_s", Json::Num(tenant.oneshot_s))
                .set("oneshot_write_j_per_solve", Json::Num(tenant.oneshot_j))
                .set("program_wall_s", Json::Num(m.program.wall_seconds))
                .set("program_write_j", Json::Num(m.program.write_energy_j))
                .set("serving", m.serving.to_json())
                .set("wall_speedup", Json::Num(m.speedup))
                .set("write_energy_ratio", Json::Num(m.energy_ratio));
            per_op.push(j);
        }
        let mut plane_j = Json::obj();
        plane_j
            .set("resident_operands", Json::Num(residents as f64))
            .set("slots_in_use", Json::Num(slots_in_use as f64))
            .set("slot_high_water", Json::Num(slot_high_water as f64))
            .set("shards", Json::Num(shards as f64));
        let mut j = Json::obj();
        j.set("operands", Json::Arr(per_op)).set("plane", plane_j);
        println!("{}", j.pretty());
    } else {
        for (tenant, m) in tenants.iter().zip(&metrics) {
            let program = &m.program;
            let serving = &m.serving;
            let speedup = m.speedup;
            let energy_ratio = m.energy_ratio;
            let mut t = TableBuilder::new(
                &format!(
                    "serve-bench {} — one-shot vs resident session ({})",
                    tenant.name, tenant.session.operand_id()
                ),
                &["value"],
            );
            t.row("one-shot solves", vec![format!("{}", tenant.oneshot_solves)]);
            t.row(
                "one-shot per-solve (ms)",
                vec![format!("{:.3}", tenant.oneshot_s * 1e3)],
            );
            t.row("one-shot write J/solve", vec![sci(tenant.oneshot_j)]);
            t.row("program wall (s)", vec![format!("{:.3}", program.wall_seconds)]);
            t.row("program write (J)", vec![sci(program.write_energy_j)]);
            t.row("resident chunks", vec![format!("{}", program.chunks_resident)]);
            t.row("resident solves", vec![format!("{}", serving.solves)]);
            t.row(
                "resident per-solve (ms)",
                vec![format!("{:.3}", serving.latency_mean_ms)],
            );
            t.row("resident p50 (ms)", vec![format!("{:.3}", serving.latency_p50_ms)]);
            t.row("resident p99 (ms)", vec![format!("{:.3}", serving.latency_p99_ms)]);
            t.row(
                "resident write J/solve",
                vec![sci(serving.write_energy_per_solve_j)],
            );
            t.row(
                "resident read J/solve",
                vec![sci(serving.read_energy_per_solve_j)],
            );
            t.row(
                "throughput (solve/s)",
                vec![format!("{:.1}", serving.throughput_sps)],
            );
            t.row("wall speedup", vec![format!("{speedup:.1}x")]);
            t.row("write energy ratio", vec![format!("{energy_ratio:.1}x")]);
            print!("{}", t.render());
        }
        let mut t = TableBuilder::new("shared execution plane", &["value"]);
        t.row("resident operands", vec![format!("{residents}")]);
        t.row("tile slots in use", vec![format!("{slots_in_use}")]);
        t.row("tile slot high water", vec![format!("{slot_high_water}")]);
        t.row("shards", vec![format!("{shards}")]);
        print!("{}", t.render());
    }
    write_obs_sinks(&args.obs)?;
    Ok(())
}

fn cmd_solve_system(args: SolveSystemArgs) -> Result<(), String> {
    arm_obs(&args.obs);
    let source = registry::build(&args.matrix)?;
    if source.nrows() != source.ncols() {
        return Err(format!(
            "solve-system needs a square operand, {} is {}x{}",
            args.matrix,
            source.nrows(),
            source.ncols()
        ));
    }
    let n = source.ncols();
    // Right-hand side from a hidden ground-truth solution so the actual
    // solution error is reportable alongside the residual.
    let x_star = Vector::standard_normal(n, args.opts.seed ^ 0xA11CE);
    let b = source.matvec(&x_star);
    let solver = solver_or_native(args.system, args.opts.clone());
    eprintln!(
        "# solve-system {} ({n}x{n}), method {}, tol {:.1e}, device {}, EC {}, \
         system {}x{} tiles of {}², backend {}",
        args.matrix,
        args.iter.method,
        args.iter.tol,
        args.opts.material,
        if args.opts.ec { "on" } else { "off" },
        args.system.tile_rows,
        args.system.tile_cols,
        args.system.cell_size,
        solver.backend_name(),
    );
    let report = solver.solve_system(source, &b, &args.iter)?;
    let x_err = report.x.sub(&x_star).norm_l2() / x_star.norm_l2();
    if args.json {
        let mut j = report.to_json();
        j.set("matrix", Json::Str(args.matrix.clone()))
            .set("x_error_l2", Json::Num(x_err));
        println!("{}", j.pretty());
    } else {
        let mut t = TableBuilder::new(
            &format!("solve-system {} via {}", args.matrix, report.method),
            &["value"],
        );
        t.row("converged", vec![format!("{}", report.converged)]);
        t.row("rel residual", vec![sci(report.rel_residual)]);
        t.row("x error (l2)", vec![sci(x_err)]);
        t.row("iterations", vec![format!("{}", report.iterations)]);
        t.row("refinements", vec![format!("{}", report.refinements)]);
        t.row("MVMs", vec![format!("{}", report.mvms)]);
        t.row(
            "programming passes",
            vec![format!("{}", report.programming_passes)],
        );
        t.row("program write (J)", vec![sci(report.program_energy_j)]);
        t.row("encode write (J)", vec![sci(report.solve_write_energy_j)]);
        t.row("read (J)", vec![sci(report.read_energy_j)]);
        t.row(
            "write amortization",
            vec![format!("{:.1}x", report.write_amortization())],
        );
        t.row("wall (s)", vec![format!("{:.3}", report.wall_seconds)]);
        print!("{}", t.render());
    }
    write_obs_sinks(&args.obs)?;
    Ok(())
}

fn cmd_run(run: RunArgs) -> Result<(), String> {
    arm_obs(&run.obs);
    let source = registry::build(&run.matrix)?;
    let x = Vector::standard_normal(source.ncols(), run.opts.seed ^ 0x5eed);
    let solver = Meliso::new(run.system, run.opts.clone())?;
    eprintln!(
        "# {} ({}x{}), device {}, EC {}, k={}, system {}x{} tiles of {}², backend {}",
        run.matrix,
        source.nrows(),
        source.ncols(),
        run.opts.material,
        if run.opts.ec { "on" } else { "off" },
        run.opts.wv_iters,
        run.system.tile_rows,
        run.system.tile_cols,
        run.system.cell_size,
        solver.backend_name(),
    );
    let reports = solver.replicate(source.as_ref(), &x, run.reps.max(1))?;
    if run.json {
        let mut arr = Vec::new();
        for r in &reports {
            arr.push(r.to_json());
        }
        println!("{}", meliso::util::json::Json::Arr(arr).pretty());
    } else {
        let s = ReplicationSummary::from_reports(&reports);
        let last = reports.last().unwrap();
        let mut t = TableBuilder::new(
            &format!("{} x {} reps", run.matrix, s.reps),
            &["value"],
        );
        t.row("rel l2 error", vec![metric_cell(s.rel_err_l2)]);
        t.row("rel linf error", vec![metric_cell(s.rel_err_inf)]);
        t.row("E_w mean (J)", vec![sci(s.ew_mean)]);
        t.row("L_w mean (s)", vec![sci(s.lw_mean)]);
        t.row("chunks", vec![format!("{}", last.chunks_total)]);
        t.row("chunks skipped", vec![format!("{}", last.chunks_skipped)]);
        t.row("MCAs used", vec![format!("{}", last.mcas_used)]);
        t.row(
            "norm. factor",
            vec![format!("{}", last.row_reassignments)],
        );
        t.row("wall (s)", vec![format!("{:.3}", last.wall_seconds)]);
        print!("{}", t.render());
    }
    write_obs_sinks(&run.obs)?;
    Ok(())
}
